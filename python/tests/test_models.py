"""L2 model correctness: shapes, gradient sanity, variant equivalence,
and the flat-parameter layout contract the Rust runtime depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def init_like_rust(model, key=0, scale=None):
    """Initialise a flat vector per the layer specs (mirrors runtime/init.rs)."""
    rng = np.random.default_rng(key)
    parts = []
    for s in model.layers:
        if s.init == "zeros":
            parts.append(np.zeros(s.size, np.float32))
        elif s.init == "ones":
            parts.append(np.ones(s.size, np.float32))
        elif s.init == "glorot_uniform":
            limit = np.sqrt(6.0 / (s.fan_in + s.fan_out))
            parts.append(rng.uniform(-limit, limit, s.size).astype(np.float32))
        elif s.init.startswith("normal:"):
            std = float(s.init.split(":")[1])
            parts.append((rng.standard_normal(s.size) * std).astype(np.float32))
        else:
            raise ValueError(s.init)
    return jnp.array(np.concatenate(parts))


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_param_count_matches_layers(name):
    m = M.build(name)
    assert M.param_count(m) == sum(s.size for s in m.layers)
    # layout contract: every layer has positive size and a known init
    for s in m.layers:
        assert s.size > 0
        assert s.init in ("zeros", "ones", "glorot_uniform") or s.init.startswith("normal:")


@pytest.mark.parametrize("name,batch", [("mlp", 4), ("cnn_mnist", 2), ("cnn_cifar", 2)])
def test_grad_shapes_and_loss(name, batch):
    m = M.build(name)
    params = init_like_rust(m)
    rng = np.random.default_rng(1)
    x = jnp.array(rng.uniform(0, 1, (batch, m.x_dim)).astype(np.float32))
    y = jnp.array(rng.integers(0, m.classes, (batch, 1)).astype(np.int32))
    loss, grads = M.make_grad(m, "jnp")(params, x, y)
    assert grads.shape == params.shape
    # fresh init → near-uniform predictions → loss ≈ ln(10)
    assert 1.8 < float(loss) < 2.9
    assert float(jnp.linalg.norm(grads)) > 0.0


def test_transformer_grad_shapes():
    m = M.build("transformer")
    params = init_like_rust(m)
    rng = np.random.default_rng(2)
    x = jnp.array(rng.integers(0, m.vocab, (2, m.seq_len)).astype(np.float32))
    y = jnp.array(rng.integers(0, m.vocab, (2, m.seq_len)).astype(np.int32))
    loss, grads = M.make_grad(m, "jnp")(params, x, y)
    assert grads.shape == params.shape
    # ln(64) ≈ 4.16 at init
    assert 3.5 < float(loss) < 4.8


@pytest.mark.parametrize("name", ["mlp", "cnn_mnist"])
def test_variants_agree(name):
    m = M.build(name)
    params = init_like_rust(m)
    rng = np.random.default_rng(3)
    batch = 4
    x = jnp.array(rng.uniform(0, 1, (batch, m.x_dim)).astype(np.float32))
    y = jnp.array(rng.integers(0, m.classes, (batch, 1)).astype(np.int32))
    l1, g1 = M.make_grad(m, "jnp")(params, x, y)
    l2, g2 = M.make_grad(m, "pallas")(params, x, y)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
    np.testing.assert_allclose(np.array(g1), np.array(g2), rtol=2e-2, atol=2e-4)


def test_eval_counts():
    m = M.build("mlp")
    params = init_like_rust(m)
    rng = np.random.default_rng(4)
    n = 16
    x = jnp.array(rng.uniform(0, 1, (n, m.x_dim)).astype(np.float32))
    y = jnp.array(rng.integers(0, m.classes, (n, 1)).astype(np.int32))
    sum_loss, correct = M.make_eval(m, "jnp")(params, x, y)
    assert 0 <= int(correct) <= n
    assert float(sum_loss) / n == pytest.approx(2.30, abs=0.6)


def test_sgd_on_mlp_reduces_loss():
    """A few hundred sequential SGD steps must learn a separable toy task."""
    m = M.Mlp("toy", [4, 16, 2])
    params = init_like_rust(m, key=5)
    grad = jax.jit(lambda p, x, y: M.make_grad(m, "jnp")(p, x, y))
    rng = np.random.default_rng(6)

    def batch():
        x = rng.standard_normal((16, 4)).astype(np.float32)
        y = (x[:, 0] > x[:, 1]).astype(np.int32)[:, None]
        return jnp.array(x), jnp.array(y)

    x0, y0 = batch()
    first, _ = grad(params, x0, y0)
    for _ in range(200):
        x, y = batch()
        loss, g = grad(params, x, y)
        params = params - 0.1 * g
    x1, y1 = batch()
    last, _ = grad(params, x1, y1)
    assert float(last) < float(first) * 0.6


def test_unpack_rejects_wrong_size():
    m = M.build("mlp")
    bad = jnp.zeros((M.param_count(m) + 1,), jnp.float32)
    with pytest.raises(AssertionError):
        m.logits(bad, jnp.zeros((1, m.x_dim)), "jnp")
