"""AOT pipeline: HLO text emission, manifest integrity, interface shapes."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_graph_emits_hlo_text():
    m = M.build("mlp")
    text = aot.lower_graph(m, "grad", 8, "jnp")
    assert text.startswith("HloModule")
    # entry layout carries the flat-param + batch shapes
    assert f"f32[{M.param_count(m)}]" in text
    assert "f32[8,20]" in text
    assert "s32[8,1]" in text


def test_lower_update_and_reduce():
    t = aot.lower_update(100, "jnp")
    assert t.startswith("HloModule")
    assert "f32[100]" in t
    t = aot.lower_reduce(100, 4, "jnp")
    assert "f32[4,100]" in t


def test_pallas_variant_lowers_to_plain_hlo():
    """interpret=True must leave no custom-calls the CPU client can't run."""
    m = M.build("mlp")
    text = aot.lower_graph(m, "grad", 8, "pallas")
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistency():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        man = json.load(f)
    assert man["format_version"] == 1
    for name, entry in man["models"].items():
        model = M.build(name)
        assert entry["param_count"] == M.param_count(model)
        assert len(entry["layers"]) == len(model.layers)
        total = sum(
            int(jnp.prod(jnp.array(l["shape"]))) for l in entry["layers"]
        )
        assert total == entry["param_count"]
    for art in man["artifacts"]:
        path = os.path.join(ARTIFACTS, art["path"])
        assert os.path.exists(path), art["path"]
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule")
    # at least one pallas and one jnp variant of the same graph exist
    pairs = {(a["model"], a["kind"], a["batch"]) for a in man["artifacts"] if a["variant"] == "pallas"}
    jnps = {(a["model"], a["kind"], a["batch"]) for a in man["artifacts"] if a["variant"] == "jnp"}
    assert pairs & jnps, "no jnp/pallas artifact pair for the ablation bench"


def test_hlo_text_reparses():
    """HLO text must survive the text parser round trip — the exact path the
    Rust runtime takes (`HloModuleProto::from_text_file`). Execution-level
    verification lives in the Rust integration tests, the real consumer."""
    from jax._src.lib import xla_client as xc

    m = M.build("mlp")
    text = aot.lower_graph(m, "grad", 8, "jnp")
    mod = xc._xla.hlo_module_from_text(text)
    reparsed = mod.to_string()
    assert "f32[6154]" in reparsed
    # ids were reassigned into the 32-bit range the xla crate requires
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 1000
