"""L1 kernel correctness: Pallas vs the pure-jnp oracle (`ref.py`).

hypothesis sweeps shapes; fixed cases pin the paper-relevant sizes. All
kernels run interpret=True (the only executable mode on CPU PJRT).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, sgd_update

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- dense ---


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 50),
    n=st.integers(1, 40),
    relu=st.booleans(),
)
def test_dense_matches_ref_shapes(m, k, n, relu):
    x = rand(m * 7919 + 1, (m, k))
    w = rand(k * 104729 + 2, (k, n))
    b = rand(n + 3, (n,))
    got = matmul.dense(x, w, b, relu)
    want = ref.dense_ref(x, w, b, relu)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (32, 20, 64),     # mlp layer 0 at batch 32
        (128, 64, 10),    # mlp head at batch 128
        (100, 784, 64),   # cnn_mnist fc0 at eval batch
        (256, 128, 128),  # block-aligned case
        (1, 1, 1),        # degenerate
    ],
)
def test_dense_paper_shapes(m, k, n):
    x = rand(1, (m, k))
    w = rand(2, (k, n))
    b = rand(3, (n,))
    got = matmul.dense(x, w, b, True)
    want = ref.dense_ref(x, w, b, True)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-5)


def test_dense_gradients_match_ref():
    x = rand(1, (9, 13))
    w = rand(2, (13, 5))
    b = rand(3, (5,))

    def f(x, w, b):
        return jnp.sum(matmul.dense(x, w, b, True) ** 2)

    def fr(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, True) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g, gr):
        np.testing.assert_allclose(np.array(a), np.array(c), rtol=1e-4, atol=1e-5)


def test_matmul_wrapper():
    x = rand(4, (17, 6))
    w = rand(5, (6, 11))
    np.testing.assert_allclose(
        np.array(matmul.matmul(x, w)), np.array(ref.matmul_ref(x, w)), rtol=1e-4, atol=1e-5
    )


def test_vmem_footprint_fits_tpu():
    """Every dense layer in the model zoo must fit VMEM comfortably."""
    VMEM = 16 * 1024 * 1024
    for (m, k, n) in [(128, 784, 64), (128, 2048, 64), (512, 64, 256), (512, 64, 64)]:
        assert matmul.vmem_footprint(m, k, n) < VMEM // 2, (m, k, n)


def test_mxu_estimate_monotone():
    small = matmul.mxu_utilization_estimate(8, 8, 8)
    big = matmul.mxu_utilization_estimate(128, 128, 128)
    assert 0.0 < small < big <= 1.0


# ----------------------------------------------------------- sgd_update ---


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 9000), scale=st.floats(0.0001, 1.0))
def test_sgd_update_matches_ref(p, scale):
    params = rand(p + 10, (p,))
    gsum = rand(p + 11, (p,))
    s = jnp.array([scale], jnp.float32)
    got = sgd_update.sgd_update(params, gsum, s)
    want = ref.sgd_update_ref(params, gsum, s)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-6)


def test_sgd_update_paper_sizes():
    # exact parameter counts of the model zoo
    for p in (6154, 52138, 111936):
        params = rand(p, (p,))
        gsum = rand(p + 1, (p,))
        s = jnp.array([0.01 / 8], jnp.float32)  # lr / k for a flush of 8
        got = sgd_update.sgd_update(params, gsum, s)
        want = ref.sgd_update_ref(params, gsum, s)
        np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(k=st.integers(1, 12), p=st.integers(1, 5000))
def test_buffer_reduce_matches_ref(k, p):
    st_ = rand(k * 31 + p, (k, p))
    got = sgd_update.buffer_reduce(st_)
    want = ref.buffer_reduce_ref(st_)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=1e-4, atol=1e-4)


def test_update_footprint():
    assert sgd_update.update_vmem_footprint(111936) < 1024 * 1024
