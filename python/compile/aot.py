"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts + manifest.json.

This is the only place Python runs in the whole system, and it runs once
(`make artifacts`). Every (model, graph, batch, variant) combination in SPECS
is lowered with `jax.jit(...).lower(...)` and serialised as **HLO text** —
not `HloModuleProto.serialize()`: jax >= 0.5 emits protos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

The manifest carries everything the Rust runtime needs to use the artifacts
without Python: tensor shapes, the flat parameter layout with init specs,
and dataset dims.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# (model, graph-kind, batch, variant). The jnp variants are the runtime
# defaults; pallas variants exist for the kernel-equivalence tests and the
# runtime ablation bench (identical numerics, different HLO).
SPECS = [
    # mlp: the paper's random-dataset experiments sweep batch sizes (Table 3)
    *[("mlp", "grad", b, "jnp") for b in (8, 16, 32, 64, 128)],
    ("mlp", "grad", 32, "pallas"),
    ("mlp", "eval", 100, "jnp"),
    # cnn_mnist: Tables 1, Fig 4-5 use batch 32 and 64
    ("cnn_mnist", "grad", 32, "jnp"),
    ("cnn_mnist", "grad", 64, "jnp"),
    ("cnn_mnist", "grad", 32, "pallas"),
    ("cnn_mnist", "eval", 100, "jnp"),
    # cnn_cifar: Table 2, Fig 6-7
    ("cnn_cifar", "grad", 32, "jnp"),
    ("cnn_cifar", "grad", 64, "jnp"),
    ("cnn_cifar", "eval", 100, "jnp"),
    # transformer: the end-to-end driver
    ("transformer", "grad", 8, "jnp"),
    ("transformer", "eval", 8, "jnp"),
]

# Parameter-server ops (L1 kernels as standalone artifacts), per model size.
UPDATE_SPECS = [("mlp", "pallas"), ("mlp", "jnp")]
REDUCE_K = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_graph(model, kind: str, batch: int, variant: str) -> str:
    p = jax.ShapeDtypeStruct((M.param_count(model),), jnp.float32)
    x = jax.ShapeDtypeStruct((batch, model.x_dim), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, model.y_dim), jnp.int32)
    if model.kind == "transformer":
        # x is [B, S] token ids (as f32), y is [B, S]
        x = jax.ShapeDtypeStruct((batch, model.seq_len), jnp.float32)
        y = jax.ShapeDtypeStruct((batch, model.seq_len), jnp.int32)
    fn = M.make_grad(model, variant) if kind == "grad" else M.make_eval(model, variant)
    return to_hlo_text(jax.jit(fn).lower(p, x, y))


def lower_update(pcount: int, variant: str) -> str:
    from .kernels import ref, sgd_update

    p = jax.ShapeDtypeStruct((pcount,), jnp.float32)
    g = jax.ShapeDtypeStruct((pcount,), jnp.float32)
    s = jax.ShapeDtypeStruct((1,), jnp.float32)
    fn = sgd_update.sgd_update if variant == "pallas" else ref.sgd_update_ref
    return to_hlo_text(jax.jit(lambda a, b, c: (fn(a, b, c),)).lower(p, g, s))


def lower_reduce(pcount: int, k: int, variant: str) -> str:
    from .kernels import ref, sgd_update

    st = jax.ShapeDtypeStruct((k, pcount), jnp.float32)
    fn = sgd_update.buffer_reduce if variant == "pallas" else ref.buffer_reduce_ref
    return to_hlo_text(jax.jit(lambda a: (fn(a),)).lower(st))


def layer_json(spec: M.LayerSpec) -> dict:
    return {
        "name": spec.name,
        "shape": list(spec.shape),
        "init": spec.init,
        "fan_in": spec.fan_in,
        "fan_out": spec.fan_out,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated model filter (e.g. 'mlp,cnn_mnist') for faster rebuilds",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = {"format_version": 1, "models": {}, "artifacts": [], "ops": []}

    for name in M.MODEL_NAMES:
        if only and name not in only:
            continue
        model = M.build(name)
        entry = {
            "kind": model.kind,
            "x_dim": model.x_dim,
            "y_dim": model.y_dim,
            "classes": model.classes,
            "param_count": M.param_count(model),
            "layers": [layer_json(s) for s in model.layers],
        }
        if model.kind == "transformer":
            entry["vocab"] = model.vocab
            entry["seq_len"] = model.seq_len
        manifest["models"][name] = entry

    for name, kind, batch, variant in SPECS:
        if only and name not in only:
            continue
        model = M.build(name)
        fname = f"{name}_{kind}_b{batch}_{variant}.hlo.txt"
        path = os.path.join(args.out, fname)
        print(f"lowering {fname} ...", flush=True)
        text = lower_graph(model, kind, batch, variant)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "model": name,
                "kind": kind,
                "batch": batch,
                "variant": variant,
                "path": fname,
                "param_count": M.param_count(model),
                "x_dim": model.x_dim if model.kind != "transformer" else model.seq_len,
                "y_dim": model.y_dim,
            }
        )

    for name, variant in UPDATE_SPECS:
        if only and name not in only:
            continue
        model = M.build(name)
        pc = M.param_count(model)
        for op, lower in (("sgd_update", lower_update), ("buffer_reduce", None)):
            fname = f"{op}_{name}_{variant}.hlo.txt"
            path = os.path.join(args.out, fname)
            print(f"lowering {fname} ...", flush=True)
            if op == "sgd_update":
                text = lower_update(pc, variant)
            else:
                text = lower_reduce(pc, REDUCE_K, variant)
            with open(path, "w") as f:
                f.write(text)
            manifest["ops"].append(
                {
                    "op": op,
                    "model": name,
                    "variant": variant,
                    "path": fname,
                    "param_count": pc,
                    "k": REDUCE_K if op == "buffer_reduce" else 0,
                }
            )
            _ = lower

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}: {len(manifest['artifacts'])} graphs, {len(manifest['ops'])} ops")


if __name__ == "__main__":
    main()
