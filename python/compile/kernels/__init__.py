"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import matmul, ref, sgd_update

__all__ = ["matmul", "ref", "sgd_update"]
