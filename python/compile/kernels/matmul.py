"""L1 Pallas kernel: tiled matmul with fused bias + ReLU (dense layer).

Hardware adaptation (DESIGN.md §6): the paper's workloads are dense-layer
dominated; on TPU the dense layer is an MXU systolic-array matmul. The kernel
tiles the output into (bm × bn) VMEM blocks over a 2-D grid; the K dimension
stays resident per block (weights stream HBM→VMEM once per (i, j) tile via
the BlockSpec index maps). f32 accumulation throughout.

CPU execution uses `interpret=True` (real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot run); the VMEM-footprint estimate
printed by `vmem_footprint` is the TPU-viability check.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (bm, bn) output tile: x_tile[bm, K] @ w_tile[K, bn] + b[bn]."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pick_block(dim: int, target: int) -> int:
    """Largest block <= target; dims are padded to a multiple of it."""
    return min(dim, target)


def _pad_to(x, axis, multiple):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad), size


def _dense_impl(x, w, b, relu: bool, bm: int = 128, bn: int = 128):
    """Fused dense layer via the Pallas kernel.

    x: [m, k], w: [k, n], b: [n] -> [m, n] (f32). Arbitrary shapes are
    supported by zero-padding m and n up to the block multiple and slicing
    the result back (K needs no padding: it is loaded whole per tile).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm_ = _pick_block(m, bm)
    bn_ = _pick_block(n, bn)
    xp, m0 = _pad_to(x, 0, bm_)
    wp, n0 = _pad_to(w, 1, bn_)
    bp = jnp.pad(b, (0, wp.shape[1] - n))[None, :]  # [1, n_pad]
    grid = (xp.shape[0] // bm_, wp.shape[1] // bn_)
    out = pl.pallas_call(
        functools.partial(_dense_kernel, relu=relu),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn_), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn_), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m0, :n0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool = False):
    """Differentiable fused dense layer (forward AND backward run the Pallas
    kernel).

    interpret-mode `pallas_call` has no built-in autodiff rule, so the VJP is
    supplied explicitly — which is also the TPU-honest formulation: the
    backward pass is two more MXU matmuls (dy·wᵀ and xᵀ·dy) through the same
    tiled kernel.
    """
    return _dense_impl(x, w, b, relu)


def _dense_fwd(x, w, b, relu):
    y = _dense_impl(x, w, b, relu)
    return y, (x, w, y)


def _dense_bwd(relu, res, dy):
    x, w, y = res
    if relu:
        dy = dy * (y > 0.0).astype(dy.dtype)
    zb_k = jnp.zeros((w.shape[0],), jnp.float32)
    zb_n = jnp.zeros((w.shape[1],), jnp.float32)
    dx = _dense_impl(dy, w.T, zb_k, False)
    dw = _dense_impl(x.T, dy, zb_n, False)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def matmul(x, w):
    """Plain matmul through the same kernel (zero bias, no ReLU)."""
    return dense(x, w, jnp.zeros((w.shape[1],), jnp.float32), False)


def vmem_footprint(m: int, k: int, n: int, bm: int = 128, bn: int = 128) -> int:
    """Bytes of VMEM one grid step touches (x-tile + w-tile + out-tile + bias).

    TPU v4 VMEM is ~16 MiB/core; DESIGN.md §Perf uses this to argue the
    chosen tiling is TPU-viable for every layer in the model zoo.
    """
    bm_ = min(m, bm)
    bn_ = min(n, bn)
    floats = bm_ * k + k * bn_ + bm_ * bn_ + bn_
    return 4 * floats


def mxu_utilization_estimate(m: int, k: int, n: int, bm: int = 128, bn: int = 128) -> float:
    """Fraction of 128x128 MXU lanes a tile keeps busy (structural estimate).

    interpret-mode wall time is *not* a TPU proxy; this ratio (tile area vs
    MXU area, capped at 1) is what EXPERIMENTS.md §Perf reports per layer.
    """
    bm_ = min(m, bm)
    bn_ = min(n, bn)
    return min(1.0, (bm_ * bn_) / (128.0 * 128.0)) * min(1.0, k / 128.0)
