"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness).

Each Pallas kernel in this package has a `*_ref` twin here with identical
semantics; `python/tests/test_kernels.py` sweeps shapes/dtypes with
hypothesis and asserts allclose. The L2 model can be built against either
implementation (the `variant` argument of `model.build`), which is also how
the jnp-vs-pallas artifact pair for the runtime benches is produced.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain f32 matmul: [m, k] @ [k, n] -> [m, n]."""
    return jnp.matmul(x, w)


def dense_ref(x, w, b, relu: bool):
    """Fused dense layer: x @ w + b, optional ReLU."""
    y = jnp.matmul(x, w) + b
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def sgd_update_ref(params, grad_sum, scale):
    """Fused parameter-server update: theta - scale * grad_sum.

    `scale` = lr / k for a flush of k buffered gradients. Shapes: all [p],
    scale broadcastable scalar (shape [1]).
    """
    return params - scale * grad_sum


def buffer_reduce_ref(stacked):
    """Sum k stacked gradients: [k, p] -> [p]."""
    return jnp.sum(stacked, axis=0)
