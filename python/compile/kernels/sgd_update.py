"""L1 Pallas kernel: fused parameter-server update  theta - scale * grad_sum.

The aggregation step the paper's contribution centres on: after a hybrid
flush of k buffered gradients the PS applies one averaged SGD step. Fusing
the scale-and-subtract into a single 1-D tiled kernel keeps the update
bandwidth-bound with exactly one read of theta, one read of grad_sum and one
write — the roofline for this op.

Also here: the buffer-reduction kernel summing k stacked gradients (the
flush's other half, exposed separately so the runtime bench can compare the
XLA path against the Rust-native accumulating buffer).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _update_kernel(p_ref, g_ref, s_ref, o_ref):
    o_ref[...] = p_ref[...] - s_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("bp",))
def sgd_update(params, grad_sum, scale, bp: int = 4096):
    """params, grad_sum: [p]; scale: [1] (lr / k). Returns updated [p]."""
    (p,) = params.shape
    bp_ = min(p, bp)
    rem = p % bp_
    pad = 0 if rem == 0 else bp_ - rem
    pp = jnp.pad(params, (0, pad))
    gp = jnp.pad(grad_sum, (0, pad))
    grid = (pp.shape[0] // bp_,)
    out = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp_,), lambda i: (i,)),
            pl.BlockSpec((bp_,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(pp.shape, jnp.float32),
        interpret=True,
    )(pp, gp, scale)
    return out[:p]


def _reduce_kernel(s_ref, o_ref):
    o_ref[...] = jnp.sum(s_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("bp",))
def buffer_reduce(stacked, bp: int = 4096):
    """Sum k stacked gradients: [k, p] -> [p], tiled along p."""
    k, p = stacked.shape
    bp_ = min(p, bp)
    rem = p % bp_
    pad = 0 if rem == 0 else bp_ - rem
    sp = jnp.pad(stacked, ((0, 0), (0, pad)))
    grid = (sp.shape[1] // bp_,)
    out = pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((k, bp_), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bp_,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp.shape[1],), jnp.float32),
        interpret=True,
    )(sp)
    return out[:p]


def update_vmem_footprint(p: int, bp: int = 4096) -> int:
    """Bytes of VMEM per grid step (theta + grad + out tiles)."""
    bp_ = min(p, bp)
    return 4 * (3 * bp_ + 1)
