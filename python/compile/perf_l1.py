"""L1 perf analysis: block-size sweep for the Pallas dense kernel.

interpret=True wall time is a *functional* check only (it simulates the grid
on CPU); the TPU-relevant outputs are the structural metrics — VMEM bytes
per grid step and the MXU-utilisation estimate — for every dense shape the
model zoo actually runs. Usage:

    cd python && python -m compile.perf_l1
"""

import time

import jax
import jax.numpy as jnp

from .kernels import matmul

# every (m, k, n) dense shape in the model zoo at its training batch
SHAPES = [
    ("mlp l0 b32", 32, 20, 64),
    ("mlp l1 b32", 32, 64, 64),
    ("mlp head b128", 128, 64, 10),
    ("cnn_mnist conv1 im2col", 32 * 28 * 28, 9, 8),
    ("cnn_mnist conv2 im2col", 32 * 14 * 14, 72, 16),
    ("cnn_mnist fc0", 32, 784, 64),
    ("cnn_cifar fc0", 32, 2048, 64),
    ("transformer qkv b8", 8 * 64, 64, 64),
    ("transformer mlp b8", 8 * 64, 64, 256),
]

BLOCKS = [(32, 32), (64, 64), (128, 128), (256, 128)]
VMEM = 16 * 1024 * 1024  # TPU v4 per-core VMEM


def main() -> None:
    print(f"{'shape':<26} {'(m,k,n)':<20} {'blocks':<10} {'VMEM/step':<12} "
          f"{'MXU est':<8} {'interp ms':<10}")
    for name, m, k, n in SHAPES:
        best = None
        for bm, bn in BLOCKS:
            fp = matmul.vmem_footprint(m, k, n, bm, bn)
            util = matmul.mxu_utilization_estimate(m, k, n, bm, bn)
            if fp > VMEM // 2:
                continue  # leave headroom for double-buffering
            score = util
            if best is None or score > best[2]:
                best = (bm, bn, util, fp)
        bm, bn, util, fp = best
        x = jnp.ones((m, k), jnp.float32)
        w = jnp.ones((k, n), jnp.float32)
        b = jnp.zeros((n,), jnp.float32)
        f = jax.jit(lambda x, w, b: matmul._dense_impl(x, w, b, False, bm, bn))
        f(x, w, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            f(x, w, b).block_until_ready()
        ms = (time.perf_counter() - t0) / 3 * 1000
        print(
            f"{name:<26} {str((m, k, n)):<20} {f'{bm}x{bn}':<10} "
            f"{fp / 1024:>8.0f} KiB {util:>7.2f} {ms:>9.2f}"
        )
    print(
        "\nAll selected tilings fit < 1/2 VMEM (double-buffer headroom); the"
        "\nsmall-K im2col conv tiles are bandwidth-bound on MXU (util < 0.1) —"
        "\nexpected for 3x3 convs; the fc / attention GEMMs reach the usable"
        "\nrange. interpret-ms is functional only (not a TPU proxy)."
    )


if __name__ == "__main__":
    main()
