"""Build-time compile package: L1 kernels, L2 models, AOT lowering."""
