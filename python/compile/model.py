"""L2: the paper's models as JAX functions over a single flat parameter
vector, plus their grad/eval graphs.

Every model exposes the same AOT interface, which is what the Rust runtime
compiles and calls:

    grad(params f32[P], x f32[B, x_dim], y s32[B, y_dim]) -> (loss f32, grads f32[P])
    eval(params f32[P], x f32[B, x_dim], y s32[B, y_dim]) -> (sum_loss f32, correct f32)

The flat-parameter layout is defined by `Model.layers` (name, shape, init)
in order; the same specs are exported into `manifest.json` so the Rust side
can initialise parameters without running Python (`runtime/init.rs`
replicates the init distributions with its own RNG — the *distribution*
matters for the experiments, not bit-equality).

`variant` selects the dense-layer implementation: `jnp` (pure XLA ops — the
fast runtime default) or `pallas` (the L1 kernel; convolution is lowered to
im2col + the same kernel, the TPU hardware adaptation of DESIGN.md §6).
pytest asserts the two variants agree numerically.
"""

import dataclasses
import math
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as pallas_matmul
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One parameter tensor in the flat layout."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "glorot_uniform" | "zeros" | "ones" | "normal:<std>"
    fan_in: int = 0
    fan_out: int = 0

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def glorot(name, shape, fan_in, fan_out):
    return LayerSpec(name, tuple(shape), "glorot_uniform", fan_in, fan_out)


def zeros(name, shape):
    return LayerSpec(name, tuple(shape), "zeros")


def ones(name, shape):
    return LayerSpec(name, tuple(shape), "ones")


def normal(name, shape, std):
    return LayerSpec(name, tuple(shape), f"normal:{std}")


def unpack(params, specs: List[LayerSpec]):
    """Slice the flat vector into the per-layer tensors."""
    out = []
    off = 0
    for s in specs:
        out.append(params[off : off + s.size].reshape(s.shape))
        off += s.size
    assert off == params.shape[0], f"param count mismatch: {off} vs {params.shape[0]}"
    return out


def _dense(variant: str, x, w, b, relu: bool):
    if variant == "pallas":
        return pallas_matmul.dense(x, w, b, relu)
    return kref.dense_ref(x, w, b, relu)


# --------------------------------------------------------------------------
# MLP (the paper's random-dataset workload, §7.2-7.4)
# --------------------------------------------------------------------------


class Mlp:
    """Fully-connected ReLU net over `dims`, NLL loss."""

    kind = "mlp"

    def __init__(self, name: str, dims: List[int]):
        self.name = name
        self.dims = dims
        self.x_dim = dims[0]
        self.classes = dims[-1]
        self.y_dim = 1
        self.layers: List[LayerSpec] = []
        for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
            self.layers.append(glorot(f"w{i}", (di, do), di, do))
            self.layers.append(zeros(f"b{i}", (do,)))

    def logits(self, params, x, variant):
        ts = unpack(params, self.layers)
        h = x
        n = len(self.dims) - 1
        for i in range(n):
            w, b = ts[2 * i], ts[2 * i + 1]
            h = _dense(variant, h, w, b, relu=(i + 1 < n))
        return h

    def per_item_nll_and_pred(self, params, x, y, variant):
        lg = self.logits(params, x, variant)
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, y, axis=-1)[:, 0]
        pred = jnp.argmax(lg, axis=-1)
        return nll, pred, y[:, 0]


# --------------------------------------------------------------------------
# CNNs (the paper's MNIST / CIFAR-10 workloads, §7.1)
# --------------------------------------------------------------------------


class Cnn:
    """conv(3x3, same) -> relu -> maxpool2, twice; then dense head.

    Input is the flat planar image (C*H*W), reshaped to NCHW. In the pallas
    variant convolutions run as im2col + the L1 matmul kernel (conv ->
    MXU-shaped GEMM), dense layers via the same kernel.
    """

    kind = "cnn"

    def __init__(self, name, channels, side, conv_ch: List[int], hidden: int, classes: int):
        self.name = name
        self.c, self.side = channels, side
        self.conv_ch = conv_ch
        self.hidden = hidden
        self.classes = classes
        self.x_dim = channels * side * side
        self.y_dim = 1
        side_out = side // (2 ** len(conv_ch))
        self.flat_dim = conv_ch[-1] * side_out * side_out
        self.layers = []
        ic = channels
        for i, oc in enumerate(conv_ch):
            rf = ic * 9
            self.layers.append(glorot(f"conv{i}_w", (oc, ic, 3, 3), rf, oc * 9))
            self.layers.append(zeros(f"conv{i}_b", (oc,)))
            ic = oc
        self.layers.append(glorot("fc0_w", (self.flat_dim, hidden), self.flat_dim, hidden))
        self.layers.append(zeros("fc0_b", (hidden,)))
        self.layers.append(glorot("fc1_w", (hidden, classes), hidden, classes))
        self.layers.append(zeros("fc1_b", (classes,)))

    def _conv(self, variant, x, w, b):
        """x: [B, C, H, W]; w: [OC, IC, 3, 3]. 'same' padding."""
        if variant == "pallas":
            b_, c, h, wd = x.shape
            oc = w.shape[0]
            patches = jax.lax.conv_general_dilated_patches(
                x,
                filter_shape=(3, 3),
                window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )  # [B, C*9, H, W]
            cols = patches.transpose(0, 2, 3, 1).reshape(b_ * h * wd, c * 9)
            wmat = w.reshape(oc, c * 9).T  # [C*9, OC]
            out = pallas_matmul.dense(cols, wmat, b, relu=False)
            return out.reshape(b_, h, wd, oc).transpose(0, 3, 1, 2)
        out = jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=(1, 1),
            padding="SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        return out + b[None, :, None, None]

    @staticmethod
    def _pool2(x):
        return jax.lax.reduce_window(
            x,
            -jnp.inf,
            jax.lax.max,
            window_dimensions=(1, 1, 2, 2),
            window_strides=(1, 1, 2, 2),
            padding="VALID",
        )

    def logits(self, params, x, variant):
        ts = unpack(params, self.layers)
        b = x.shape[0]
        h = x.reshape(b, self.c, self.side, self.side)
        idx = 0
        for _ in self.conv_ch:
            h = self._conv(variant, h, ts[idx], ts[idx + 1])
            idx += 2
            h = jnp.maximum(h, 0.0)
            h = self._pool2(h)
        h = h.reshape(b, self.flat_dim)
        h = _dense(variant, h, ts[idx], ts[idx + 1], relu=True)
        h = _dense(variant, h, ts[idx + 2], ts[idx + 3], relu=False)
        return h

    per_item_nll_and_pred = Mlp.per_item_nll_and_pred


# --------------------------------------------------------------------------
# Decoder-only transformer LM (the end-to-end driver workload)
# --------------------------------------------------------------------------


class Transformer:
    """Pre-LN causal transformer; tied-free head; NLL over all positions.

    x arrives as f32 token ids [B, S] (the runtime's uniform f32 feature
    interface) and is cast to int for the embedding gather.
    """

    kind = "transformer"

    def __init__(self, name, vocab, seq_len, d_model, heads, depth, d_ff=None):
        assert d_model % heads == 0
        self.name = name
        self.vocab = vocab
        self.seq_len = seq_len
        self.d = d_model
        self.heads = heads
        self.depth = depth
        self.d_ff = d_ff or 4 * d_model
        self.x_dim = seq_len
        self.y_dim = seq_len
        self.classes = vocab
        self.layers = [
            normal("embed", (vocab, d_model), 0.02),
            normal("pos", (seq_len, d_model), 0.02),
        ]
        for l in range(depth):
            p = f"blk{l}_"
            self.layers += [
                ones(p + "ln1_g", (d_model,)),
                zeros(p + "ln1_b", (d_model,)),
                glorot(p + "wq", (d_model, d_model), d_model, d_model),
                glorot(p + "wk", (d_model, d_model), d_model, d_model),
                glorot(p + "wv", (d_model, d_model), d_model, d_model),
                glorot(p + "wo", (d_model, d_model), d_model, d_model),
                ones(p + "ln2_g", (d_model,)),
                zeros(p + "ln2_b", (d_model,)),
                glorot(p + "w1", (d_model, self.d_ff), d_model, self.d_ff),
                zeros(p + "b1", (self.d_ff,)),
                glorot(p + "w2", (self.d_ff, d_model), self.d_ff, d_model),
                zeros(p + "b2", (d_model,)),
            ]
        self.layers += [
            ones("lnf_g", (d_model,)),
            zeros("lnf_b", (d_model,)),
            glorot("head_w", (d_model, vocab), d_model, vocab),
            zeros("head_b", (vocab,)),
        ]

    @staticmethod
    def _ln(x, g, b):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) / jnp.sqrt(var + 1e-5) * g + b

    def _dense2(self, variant, x, w, b, relu=False):
        """Dense over the last axis of a [B, S, d] tensor."""
        b_, s, din = x.shape
        y = _dense(variant, x.reshape(b_ * s, din), w, b, relu)
        return y.reshape(b_, s, w.shape[1])

    def logits(self, params, x, variant):
        ts = unpack(params, self.layers)
        it = iter(ts)
        embed, pos = next(it), next(it)
        b, s = x.shape
        ids = x.astype(jnp.int32)
        h = embed[ids] + pos[None, :s, :]
        mask = jnp.tril(jnp.ones((s, s), jnp.float32))
        for _ in range(self.depth):
            ln1_g, ln1_b = next(it), next(it)
            wq, wk, wv, wo = next(it), next(it), next(it), next(it)
            ln2_g, ln2_b = next(it), next(it)
            w1, b1, w2, b2 = next(it), next(it), next(it), next(it)
            zb = jnp.zeros((self.d,), jnp.float32)
            zf = jnp.zeros((self.d_ff,), jnp.float32)
            a_in = self._ln(h, ln1_g, ln1_b)
            q = self._dense2(variant, a_in, wq, zb)
            k = self._dense2(variant, a_in, wk, zb)
            v = self._dense2(variant, a_in, wv, zb)
            hd = self.d // self.heads
            q = q.reshape(b, s, self.heads, hd).transpose(0, 2, 1, 3)
            k = k.reshape(b, s, self.heads, hd).transpose(0, 2, 1, 3)
            v = v.reshape(b, s, self.heads, hd).transpose(0, 2, 1, 3)
            att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
            att = jnp.where(mask[None, None] > 0, att, -1e30)
            att = jax.nn.softmax(att, axis=-1)
            ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
            ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, self.d)
            h = h + self._dense2(variant, ctx, wo, zb)
            m_in = self._ln(h, ln2_g, ln2_b)
            m = self._dense2(variant, m_in, w1, b1, relu=True)
            _ = zf
            h = h + self._dense2(variant, m, w2, b2)
        lnf_g, lnf_b = next(it), next(it)
        head_w, head_b = next(it), next(it)
        h = self._ln(h, lnf_g, lnf_b)
        return self._dense2(variant, h, head_w, head_b)  # [B, S, V]

    def per_item_nll_and_pred(self, params, x, y, variant):
        lg = self.logits(params, x, variant)  # [B, S, V]
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, y[..., None], axis=-1)[..., 0]  # [B, S]
        pred = jnp.argmax(lg, axis=-1)
        return nll.reshape(-1), pred.reshape(-1), y.reshape(-1)


# --------------------------------------------------------------------------
# The grad / eval graphs shared by every model
# --------------------------------------------------------------------------


def param_count(model) -> int:
    return sum(s.size for s in model.layers)


def make_loss(model, variant: str) -> Callable:
    def loss_fn(params, x, y):
        nll, _, _ = model.per_item_nll_and_pred(params, x, y, variant)
        return jnp.mean(nll)

    return loss_fn


def make_grad(model, variant: str) -> Callable:
    """(params, x, y) -> (loss, grads) — the worker hot-path graph."""
    loss_fn = make_loss(model, variant)

    def grad_fn(params, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        return loss, grads

    return grad_fn


def make_eval(model, variant: str) -> Callable:
    """(params, x, y) -> (sum_nll, correct_count) over all label items."""

    def eval_fn(params, x, y):
        nll, pred, target = model.per_item_nll_and_pred(params, x, y, variant)
        sum_loss = jnp.sum(nll)
        correct = jnp.sum((pred == target).astype(jnp.float32))
        return sum_loss, correct

    return eval_fn


# --------------------------------------------------------------------------
# The model zoo (names referenced by aot.py and the Rust manifest)
# --------------------------------------------------------------------------


def build(name: str):
    if name == "mlp":
        # The paper's random-dataset model: 20-dim, 10 classes.
        return Mlp("mlp", [20, 64, 64, 10])
    if name == "cnn_mnist":
        return Cnn("cnn_mnist", channels=1, side=28, conv_ch=[8, 16], hidden=64, classes=10)
    if name == "cnn_cifar":
        return Cnn("cnn_cifar", channels=3, side=32, conv_ch=[16, 32], hidden=64, classes=10)
    if name == "transformer":
        return Transformer("transformer", vocab=64, seq_len=64, d_model=64, heads=4, depth=2)
    raise ValueError(f"unknown model {name!r}")


MODEL_NAMES = ["mlp", "cnn_mnist", "cnn_cifar", "transformer"]
