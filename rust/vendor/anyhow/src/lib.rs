//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container image carries no crates.io registry, so this path
//! dependency implements exactly the subset of `anyhow` the workspace uses:
//! [`Result`], [`Error`] (with `?`-conversion from any std error type),
//! and the `anyhow!` / `bail!` / `ensure!` macros in their
//! format-string forms. Swapping this for the real crate is a one-line
//! change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with a defaultable error type, like anyhow's.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed dynamic error. Deliberately does **not** implement
/// `std::error::Error` so the blanket `From<E>` below cannot overlap the
/// reflexive `From<Error> for Error`.
pub struct Error(Box<dyn StdError + Send + Sync + 'static>);

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + Send + Sync + 'static,
    {
        Error(Box::new(MessageError(message)))
    }

    /// Construct from a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// The root cause chain, starting at this error.
    pub fn chain(&self) -> Chain<'_> {
        Chain {
            next: Some(self.0.as_ref()),
        }
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)?;
        // `{:#}` renders the source chain inline, like anyhow.
        if f.alternate() {
            let mut source = self.0.source();
            while let Some(cause) = source {
                write!(f, ": {cause}")?;
                source = cause.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

/// Iterator over an error's cause chain (subset of anyhow's `Chain`).
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let current = self.next?;
        self.next = current.source();
        Some(current)
    }
}

struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Display> StdError for MessageError<M> {}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    fn checked(n: usize) -> Result<usize> {
        ensure!(n < 10, "n too big: {n}");
        if n == 7 {
            bail!("seven is right out");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn macros_format_messages() {
        let e = anyhow!("bad value `{}`", 42);
        assert_eq!(e.to_string(), "bad value `42`");
        assert_eq!(checked(3).unwrap(), 3);
        assert!(checked(12).unwrap_err().to_string().contains("too big"));
        assert!(checked(7).unwrap_err().to_string().contains("seven"));
    }

    #[test]
    fn alternate_display_and_debug_render() {
        let e = anyhow!("top level");
        assert_eq!(format!("{e:#}"), "top level");
        assert!(format!("{e:?}").contains("top level"));
        assert_eq!(e.chain().count(), 1);
    }
}
