//! Offline API stub for the `xla` (PJRT) crate.
//!
//! The offline image cannot carry the real PJRT dependency closure, so this
//! stub mirrors the API surface `hybrid-sgd`'s runtime layer compiles
//! against and fails at *runtime* with a clear message. A deployment with
//! the real crate replaces the `xla` path dependency in `rust/Cargo.toml`;
//! no source changes are needed.

// Stub types are deliberately never constructed on the offline path.
#![allow(dead_code)]

use std::fmt;
use std::rc::Rc;

/// Error type mirroring the real crate's (Display + std::error::Error).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built against the offline `xla` \
         stub; swap rust/vendor/xla for the real crate to run AOT artifacts)"
    ))
}

/// Element types the runtime layer allocates literals for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Parsed HLO module (stub: parsing always fails — no artifacts offline).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parse HLO `{path}`")))
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. `Rc` keeps the stub `!Send`, matching the real
/// crate's threading contract (engines are built inside worker threads).
pub struct PjRtClient(Rc<()>);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("create PJRT CPU client"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile executable"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(Rc<()>);

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// Device buffer returned by `execute`.
pub struct PjRtBuffer(Rc<()>);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("download buffer"))
    }
}

/// Host literal (input/output tensor).
pub struct Literal {
    len: usize,
}

impl Literal {
    pub fn create_from_shape(_ty: PrimitiveType, dims: &[usize]) -> Literal {
        Literal {
            len: dims.iter().product(),
        }
    }

    pub fn copy_raw_from<T: Copy>(&mut self, src: &[T]) -> Result<()> {
        let _ = src;
        Err(unavailable("upload literal"))
    }

    pub fn copy_raw_to<T: Copy>(&self, dst: &mut [T]) -> Result<()> {
        let _ = dst;
        Err(unavailable("download literal"))
    }

    pub fn get_first_element<T: Copy + Default>(&self) -> Result<T> {
        Err(unavailable("read literal element"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("destructure 1-tuple"))
    }

    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        Err(unavailable("destructure 2-tuple"))
    }

    pub fn element_count(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_clear_errors() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline `xla` stub"));
        let lit = Literal::create_from_shape(PrimitiveType::F32, &[2, 3]);
        assert_eq!(lit.element_count(), 6);
    }
}
