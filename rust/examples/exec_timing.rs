use hybrid_sgd::engine::GradEngine;
use hybrid_sgd::runtime::{init_params, Manifest, XlaEngine};
use hybrid_sgd::util::rng::Pcg64;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let man = Manifest::load("artifacts")?;
    for (model, batch, xd) in [("mlp", 32usize, 20usize), ("cnn_mnist", 32, 784), ("cnn_cifar", 32, 3072), ("transformer", 8, 64)] {
        let mut rng = Pcg64::seeded(1);
        let entry = man.model(model)?;
        let params = init_params(entry, &mut rng)?;
        let mut eng = XlaEngine::new(&man, model, Some(batch), "jnp", false)?;
        let mut x = vec![0.1f32; batch * xd];
        rng.fill_normal(&mut x, 1.0);
        if model == "transformer" { for v in x.iter_mut() { *v = (v.abs() * 60.0).min(63.0).floor(); } }
        let ydim = if model == "transformer" { 64 } else { 1 };
        let y: Vec<i32> = (0..batch * ydim).map(|i| (i % 10) as i32).collect();
        let mut g = vec![0.0f32; params.len()];
        eng.grad(&params, &x, &y, &mut g)?; // warmup
        let t0 = Instant::now();
        let n = 20;
        for _ in 0..n { eng.grad(&params, &x, &y, &mut g)?; }
        println!("{model:<12} b{batch}: {:.2} ms/grad", t0.elapsed().as_secs_f64() * 1000.0 / n as f64);
    }
    Ok(())
}
