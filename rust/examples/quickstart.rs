//! Quickstart: train a small MLP on the paper's random-cluster dataset with
//! the smooth-switch hybrid parameter server, through the full AOT/XLA
//! stack, and print the learning curve.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --secs F --workers N --shards S --policy <async|sync|hybrid:step:133>

use hybrid_sgd::coordinator::{train, DelayModel, EvalSet, Policy, RunInputs, Schedule, TrainConfig};
use hybrid_sgd::data::{random_cluster, Batcher};
use hybrid_sgd::runtime::{default_artifact_dir, engine_factories, init_params, Manifest};
use hybrid_sgd::util::cli::Args;
use hybrid_sgd::util::plot::{render, Curve};
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let secs = args.f64_or("secs", 8.0);
    let workers = args.usize_or("workers", 6);
    let policy = Policy::parse(&args.str_or("policy", "hybrid:step:150"))?;

    // 1. Data: the paper's random 20-dim 10-class Gaussian clusters.
    let mut rng = Pcg64::seeded(7);
    let spec = random_cluster::ClusterSpec::default(); // 10k samples
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);
    println!(
        "dataset: {} train / {} test, {} dims, {} classes",
        train_set.len(),
        test_set.len(),
        train_set.dim,
        train_set.classes
    );

    // 2. Engines: AOT-compiled XLA executables (built by `make artifacts`).
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let init = init_params(manifest.model("mlp")?, &mut rng)?;
    let (worker_engine, eval_engine) = engine_factories(&dir, "mlp", 32, "jnp")?;

    // 3. Wire up the parameter server run.
    let test = EvalSet::from_dataset(&test_set, 500, &mut rng);
    let probe = EvalSet::from_dataset(&train_set, 500, &mut rng);
    let train_arc = Arc::new(train_set);
    let shards = train_arc.shard_indices(workers);
    let inputs = RunInputs {
        worker_engine,
        eval_engine,
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                shards[id].clone(),
                32,
                Pcg64::new(1234, id as u64),
            )) as Box<dyn hybrid_sgd::coordinator::worker::BatchSource>
        }),
        init_params: &init,
        test: &test,
        train_probe: &probe,
    };
    let cfg = TrainConfig {
        policy,
        workers,
        lr: 0.01,
        duration: Duration::from_secs_f64(secs),
        delay: DelayModel::paper_default(),
        seed: 7,
        eval_interval: Duration::from_millis(400),
        k_max: None,
        compute_floor: Duration::from_millis(20),
        shards: args.usize_or("shards", 1),
        wire: hybrid_sgd::coordinator::WireFormat::parse(&args.str_or("compress", "dense"))
            .expect("bad --compress (dense | topk:<k|frac> | int8 | topk+int8:<k|frac>)"),
        steps: None,
        elastic: false,
        min_quorum: 1,
        stream: None,
        aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
        partition: hybrid_sgd::data::Partition::Iid,
        trace: None,
        param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
    };
    let _ = Schedule::Step { step: 1 }; // (see threshold.rs for all schedules)

    // 4. Train and report.
    let m = train(&cfg, &inputs)?;
    println!(
        "\n{} gradients, {} updates, {} flushes, {:.1} grads/s, mean staleness {:.2}",
        m.gradients_total,
        m.updates_total,
        m.flushes,
        m.grads_per_sec(),
        m.mean_staleness
    );
    println!(
        "{}",
        render(
            "test accuracy (%)",
            &[Curve {
                label: "hybrid",
                t: &m.test_acc.t,
                v: &m.test_acc.v,
            }],
            64,
            12
        )
    );
    if let Some((tr, te, acc)) = m.final_metrics() {
        println!("final: train loss {tr:.4}, test loss {te:.4}, test acc {acc:.2}%");
    }
    Ok(())
}
