//! Reproduce the paper's MNIST experiment (§7.1, Table 1 / Figures 4-5):
//! train the CNN under all three aggregation policies from identical
//! initialisation and print the metric curves + interval-mean differences.
//!
//!     cargo run --release --example mnist_compare -- --secs 12 --rounds 1

use hybrid_sgd::experiments::config::{DatasetKind, ExpConfig};
use hybrid_sgd::experiments::figures::comparison_charts;
use hybrid_sgd::experiments::runner::{run_comparison, Algo};
use hybrid_sgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let mut cfg = ExpConfig::default_for(DatasetKind::Mnist);
    cfg.secs = args.f64_or("secs", cfg.secs);
    cfg.rounds = args.usize_or("rounds", 1);
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.batch = args.usize_or("batch", cfg.batch);
    cfg.step_mult = args.f64_or("step-mult", 3.0); // paper: step 300

    println!(
        "MNIST comparison: {} workers, batch {}, schedule {}, {}s x {} rounds",
        cfg.workers,
        cfg.batch,
        cfg.schedule(),
        cfg.secs,
        cfg.rounds
    );
    let cmp = run_comparison(&cfg)?;
    println!("{}", comparison_charts("MNIST (synthetic)", &cmp));

    let d = cmp.diff_vs(Algo::Async)?;
    println!("hybrid − async, averaged over the training interval:");
    println!("  test accuracy : {:+.3}   (paper Table 1 @(300,32): +1.374)", d.test_acc);
    println!("  test loss     : {:+.3}   (paper: -0.047)", d.test_loss);
    println!("  train loss    : {:+.3}   (paper: -0.047)", d.train_loss);
    for (algo, avg) in &cmp.averaged {
        println!(
            "  {:<7} final acc {:>6.2}%  ({:.1} grads/s, staleness {:.2})",
            algo.name(),
            avg.test_acc.last().copied().unwrap_or(f64::NAN),
            avg.grads_per_sec,
            avg.mean_staleness
        );
    }
    Ok(())
}
