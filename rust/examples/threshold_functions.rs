//! Paper §9 (future work): "Different monotonically increasing functions can
//! also be used to see if all such functions can be straightaway plugged in
//! without much change in performance."
//!
//! This example plugs every [`Schedule`] the framework implements into the
//! hybrid policy and compares them on the random-cluster workload under
//! identical initialisation.
//!
//!     cargo run --release --example threshold_functions -- --secs 8

use hybrid_sgd::coordinator::{
    train, DelayModel, EvalSet, Policy, RunInputs, Schedule, TrainConfig,
};
use hybrid_sgd::data::{random_cluster, Batcher};
use hybrid_sgd::runtime::{default_artifact_dir, engine_factories, init_params, Manifest};
use hybrid_sgd::util::cli::Args;
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let secs = args.f64_or("secs", 8.0);
    let workers = args.usize_or("workers", 6);

    // Schedules tuned to reach K = workers around the same point of the run
    // (~1600 expected arrivals at 200/s x 8 s).
    let schedules: Vec<(&str, Schedule)> = vec![
        ("step (paper)", Schedule::Step { step: 150 }),
        ("linear", Schedule::Linear { rate: 1.0 / 150.0 }),
        (
            "exponential",
            Schedule::Exponential {
                step: 350,
                growth: 2.0,
            },
        ),
        (
            "sigmoid",
            Schedule::Sigmoid {
                mid: 700.0,
                scale: 180.0,
            },
        ),
        ("constant k=1 (async)", Schedule::Constant { k: 1 }),
    ];

    let mut rng = Pcg64::seeded(21);
    let spec = random_cluster::ClusterSpec::default();
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let init = init_params(manifest.model("mlp")?, &mut rng)?;
    let test = EvalSet::from_dataset(&test_set, 500, &mut rng);
    let probe = EvalSet::from_dataset(&train_set, 500, &mut rng);
    let train_arc = Arc::new(train_set);

    println!("schedule comparison on the random dataset ({secs}s each, identical init):\n");
    let mut results = Vec::new();
    for (name, schedule) in schedules {
        let (worker_engine, eval_engine) = engine_factories(&dir, "mlp", 32, "jnp")?;
        let shards = train_arc.shard_indices(workers);
        let train_arc2 = Arc::clone(&train_arc);
        let inputs = RunInputs {
            worker_engine,
            eval_engine,
            batch_source: Arc::new(move |id| {
                Box::new(Batcher::new(
                    Arc::clone(&train_arc2),
                    shards[id].clone(),
                    32,
                    Pcg64::new(5555, id as u64),
                )) as Box<dyn hybrid_sgd::coordinator::worker::BatchSource>
            }),
            init_params: &init,
            test: &test,
            train_probe: &probe,
        };
        let cfg = TrainConfig {
            policy: Policy::Hybrid {
                schedule: schedule.clone(),
                strict: false,
            },
            workers,
            lr: 0.01,
            duration: Duration::from_secs_f64(secs),
            delay: DelayModel::paper_default(),
            seed: 21,
            eval_interval: Duration::from_millis(400),
            k_max: None,
            compute_floor: Duration::from_millis(20),
            shards: args.usize_or("shards", 1),
            wire: hybrid_sgd::coordinator::WireFormat::Dense,
            steps: None,
            elastic: false,
            min_quorum: 1,
            stream: None,
            aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
            partition: hybrid_sgd::data::Partition::Iid,
            trace: None,
            param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
        };
        let m = train(&cfg, &inputs)?;
        let (tr, te, acc) = m.final_metrics().unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        println!(
            "  {name:<22} final acc {acc:>6.2}%  test loss {te:.4}  train loss {tr:.4}  ({} updates, {} flushes)",
            m.updates_total, m.flushes
        );
        results.push((name, acc));
    }

    let (best, best_acc) = results
        .iter()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    let (worst, worst_acc) = results
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!(
        "\nbest: {best} ({best_acc:.2}%), worst: {worst} ({worst_acc:.2}%) — \
         if the monotone schedules cluster together (and above async), §9's \
         conjecture holds on this workload"
    );
    Ok(())
}
