//! End-to-end driver (mandated validation): train a transformer LM through
//! the full three-layer stack — Rust hybrid parameter server → AOT XLA
//! executable → JAX/Pallas-authored fwd/bwd — for a few hundred steps on a
//! synthetic corpus, and log the loss curve.
//!
//! The model is a ~112k-parameter decoder-only char-LM (vocab 64, d=64,
//! 2 layers, 4 heads, seq 64) — scaled to this single-core container from
//! the "~100M transformer" reference point; the *system path* exercised is
//! identical at any scale (DESIGN.md §1.6).
//!
//!     cargo run --release --example train_transformer -- --steps 300

use hybrid_sgd::coordinator::worker::TokenBatchSource;
use hybrid_sgd::coordinator::{train, DelayModel, EvalSet, Policy, RunInputs, Schedule, TrainConfig};
use hybrid_sgd::data::tokens::{generate, CorpusSpec, TokenBatcher};
use hybrid_sgd::runtime::{default_artifact_dir, engine_factories, init_params, Manifest};
use hybrid_sgd::util::cli::Args;
use hybrid_sgd::util::plot::{render, Curve};
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let steps = args.usize_or("steps", 300);
    let workers = args.usize_or("workers", 3);
    let batch = 8; // matches the transformer_grad_b8 artifact

    // 1. Synthetic corpus: first-order Markov source + memorised phrases.
    let mut rng = Pcg64::seeded(99);
    let spec = CorpusSpec::default(); // vocab 64, 200k tokens, seq 64
    let corpus = Arc::new(generate(&spec, &mut rng));
    let (train_windows, test_windows) = corpus.split_windows(0.9, &mut rng);
    println!(
        "corpus: {} tokens, vocab {}, {} train windows / {} test",
        corpus.tokens.len(),
        corpus.vocab,
        train_windows.len(),
        test_windows.len()
    );

    // 2. AOT transformer engine.
    let dir = default_artifact_dir();
    let manifest = Manifest::load(&dir)?;
    let entry = manifest.model("transformer")?;
    println!("model: {} parameters (decoder-only, d=64, 2 layers)", entry.param_count);
    let init = init_params(entry, &mut rng)?;
    let (worker_engine, eval_engine) = engine_factories(&dir, "transformer", batch, "jnp")?;

    // 3. Token eval sets (per-token loss + next-token accuracy).
    let test = EvalSet::from_tokens(&corpus, &test_windows, 64);
    let probe = EvalSet::from_tokens(&corpus, &train_windows, 64);

    // 4. Budget: ~steps gradients at the measured ~15 ms/grad (all workers
    //    share one core, so total throughput is core-bound at ~25-50 grads/s)
    //    plus a compile allowance: each worker thread compiles its own PJRT
    //    executable at startup (~3 s each, sequential on one core).
    let est_rate = 25.0; // grads/s, conservative single-core estimate
    let compile_allowance = 4.0 * (workers as f64 + 1.0);
    let secs = args.f64_or("secs", steps as f64 / est_rate + compile_allowance);
    let train_windows = Arc::new(train_windows);
    let corpus2 = Arc::clone(&corpus);
    let tw = Arc::clone(&train_windows);
    let inputs = RunInputs {
        worker_engine,
        eval_engine,
        batch_source: Arc::new(move |id| {
            let shard: Vec<usize> = tw
                .iter()
                .copied()
                .skip(id)
                .step_by(workers)
                .collect();
            Box::new(TokenBatchSource::new(
                TokenBatcher::new(Arc::clone(&corpus2), shard, batch, Pcg64::new(7, id as u64)),
                batch,
                corpus2.seq_len,
            )) as Box<dyn hybrid_sgd::coordinator::worker::BatchSource>
        }),
        init_params: &init,
        test: &test,
        train_probe: &probe,
    };
    let cfg = TrainConfig {
        policy: Policy::Hybrid {
            schedule: Schedule::Step {
                step: (steps / workers).max(1),
            },
            strict: false,
        },
        workers,
        lr: args.f64_or("lr", 0.25) as f32, // plain SGD on a tiny LM needs a hot lr
        duration: Duration::from_secs_f64(secs),
        delay: DelayModel::none(),
        seed: 99,
        eval_interval: Duration::from_secs_f64((secs / 20.0).max(1.0)),
        k_max: None,
        compute_floor: Duration::ZERO,
        shards: args.usize_or("shards", 1),
        wire: hybrid_sgd::coordinator::WireFormat::Dense,
        steps: None,
        elastic: false,
        min_quorum: 1,
        stream: None,
        aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
        partition: hybrid_sgd::data::Partition::Iid,
        trace: None,
        param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
    };

    println!("training for ~{secs:.0}s (~{steps} gradient steps) ...\n");
    let m = train(&cfg, &inputs)?;

    println!(
        "{}",
        render(
            "transformer LM — train loss (nats/token)",
            &[
                Curve {
                    label: "train",
                    t: &m.train_loss.t,
                    v: &m.train_loss.v,
                },
                Curve {
                    label: "test",
                    t: &m.test_loss.t,
                    v: &m.test_loss.v,
                },
            ],
            64,
            14
        )
    );
    let first = m.train_loss.v.first().copied().unwrap_or(f64::NAN);
    let last = m.train_loss.v.last().copied().unwrap_or(f64::NAN);
    let acc = m.test_acc.v.last().copied().unwrap_or(f64::NAN);
    println!("gradients      : {}", m.gradients_total);
    println!("updates        : {}", m.updates_total);
    println!("loss           : {first:.3} → {last:.3} nats/token (ln V = {:.3})", (64f64).ln());
    println!("next-token acc : {acc:.1}%");
    println!("\nloss-curve samples (t, train, test):");
    for i in (0..m.train_loss.len()).step_by(2) {
        println!(
            "  {:6.1}s  {:.4}  {:.4}",
            m.train_loss.t[i], m.train_loss.v[i], m.test_loss.v[i]
        );
    }
    anyhow::ensure!(
        last < first - 0.3,
        "loss did not fall meaningfully ({first:.3} → {last:.3})"
    );
    println!("\ne2e OK: the full PS→PJRT→JAX/Pallas path trains the LM.");
    Ok(())
}
