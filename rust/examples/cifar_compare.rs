//! Reproduce the paper's CIFAR-10 experiment (§7.1, Table 2 / Figures 6-7)
//! on the synthetic CIFAR lookalike — the paper's "harder optimisation
//! problem" where the hybrid's advantage is largest.
//!
//!     cargo run --release --example cifar_compare -- --secs 20 --rounds 1

use hybrid_sgd::experiments::config::{DatasetKind, ExpConfig};
use hybrid_sgd::experiments::figures::comparison_charts;
use hybrid_sgd::experiments::runner::{run_comparison, Algo};
use hybrid_sgd::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let mut cfg = ExpConfig::default_for(DatasetKind::Cifar);
    cfg.secs = args.f64_or("secs", cfg.secs);
    cfg.rounds = args.usize_or("rounds", 1);
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.batch = args.usize_or("batch", cfg.batch);
    cfg.step_mult = args.f64_or("step-mult", 3.0);

    println!(
        "CIFAR-10 comparison: {} workers, batch {}, schedule {}, {}s x {} rounds",
        cfg.workers,
        cfg.batch,
        cfg.schedule(),
        cfg.secs,
        cfg.rounds
    );
    let cmp = run_comparison(&cfg)?;
    println!("{}", comparison_charts("CIFAR-10 (synthetic)", &cmp));

    let d = cmp.diff_vs(Algo::Async)?;
    println!("hybrid − async, averaged over the training interval:");
    println!("  test accuracy : {:+.3}   (paper Table 2 @(300,32): +4.849)", d.test_acc);
    println!("  test loss     : {:+.3}   (paper: -0.137)", d.test_loss);
    println!("  train loss    : {:+.3}   (paper: -0.139)", d.train_loss);
    for (algo, avg) in &cmp.averaged {
        println!(
            "  {:<7} final acc {:>6.2}%  ({:.1} grads/s, {:.0} updates)",
            algo.name(),
            avg.test_acc.last().copied().unwrap_or(f64::NAN),
            avg.grads_per_sec,
            avg.updates_total
        );
    }
    Ok(())
}
