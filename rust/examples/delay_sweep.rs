//! Reproduce the paper's communication-delay robustness experiment (§7.4,
//! Table 5 / Figure 10): sweep the delay σ and report hybrid − async.
//!
//!     cargo run --release --example delay_sweep -- --stds 0.25,0.75,1.25 --secs 8

use hybrid_sgd::coordinator::DelayModel;
use hybrid_sgd::experiments::config::{DatasetKind, ExpConfig};
use hybrid_sgd::experiments::runner::{run_comparison_algos, Algo};
use hybrid_sgd::util::cli::Args;
use hybrid_sgd::util::plot::bars;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(false);
    let stds = args.f64_list("stds", &[0.25, 0.5, 0.75, 1.0, 1.25]);
    let paper = [3.915, 1.920, 3.012, 2.879, 5.184];

    let mut items = Vec::new();
    for (i, &std) in stds.iter().enumerate() {
        let mut cfg = ExpConfig::default_for(DatasetKind::Random);
        cfg.secs = args.f64_or("secs", cfg.secs);
        cfg.rounds = args.usize_or("rounds", 1);
        cfg.workers = args.usize_or("workers", cfg.workers);
        cfg.delay = DelayModel::paper_default().with_std(std);
        cfg.seed = 42 + (std * 100.0) as u64;
        let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async])?;
        let d = cmp.diff_vs(Algo::Async)?;
        println!(
            "σ = {std:<5}: Δacc {:+.3} (paper {:+.3}), Δtest-loss {:+.3}, Δtrain-loss {:+.3}",
            d.test_acc,
            paper.get(i).copied().unwrap_or(f64::NAN),
            d.test_loss,
            d.train_loss
        );
        items.push((format!("σ={std}"), d.test_acc));
    }
    println!(
        "\n{}",
        bars("Δ test accuracy (hybrid − async) vs delay σ — Figure 10", &items, 40)
    );
    let wins = items.iter().filter(|(_, v)| *v > 0.0).count();
    println!(
        "hybrid outperformed async at {wins}/{} delay levels (paper: 5/5)",
        items.len()
    );
    Ok(())
}
