//! L3 hot-path micro-benchmarks: the parameter-server operations that run
//! once per gradient arrival. Targets (DESIGN.md §7): PS cost ≪ grad
//! latency (≥ ~0.2 ms), no allocation in the per-gradient loop.
//!
//!     cargo bench --bench bench_hotpath          # full
//!     BENCH_QUICK=1 cargo bench ...              # smoke

use hybrid_sgd::coordinator::buffer::GradientBuffer;
use hybrid_sgd::coordinator::compress::{
    dequantize_i8, quantize_i8_into, GradView, QuantGrad, ShardGrad, SparseGrad, TopKCompressor,
};
use hybrid_sgd::coordinator::params::ParamStore;
use hybrid_sgd::coordinator::{Aggregator, Policy, Schedule, ShardedAggregator};
use hybrid_sgd::transport::frame::{decode_frame, encode_frame_into};
use hybrid_sgd::transport::loadgen::measure_conn_throughput;
use hybrid_sgd::transport::msg::{encode_submit_into, Msg};
use hybrid_sgd::transport::FrontendKind;
use hybrid_sgd::util::bench::{black_box, Bencher};
use hybrid_sgd::util::json::Json;
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

/// One wire-format case for the `BENCH_compress.json` baseline.
struct WireCase {
    name: String,
    dim: usize,
    ops_per_sec: f64,
    bytes_per_step: usize,
}

/// Compress / decompress / accumulate micro-benches for the gradient wire
/// formats at d ∈ {1e4, 1e5, 1e6}, plus the bytes-on-wire acceptance check:
/// top-k at 1% density must cut per-step bytes ≥ 50× vs dense f32.
fn bench_wire_formats(b: &mut Bencher) -> Vec<WireCase> {
    println!("\n== gradient wire formats: compress / decompress / accumulate ==");
    let mut cases = Vec::new();
    let mut record = |name: &str, dim: usize, mean_ns: f64, bytes: usize| {
        cases.push(WireCase {
            name: name.to_string(),
            dim,
            ops_per_sec: 1e9 / mean_ns,
            bytes_per_step: bytes,
        });
    };
    for &dim in &[10_000usize, 100_000, 1_000_000] {
        let mut rng = Pcg64::seeded(7);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);
        let k = dim / 100; // 1% density
        let dense_bytes = dim * 4;

        // dense baseline: the accumulate the PS always ran
        let mut buf = GradientBuffer::new(dim, 8);
        let r = b.bench(&format!("dense accumulate d={dim}"), || {
            buf.push(black_box(&grad), 0, 0, 0);
            if buf.len() >= 64 {
                buf.clear();
            }
        });
        record("dense_accumulate", dim, r.mean_ns, dense_bytes);

        // top-k 1%: allocation-free compress into a reused SparseGrad
        let mut comp = TopKCompressor::new(dim, k);
        let mut sg = SparseGrad::with_dim(dim);
        let r = b.bench(&format!("topk 1% compress d={dim}"), || {
            comp.compress_into(black_box(&grad), &mut sg);
        });
        let sparse_bytes = sg.payload_bytes();
        record("topk1pct_compress", dim, r.mean_ns, sparse_bytes);

        // sparse accumulate: O(nnz) scatter-add, never densified
        let mut buf2 = GradientBuffer::new(dim, 8);
        let r = b.bench(&format!("topk 1% accumulate d={dim}"), || {
            buf2.push_view(
                GradView::Sparse {
                    idx: black_box(&sg.idx),
                    val: &sg.val,
                },
                0,
                0,
                0,
            );
            if buf2.len() >= 64 {
                buf2.clear();
            }
        });
        record("topk1pct_accumulate", dim, r.mean_ns, sparse_bytes);

        // int8: quantize into a reused buffer; accumulate dequantizes on
        // the fly
        let mut q = QuantGrad::empty();
        let r = b.bench(&format!("int8 quantize d={dim}"), || {
            quantize_i8_into(black_box(&grad), &mut q);
        });
        record("int8_quantize", dim, r.mean_ns, q.payload_bytes());
        let mut buf3 = GradientBuffer::new(dim, 8);
        let r = b.bench(&format!("int8 accumulate d={dim}"), || {
            buf3.push_view(
                GradView::Quant {
                    scale: q.scale,
                    data: black_box(&q.data),
                },
                0,
                0,
                0,
            );
            if buf3.len() >= 64 {
                buf3.clear();
            }
        });
        record("int8_accumulate", dim, r.mean_ns, q.payload_bytes());
        let r = b.bench(&format!("int8 dequantize d={dim}"), || {
            black_box(dequantize_i8(&q));
        });
        record("int8_dequantize", dim, r.mean_ns, q.payload_bytes());

        // Acceptance: top-k at 1% density cuts per-step bytes ≥ 50×.
        assert!(
            dense_bytes >= 50 * sparse_bytes,
            "top-k@1% must reduce bytes-on-wire ≥ 50×: dense {dense_bytes} vs sparse {sparse_bytes}"
        );
        println!(
            "      bytes/step d={dim}: dense {dense_bytes}, topk1% {sparse_bytes} ({:.0}x), int8 {} ({:.1}x)",
            dense_bytes as f64 / sparse_bytes as f64,
            q.payload_bytes(),
            dense_bytes as f64 / q.payload_bytes() as f64,
        );
    }
    cases
}

/// Write the dense-vs-topk-vs-int8 ops/sec baseline when asked to
/// (`BENCH_COMPRESS_OUT=../BENCH_compress.json cargo bench --bench
/// bench_hotpath` — cargo runs bench binaries with cwd = the package root
/// `rust/`, so relative paths resolve from there).
fn write_compress_baseline(cases: &[WireCase]) {
    let Ok(path) = std::env::var("BENCH_COMPRESS_OUT") else {
        return;
    };
    let mut rows = Vec::new();
    for c in cases {
        rows.push(Json::from_pairs(vec![
            ("name", Json::Str(c.name.clone())),
            ("dim", Json::Num(c.dim as f64)),
            ("ops_per_sec", Json::Num(c.ops_per_sec)),
            ("bytes_per_step", Json::Num(c.bytes_per_step as f64)),
        ]));
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("bench_hotpath/wire_formats".to_string())),
        (
            "quick",
            Json::Bool(std::env::var("BENCH_QUICK").map_or(false, |v| v == "1")),
        ),
        ("cases", Json::Arr(rows)),
    ]);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

/// One frame-codec case for the `BENCH_transport.json` baseline.
struct TransportCase {
    name: String,
    payload_label: String,
    ops_per_sec: f64,
    bytes_per_frame: usize,
}

/// Frame codec throughput: encode+decode of one `SubmitGrad` frame at
/// payload sizes ≈ {800 B, 8 KB, 80 KB, 4 MB} for the dense / topk / int8
/// gradient formats (the transport satellite of ISSUE 4). Encode writes
/// into reused buffers; decode validates the CRC and rebuilds the
/// shard-local payload — the full per-message cost of the TCP path minus
/// the socket.
fn bench_transport_frames(b: &mut Bencher) -> Vec<TransportCase> {
    println!("\n== transport frame codec: SubmitGrad encode + decode ==");
    let mut cases: Vec<TransportCase> = Vec::new();
    // (label, dense dim, topk nnz, int8 len) targeting the payload sizes.
    let sizes: [(&str, usize, usize, usize); 4] = [
        ("800B", 200, 100, 800),
        ("8KB", 2_000, 1_000, 8_000),
        ("80KB", 20_000, 10_000, 80_000),
        ("4MB", 1_000_000, 500_000, 4_000_000),
    ];
    let mut rng = Pcg64::seeded(31);
    for (label, dense_n, nnz, int8_n) in sizes {
        let mut dense = vec![0.0f32; dense_n];
        rng.fill_normal(&mut dense, 1.0);
        let sparse = {
            let mut idx: Vec<u32> = (0..nnz as u32).collect();
            // spread the indices out like a real top-k selection
            for i in idx.iter_mut() {
                *i *= 2;
            }
            let mut val = vec![0.0f32; nnz];
            rng.fill_normal(&mut val, 1.0);
            SparseGrad {
                dim: nnz * 2,
                idx,
                val,
            }
        };
        let quant = QuantGrad {
            scale: 0.01,
            data: (0..int8_n).map(|i| (i % 251) as i8).collect(),
        };
        let payloads: [(&str, ShardGrad, usize); 3] = [
            ("dense", ShardGrad::Dense(Arc::new(dense)), dense_n),
            ("topk", ShardGrad::Sparse(Arc::new(sparse)), nnz * 2),
            ("int8", ShardGrad::Quant(Arc::new(quant)), int8_n),
        ];
        for (fmt, grad, shard_len) in payloads {
            let mut msg_buf = Vec::new();
            let mut frame_buf = Vec::new();
            encode_submit_into(0, 1, 2, 0.5, &grad, 0..shard_len, &mut msg_buf).unwrap();
            frame_buf.clear();
            encode_frame_into(&msg_buf, &mut frame_buf);
            let bytes_per_frame = frame_buf.len();
            let r = b.bench(&format!("frame encode {fmt} {label}"), || {
                encode_submit_into(0, 1, 2, 0.5, black_box(&grad), 0..shard_len, &mut msg_buf)
                    .unwrap();
                frame_buf.clear();
                encode_frame_into(&msg_buf, &mut frame_buf);
            });
            cases.push(TransportCase {
                name: format!("encode_{fmt}"),
                payload_label: label.to_string(),
                ops_per_sec: 1e9 / r.mean_ns,
                bytes_per_frame,
            });
            let r = b.bench(&format!("frame decode {fmt} {label}"), || {
                let (payload, _) = decode_frame(black_box(&frame_buf)).expect("valid frame");
                black_box(Msg::decode(payload).expect("valid message"));
            });
            cases.push(TransportCase {
                name: format!("decode_{fmt}"),
                payload_label: label.to_string(),
                ops_per_sec: 1e9 / r.mean_ns,
                bytes_per_frame,
            });
            // Sanity: decode reproduces the payload's view bitwise (cheap,
            // once per case — guards the bench itself against drift).
            let (payload, _) = decode_frame(&frame_buf).expect("valid frame");
            match Msg::decode(payload).expect("valid message") {
                Msg::SubmitGrad { grad: got, .. } => {
                    let mut want = vec![0.0f32; shard_len];
                    grad.view(0..shard_len).add_to(&mut want);
                    let mut have = vec![0.0f32; shard_len];
                    got.view(0..shard_len).add_to(&mut have);
                    assert!(
                        want.iter().zip(&have).all(|(a, c)| a.to_bits() == c.to_bits()),
                        "{fmt} {label}: frame roundtrip diverged"
                    );
                }
                other => panic!("unexpected decode: {other:?}"),
            }
        }
    }
    cases
}

/// One (frontend, connection-count) row of the scaling curve.
struct ConnCase {
    frontend: &'static str,
    conns: usize,
    ops_per_sec: f64,
    p99_ack_latency_us: f64,
}

/// Connections-vs-throughput: drive both serving frontends with N
/// pipelined clients (window 16, dense d=64 submissions against an
/// echo-ack shard stub) and record aggregate acks/sec plus p99 submit→ack
/// latency. This is the ISSUE 6 acceptance curve: the reactor must hold
/// throughput as connections grow while the thread-per-connection
/// baseline pays context-switch and per-thread-heartbeat costs.
fn bench_connection_scaling() -> Vec<ConnCase> {
    println!("\n== connections vs throughput: reactor vs threaded frontend ==");
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let counts: &[usize] = if quick { &[2, 8, 32] } else { &[2, 8, 32, 128] };
    let dur = if quick {
        Duration::from_millis(150)
    } else {
        Duration::from_secs(1)
    };
    let mut out = Vec::new();
    for &conns in counts {
        for (name, kind) in [
            ("reactor", FrontendKind::Reactor),
            ("threaded", FrontendKind::Threaded),
        ] {
            match measure_conn_throughput(kind, conns, 16, 64, dur) {
                Ok(r) => {
                    println!(
                        "  {name:>8} conns={conns:<4} {:>12.0} acks/s   p99 {:>8.1} µs",
                        r.ops_per_sec, r.p99_ack_latency_us
                    );
                    out.push(ConnCase {
                        frontend: name,
                        conns,
                        ops_per_sec: r.ops_per_sec,
                        p99_ack_latency_us: r.p99_ack_latency_us,
                    });
                }
                Err(e) => println!("  {name:>8} conns={conns:<4} skipped: {e}"),
            }
        }
    }
    out
}

/// Emit the transport baseline when asked
/// (`BENCH_TRANSPORT_OUT=../BENCH_transport.json cargo bench --bench
/// bench_hotpath`; cargo runs bench binaries with cwd = rust/).
fn write_transport_baseline(cases: &[TransportCase], conn_cases: &[ConnCase]) {
    let Ok(path) = std::env::var("BENCH_TRANSPORT_OUT") else {
        return;
    };
    let mut rows = Vec::new();
    for c in cases {
        rows.push(Json::from_pairs(vec![
            ("name", Json::Str(c.name.clone())),
            ("payload", Json::Str(c.payload_label.clone())),
            ("ops_per_sec", Json::Num(c.ops_per_sec)),
            ("bytes_per_frame", Json::Num(c.bytes_per_frame as f64)),
        ]));
    }
    let mut conn_rows = Vec::new();
    for c in conn_cases {
        conn_rows.push(Json::from_pairs(vec![
            ("frontend", Json::Str(c.frontend.to_string())),
            ("conns", Json::Num(c.conns as f64)),
            ("ops_per_sec", Json::Num(c.ops_per_sec)),
            ("p99_ack_latency_us", Json::Num(c.p99_ack_latency_us)),
        ]));
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("bench_hotpath/transport_frames".to_string())),
        (
            "quick",
            Json::Bool(std::env::var("BENCH_QUICK").map_or(false, |v| v == "1")),
        ),
        ("cases", Json::Arr(rows)),
        ("connections_vs_throughput", Json::Arr(conn_rows)),
    ]);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

/// One tracing-overhead case for the `BENCH_trace.json` baseline.
struct TraceOverheadCase {
    name: &'static str,
    ops_per_sec: f64,
    p50_ns: f64,
}

/// Tracing overhead on the submit hot path (the ISSUE 9 gate). Four
/// variants of the same per-arrival sequence — enqueue stamp probe,
/// async `on_gradient`, queue/apply span records — at the mid-size
/// model:
///
///   submit_plain         no trace plumbing at all (pre-tracing shape)
///   submit_trace_off     the shipped code shape with `trace = None`
///   submit_trace_ring    recording into the flight-recorder ring
///   submit_trace_export  recording while another thread drains/exports
///
/// The acceptance gate pins `submit_trace_off` within 2% of
/// `submit_plain` on p50 per gradient (relaxed to 10% under BENCH_QUICK,
/// whose 200 ms budget leaves real scheduler noise in a CI runner).
fn bench_trace_overhead(b: &mut Bencher) -> Vec<TraceOverheadCase> {
    use hybrid_sgd::util::trace::{chrome_trace_json, Stage, TraceRing};
    println!("\n== gradient-lifecycle tracing: submit-path overhead ==");
    let dim = 52_138;
    let mut rng = Pcg64::seeded(9);
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 1.0);
    let grad = Arc::new(grad);

    // One measured iteration = `BATCH` submit sequences, amortizing the
    // harness's per-iteration timer reads below the 2% gate.
    const BATCH: usize = 16;
    let case = |r: &hybrid_sgd::util::bench::BenchResult, name: &'static str| TraceOverheadCase {
        name,
        ops_per_sec: BATCH as f64 / r.mean_secs(),
        p50_ns: r.p50_ns / BATCH as f64,
    };
    let run = |b: &mut Bencher, name: &'static str, trace: Option<Arc<TraceRing>>| {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(Policy::Async, dim, 8);
        let mut w = 0usize;
        let mut seq = 0u64;
        let grad = Arc::clone(&grad);
        let r = b.bench(name, move || {
            for _ in 0..BATCH {
                // The exact shape the frontends and shards run: an
                // Option probe for the enqueue stamp, spans only when
                // a ring is installed.
                let enq = trace.as_ref().map_or(0, |tr| tr.real_now());
                let v = ps.version();
                agg.on_gradient(&mut ps, black_box(&grad), w % 8, v, 1.0);
                if let Some(tr) = &trace {
                    let now = tr.real_now();
                    tr.span(Stage::Queue, (w % 8) as u32, 0, enq, now, seq, 0);
                    tr.span(Stage::Apply, (w % 8) as u32, 0, now, tr.real_now(), seq, 0);
                }
                w += 1;
                seq += 1;
            }
        });
        case(&r, name)
    };

    let plain = {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(Policy::Async, dim, 8);
        let mut w = 0usize;
        let grad = Arc::clone(&grad);
        let r = b.bench("submit_plain", move || {
            for _ in 0..BATCH {
                let v = ps.version();
                agg.on_gradient(&mut ps, black_box(&grad), w % 8, v, 1.0);
                w += 1;
            }
        });
        case(&r, "submit_plain")
    };
    let off = run(b, "submit_trace_off", None);
    let on = run(b, "submit_trace_ring", Some(Arc::new(TraceRing::new(1 << 16))));

    // Worst case: a drain thread continuously serializing the ring into
    // Chrome JSON while the submit path keeps recording.
    let export_ring = Arc::new(TraceRing::new(1 << 16));
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let drainer = {
        let ring = Arc::clone(&export_ring);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut bytes = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                bytes += chrome_trace_json(&ring.drain()).len();
            }
            bytes
        })
    };
    let exporting = run(b, "submit_trace_export", Some(Arc::clone(&export_ring)));
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    black_box(drainer.join().unwrap());

    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let limit = if quick { 1.10 } else { 1.02 };
    let ratio = off.p50_ns / plain.p50_ns;
    println!(
        "  trace-off overhead: {:+.2}% on p50 per gradient (gate +{:.0}%{})",
        (ratio - 1.0) * 100.0,
        (limit - 1.0) * 100.0,
        if quick { ", quick-noise headroom" } else { "" }
    );
    assert!(
        ratio <= limit,
        "tracing-off submit path regressed: p50 {:.1} ns/gradient vs plain {:.1} ({:+.2}%)",
        off.p50_ns,
        plain.p50_ns,
        (ratio - 1.0) * 100.0
    );
    vec![plain, off, on, exporting]
}

/// Emit the tracing-overhead baseline when asked
/// (`BENCH_TRACE_OUT=../BENCH_trace.json cargo bench --bench
/// bench_hotpath`; cargo runs bench binaries with cwd = rust/).
fn write_trace_baseline(cases: &[TraceOverheadCase]) {
    let Ok(path) = std::env::var("BENCH_TRACE_OUT") else {
        return;
    };
    let mut rows = Vec::new();
    for c in cases {
        rows.push(Json::from_pairs(vec![
            ("name", Json::Str(c.name.to_string())),
            ("dim", Json::Num(52_138.0)),
            ("ops_per_sec", Json::Num(c.ops_per_sec)),
        ]));
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("bench_hotpath/trace_overhead".to_string())),
        (
            "quick",
            Json::Bool(std::env::var("BENCH_QUICK").map_or(false, |v| v == "1")),
        ),
        ("cases", Json::Arr(rows)),
    ]);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

/// One publish-cost case for the `BENCH_memory.json` baseline.
struct MemoryCase {
    name: &'static str,
    dim: usize,
    dtype: &'static str,
    ops_per_sec: f64,
    /// Steady-state bytes copied/converted into the published snapshot per
    /// publish (exact and hardware-independent: dense re-copies every
    /// block, a sparse update re-copies only its dirty blocks).
    bytes_per_publish: usize,
}

/// One peak-RSS observation. VmHWM is a process-monotone high-water mark,
/// so dims must run ascending and each row means "peak over all work up to
/// and including this dim".
struct RssCase {
    dim: usize,
    model_bytes: usize,
    peak_rss_bytes: u64,
}

/// Big-model memory path (ISSUE 10): steady-state snapshot publish cost of
/// the block-recycling pipeline — dense full-dim updates vs sparse updates
/// dirtying ~1% of blocks — at f32 and f16 snapshot dtypes, plus peak-RSS
/// observations per dim. Full runs add the 1e8-coordinate case in the
/// recommended big-model configuration only (f16 snapshots, sparse
/// updates): dense there needs a 400 MB gradient and an f32 snapshot
/// pipeline peaks at 3× model — exactly what DESIGN.md §2.12 tells
/// operators to avoid — and running it would poison the monotone VmHWM
/// reading for the configuration that matters.
fn bench_memory(b: &mut Bencher) -> (Vec<MemoryCase>, Vec<RssCase>) {
    use hybrid_sgd::coordinator::params::{block_count, BLOCK_ELEMS};
    use hybrid_sgd::coordinator::{peak_rss_bytes, ParamDtype, SnapshotCell};
    println!("\n== big-model memory path: snapshot publish + peak RSS ==");
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let mut cases: Vec<MemoryCase> = Vec::new();
    let mut rss: Vec<RssCase> = Vec::new();

    // The cell starts empty so construction peaks at master + one
    // snapshot, not master + f32 clone + snapshot.
    let store = |dim: usize, dtype: ParamDtype| {
        let cell = Arc::new(SnapshotCell::new(Vec::new()));
        ParamStore::with_cell_dtype(vec![0.1; dim], 0.01, cell, dtype)
    };
    // ~1% of blocks dirty: one coordinate in every 100th block (never the
    // trailing partial block, so each dirty block re-copies BLOCK_ELEMS).
    let sparse_idx = |dim: usize| -> Vec<u32> {
        let touched = (block_count(dim) / 100).max(1);
        (0..touched as u32).map(|i| i * 100 * BLOCK_ELEMS as u32).collect()
    };
    // Steady-state bytes per publish, read off the store's own accounting
    // after the timed loop has reached the buffer-recycle steady state.
    let per_publish = |ps: &mut ParamStore, mut op: &mut dyn FnMut(&mut ParamStore)| -> usize {
        let (p0, b0) = (ps.publishes(), ps.snapshot_bytes_published());
        for _ in 0..4 {
            op(ps);
        }
        ((ps.snapshot_bytes_published() - b0) / (ps.publishes() - p0)) as usize
    };

    for &dim in &[1_000_000usize, 10_000_000] {
        let idx = sparse_idx(dim);
        let val = vec![1e-3f32; idx.len()];
        let mut grad = vec![0.0f32; dim];
        Pcg64::seeded(11).fill_normal(&mut grad, 1.0);

        let mut dense_f32 = 0usize;
        let mut delta_f32 = 0usize;
        for dtype in [ParamDtype::F32, ParamDtype::F16] {
            let dname = dtype.as_str();
            let mut ps = store(dim, dtype);
            let r = b.bench(&format!("publish dense d={dim} {dname}"), || {
                ps.apply_single(black_box(&grad));
            });
            let dense = per_publish(&mut ps, &mut |ps| ps.apply_single(&grad));
            cases.push(MemoryCase {
                name: "publish_dense",
                dim,
                dtype: dname,
                ops_per_sec: 1e9 / r.mean_ns,
                bytes_per_publish: dense,
            });

            let mut ps = store(dim, dtype);
            let r = b.bench(&format!("publish delta1pct d={dim} {dname}"), || {
                ps.apply_view(GradView::Sparse {
                    idx: black_box(&idx),
                    val: &val,
                });
            });
            let delta = per_publish(&mut ps, &mut |ps| {
                ps.apply_view(GradView::Sparse {
                    idx: &idx,
                    val: &val,
                })
            });
            cases.push(MemoryCase {
                name: "publish_delta1pct",
                dim,
                dtype: dname,
                ops_per_sec: 1e9 / r.mean_ns,
                bytes_per_publish: delta,
            });

            // Acceptance: buffer recycling makes a sparse update publish in
            // O(dirty blocks), not O(dim) — ≥ 50× fewer snapshot bytes than
            // the dense re-copy at 1% block density.
            assert!(
                dense >= 50 * delta,
                "delta publish must cut snapshot bytes ≥ 50× at 1% dirty blocks: \
                 dense {dense} vs delta {delta} (d={dim} {dname})"
            );
            if dtype == ParamDtype::F32 {
                dense_f32 = dense;
                delta_f32 = delta;
            }
        }
        println!(
            "      bytes/publish d={dim}: dense f32 {dense_f32}, delta f32 {delta_f32} ({:.0}x), f16 halves both",
            dense_f32 as f64 / delta_f32 as f64
        );
        rss.push(RssCase {
            dim,
            model_bytes: dim * 4,
            peak_rss_bytes: peak_rss_bytes(),
        });
    }

    if !quick {
        let dim = 100_000_000usize;
        let idx = sparse_idx(dim);
        let val = vec![1e-3f32; idx.len()];
        let mut ps = store(dim, ParamDtype::F16);
        let r = b.bench(&format!("publish delta1pct d={dim} f16"), || {
            ps.apply_view(GradView::Sparse {
                idx: black_box(&idx),
                val: &val,
            });
        });
        let delta = per_publish(&mut ps, &mut |ps| {
            ps.apply_view(GradView::Sparse {
                idx: &idx,
                val: &val,
            })
        });
        cases.push(MemoryCase {
            name: "publish_delta1pct",
            dim,
            dtype: "f16",
            ops_per_sec: 1e9 / r.mean_ns,
            bytes_per_publish: delta,
        });
        let hwm = peak_rss_bytes();
        rss.push(RssCase {
            dim,
            model_bytes: dim * 4,
            peak_rss_bytes: hwm,
        });
        if hwm > 0 {
            println!(
                "      peak RSS at d=1e8/f16: {:.0} MB = {:.2}x model bytes \
                 (f32 master + published f16 snapshot + one recycled spare)",
                hwm as f64 / 1e6,
                hwm as f64 / (dim as f64 * 4.0)
            );
        }
    }
    (cases, rss)
}

/// Emit the memory baseline when asked
/// (`BENCH_MEMORY_OUT=../BENCH_memory.json cargo bench --bench
/// bench_hotpath`; cargo runs bench binaries with cwd = rust/).
fn write_memory_baseline(cases: &[MemoryCase], rss: &[RssCase]) {
    let Ok(path) = std::env::var("BENCH_MEMORY_OUT") else {
        return;
    };
    let mut rows = Vec::new();
    for c in cases {
        rows.push(Json::from_pairs(vec![
            ("name", Json::Str(c.name.to_string())),
            ("dim", Json::Num(c.dim as f64)),
            ("dtype", Json::Str(c.dtype.to_string())),
            ("ops_per_sec", Json::Num(c.ops_per_sec)),
            ("bytes_per_publish", Json::Num(c.bytes_per_publish as f64)),
        ]));
    }
    let mut rss_rows = Vec::new();
    for r in rss {
        rss_rows.push(Json::from_pairs(vec![
            ("dim", Json::Num(r.dim as f64)),
            ("model_bytes", Json::Num(r.model_bytes as f64)),
            ("peak_rss_bytes", Json::Num(r.peak_rss_bytes as f64)),
        ]));
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("bench_hotpath/memory".to_string())),
        (
            "quick",
            Json::Bool(std::env::var("BENCH_QUICK").map_or(false, |v| v == "1")),
        ),
        ("cases", Json::Arr(rows)),
        ("peak_rss", Json::Arr(rss_rows)),
    ]);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let mut b = Bencher::new();
    println!("== L3 parameter-server hot path ==");

    // Parameter sizes of the model zoo.
    for &dim in &[6_154usize, 52_138, 111_936] {
        let mut rng = Pcg64::seeded(1);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);

        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        b.bench(&format!("apply_single d={dim}"), || {
            ps.apply_single(black_box(&grad));
        });

        let mut buffer = GradientBuffer::new(dim, 8);
        b.bench(&format!("buffer_push d={dim}"), || {
            buffer.push(black_box(&grad), 3, 0, 0);
            if buffer.len() >= 64 {
                buffer.clear();
            }
        });

        let mut ps2 = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: Schedule::Step { step: 100 },
                strict: false,
            },
            dim,
            8,
        );
        let mut w = 0usize;
        b.bench(&format!("hybrid on_gradient d={dim}"), || {
            let v = ps2.version();
            agg.on_gradient(&mut ps2, black_box(&grad), w % 8, v, 1.0);
            w += 1;
        });

        // What replaced the per-reply θ clone: the server-side snapshot
        // publish (one memcpy into a recycled buffer, amortised over all
        // readers) and the reader-side refresh (Arc load + memcpy).
        let mut ps3 = ParamStore::new(vec![0.1; dim], 0.01);
        b.bench(&format!("snapshot publish d={dim}"), || {
            ps3.apply_single(black_box(&grad)); // bump ⇒ publish
        });
        let cell = ps3.cell();
        let mut local = vec![0.0f32; dim];
        b.bench(&format!("snapshot refresh d={dim}"), || {
            let snap = cell.load();
            snap.copy_to(&mut local);
            black_box(&local);
        });
        b.bench(&format!("snapshot load only d={dim}"), || {
            black_box(cell.load().version);
        });
    }

    // Policy comparison at fixed dim: per-arrival overhead must be flat.
    let dim = 52_138;
    let mut rng = Pcg64::seeded(2);
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 1.0);
    for (name, policy) in [
        ("async", Policy::Async),
        ("sync", Policy::Sync),
        (
            "hybrid",
            Policy::Hybrid {
                schedule: Schedule::Step { step: 50 },
                strict: false,
            },
        ),
    ] {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(policy, dim, 8);
        let mut w = 0usize;
        b.bench(&format!("on_gradient policy={name}"), || {
            let v = ps.version();
            agg.on_gradient(&mut ps, black_box(&grad), w % 8, v, 1.0);
            w += 1;
        });
    }

    // Sharded state machine: the per-arrival cost of S shards driven
    // sequentially must stay ~flat vs the unsharded machine (the win in the
    // threaded server is that the shards run on S threads).
    {
        let dim = 111_936;
        let mut rng = Pcg64::seeded(3);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);
        let init = vec![0.1f32; dim];
        for shards in [1usize, 4] {
            let mut m = ShardedAggregator::new(
                Policy::Hybrid {
                    schedule: Schedule::Step { step: 100 },
                    strict: false,
                },
                &init,
                0.01,
                8,
                shards,
            );
            let mut w = 0usize;
            b.bench(&format!("sharded on_gradient S={shards} d={dim}"), || {
                let v = m.version();
                m.on_gradient(black_box(&grad), w % 8, v, 1.0);
                w += 1;
            });
        }
    }

    let wire_cases = bench_wire_formats(&mut b);
    write_compress_baseline(&wire_cases);

    let transport_cases = bench_transport_frames(&mut b);
    let conn_cases = bench_connection_scaling();
    write_transport_baseline(&transport_cases, &conn_cases);

    let trace_cases = bench_trace_overhead(&mut b);
    write_trace_baseline(&trace_cases);

    let (memory_cases, rss_cases) = bench_memory(&mut b);
    write_memory_baseline(&memory_cases, &rss_cases);

    b.summary();
    // Headline check: the hybrid PS step on the largest model must be far
    // below the cheapest gradient latency (~0.2 ms for the mlp artifact).
    let hot = b
        .results
        .iter()
        .find(|r| r.name.contains("hybrid on_gradient d=111936"))
        .unwrap();
    println!(
        "\nPS overhead on the largest model: {:.1} µs/gradient ({}x below the 0.2 ms mlp grad)",
        hot.mean_ns / 1e3,
        (200_000.0 / hot.mean_ns) as u64
    );
}
