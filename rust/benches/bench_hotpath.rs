//! L3 hot-path micro-benchmarks: the parameter-server operations that run
//! once per gradient arrival. Targets (DESIGN.md §7): PS cost ≪ grad
//! latency (≥ ~0.2 ms), no allocation in the per-gradient loop.
//!
//!     cargo bench --bench bench_hotpath          # full
//!     BENCH_QUICK=1 cargo bench ...              # smoke

use hybrid_sgd::coordinator::buffer::GradientBuffer;
use hybrid_sgd::coordinator::params::ParamStore;
use hybrid_sgd::coordinator::{Aggregator, Policy, Schedule, ShardedAggregator};
use hybrid_sgd::util::bench::{black_box, Bencher};
use hybrid_sgd::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::new();
    println!("== L3 parameter-server hot path ==");

    // Parameter sizes of the model zoo.
    for &dim in &[6_154usize, 52_138, 111_936] {
        let mut rng = Pcg64::seeded(1);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);

        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        b.bench(&format!("apply_single d={dim}"), || {
            ps.apply_single(black_box(&grad));
        });

        let mut buffer = GradientBuffer::new(dim, 8);
        b.bench(&format!("buffer_push d={dim}"), || {
            buffer.push(black_box(&grad), 3, 0, 0);
            if buffer.len() >= 64 {
                buffer.clear();
            }
        });

        let mut ps2 = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: Schedule::Step { step: 100 },
                strict: false,
            },
            dim,
            8,
        );
        let mut w = 0usize;
        b.bench(&format!("hybrid on_gradient d={dim}"), || {
            let v = ps2.version();
            agg.on_gradient(&mut ps2, black_box(&grad), w % 8, v, 1.0);
            w += 1;
        });

        // What replaced the per-reply θ clone: the server-side snapshot
        // publish (one memcpy into a recycled buffer, amortised over all
        // readers) and the reader-side refresh (Arc load + memcpy).
        let mut ps3 = ParamStore::new(vec![0.1; dim], 0.01);
        b.bench(&format!("snapshot publish d={dim}"), || {
            ps3.apply_single(black_box(&grad)); // bump ⇒ publish
        });
        let cell = ps3.cell();
        let mut local = vec![0.0f32; dim];
        b.bench(&format!("snapshot refresh d={dim}"), || {
            let snap = cell.load();
            local.copy_from_slice(&snap.theta);
            black_box(&local);
        });
        b.bench(&format!("snapshot load only d={dim}"), || {
            black_box(cell.load().version);
        });
    }

    // Policy comparison at fixed dim: per-arrival overhead must be flat.
    let dim = 52_138;
    let mut rng = Pcg64::seeded(2);
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 1.0);
    for (name, policy) in [
        ("async", Policy::Async),
        ("sync", Policy::Sync),
        (
            "hybrid",
            Policy::Hybrid {
                schedule: Schedule::Step { step: 50 },
                strict: false,
            },
        ),
    ] {
        let mut ps = ParamStore::new(vec![0.1; dim], 0.01);
        let mut agg = Aggregator::new(policy, dim, 8);
        let mut w = 0usize;
        b.bench(&format!("on_gradient policy={name}"), || {
            let v = ps.version();
            agg.on_gradient(&mut ps, black_box(&grad), w % 8, v, 1.0);
            w += 1;
        });
    }

    // Sharded state machine: the per-arrival cost of S shards driven
    // sequentially must stay ~flat vs the unsharded machine (the win in the
    // threaded server is that the shards run on S threads).
    {
        let dim = 111_936;
        let mut rng = Pcg64::seeded(3);
        let mut grad = vec![0.0f32; dim];
        rng.fill_normal(&mut grad, 1.0);
        let init = vec![0.1f32; dim];
        for shards in [1usize, 4] {
            let mut m = ShardedAggregator::new(
                Policy::Hybrid {
                    schedule: Schedule::Step { step: 100 },
                    strict: false,
                },
                &init,
                0.01,
                8,
                shards,
            );
            let mut w = 0usize;
            b.bench(&format!("sharded on_gradient S={shards} d={dim}"), || {
                let v = m.version();
                m.on_gradient(black_box(&grad), w % 8, v, 1.0);
                w += 1;
            });
        }
    }

    b.summary();
    // Headline check: the hybrid PS step on the largest model must be far
    // below the cheapest gradient latency (~0.2 ms for the mlp artifact).
    let hot = b
        .results
        .iter()
        .find(|r| r.name.contains("hybrid on_gradient d=111936"))
        .unwrap();
    println!(
        "\nPS overhead on the largest model: {:.1} µs/gradient ({}x below the 0.2 ms mlp grad)",
        hot.mean_ns / 1e3,
        (200_000.0 / hot.mean_ns) as u64
    );
}
