//! Ablations over the design choices DESIGN.md calls out:
//!   1. smooth vs strict hybrid (does blocking help or hurt?)
//!   2. threshold-function family (paper §9: are monotone schedules
//!      interchangeable?)
//!   3. engine ablation: native-Rust backprop vs AOT XLA executables at the
//!      coordinator level (how much does the engine choice move end metrics?)
//!
//! Runs on the native engine by default (fast, no artifacts needed);
//! ablation 3 requires artifacts and skips without them.

use hybrid_sgd::coordinator::worker::BatchSource;
use hybrid_sgd::coordinator::{
    train, DelayModel, EvalSet, Policy, RunInputs, RunMetrics, Schedule, TrainConfig,
};
use hybrid_sgd::data::{random_cluster, Batcher, Dataset};
use hybrid_sgd::engine::{factory, GradEngine};
use hybrid_sgd::native::MlpEngine;
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const DIMS: [usize; 4] = [20, 64, 64, 10];

struct Fixture {
    train_set: Arc<Dataset>,
    test: EvalSet,
    probe: EvalSet,
    init: Vec<f32>,
}

fn fixture() -> Fixture {
    let mut rng = Pcg64::seeded(77);
    let spec = random_cluster::ClusterSpec::default();
    let full = random_cluster::generate(&spec, &mut rng);
    let (train_set, test_set) = full.split(0.8, &mut rng);
    Fixture {
        test: EvalSet::from_dataset(&test_set, 400, &mut rng),
        probe: EvalSet::from_dataset(&train_set, 400, &mut rng),
        init: MlpEngine::init_params(&DIMS, &mut rng),
        train_set: Arc::new(train_set),
    }
}

fn run_native(fx: &Fixture, policy: Policy, secs: f64, compute_ms: f64) -> RunMetrics {
    let workers = 6;
    let batch = 32;
    let dims: Vec<usize> = DIMS.to_vec();
    let dims2 = dims.clone();
    let shards = fx.train_set.shard_indices(workers);
    let train_arc = Arc::clone(&fx.train_set);
    let inputs = RunInputs {
        worker_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims.clone(), batch)) as Box<dyn GradEngine>)
        }),
        eval_engine: factory(move || {
            Ok(Box::new(MlpEngine::new(dims2.clone(), 100)) as Box<dyn GradEngine>)
        }),
        batch_source: Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train_arc),
                shards[id].clone(),
                batch,
                Pcg64::new(7, id as u64),
            )) as Box<dyn BatchSource>
        }),
        init_params: &fx.init,
        test: &fx.test,
        train_probe: &fx.probe,
    };
    let cfg = TrainConfig {
        policy,
        workers,
        lr: 0.01,
        duration: Duration::from_secs_f64(secs),
        delay: DelayModel::paper_default(),
        seed: 7,
        eval_interval: Duration::from_millis(300),
        k_max: None,
        compute_floor: Duration::from_secs_f64(compute_ms / 1000.0),
        shards: 1,
        wire: hybrid_sgd::coordinator::WireFormat::Dense,
        steps: None,
        elastic: false,
        min_quorum: 1,
        stream: None,
        aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
        partition: hybrid_sgd::data::Partition::Iid,
        trace: None,
        param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
    };
    train(&cfg, &inputs).expect("run failed")
}

fn report(name: &str, m: &RunMetrics) {
    let (tr, te, acc) = m.final_metrics().unwrap_or((f64::NAN, f64::NAN, f64::NAN));
    println!(
        "  {name:<28} acc {acc:>6.2}%  test-loss {te:.4}  train-loss {tr:.4}  \
         ({} grads, {} updates, staleness {:.2})",
        m.gradients_total, m.updates_total, m.mean_staleness
    );
}

fn main() {
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Off);
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let secs = if quick { 1.5 } else { 8.0 };
    let compute_ms = if quick { 0.0 } else { 20.0 };
    let step = if quick { 30 } else { 150 };
    let fx = fixture();

    println!("== ablation 1: smooth vs strict hybrid ({secs}s each) ==");
    for strict in [false, true] {
        let m = run_native(
            &fx,
            Policy::Hybrid {
                schedule: Schedule::Step { step },
                strict,
            },
            secs,
            compute_ms,
        );
        report(if strict { "strict (blocking)" } else { "smooth (paper default)" }, &m);
    }

    println!("\n== ablation 2: threshold-function family (paper §9) ==");
    let schedules: Vec<(&str, Schedule)> = vec![
        ("step (paper)", Schedule::Step { step }),
        (
            "linear",
            Schedule::Linear {
                rate: 1.0 / step as f64,
            },
        ),
        (
            "exponential",
            Schedule::Exponential {
                step: step * 2,
                growth: 2.0,
            },
        ),
        (
            "sigmoid",
            Schedule::Sigmoid {
                mid: (step * 4) as f64,
                scale: step as f64,
            },
        ),
        ("const k=1 (async)", Schedule::Constant { k: 1 }),
        ("const k=W (batched)", Schedule::Constant { k: 6 }),
    ];
    for (name, schedule) in schedules {
        let m = run_native(
            &fx,
            Policy::Hybrid {
                schedule,
                strict: false,
            },
            secs,
            compute_ms,
        );
        report(name, &m);
    }
    {
        // §9 heuristic: staleness-driven adaptive K (no tuned step size)
        let m = run_native(
            &fx,
            Policy::HybridAdaptive {
                cfg: hybrid_sgd::coordinator::AdaptiveConfig::default(),
                strict: false,
            },
            secs,
            compute_ms,
        );
        report("adaptive (staleness-EWMA)", &m);
    }

    println!("\n== ablation 3: engine choice (native vs XLA) under hybrid ==");
    {
        let m = run_native(
            &fx,
            Policy::Hybrid {
                schedule: Schedule::Step { step },
                strict: false,
            },
            secs,
            compute_ms,
        );
        report("native backprop", &m);
    }
    match hybrid_sgd::runtime::engine_factories("artifacts", "mlp", 32, "jnp") {
        Ok((worker_engine, eval_engine)) => {
            let workers = 6;
            let shards = fx.train_set.shard_indices(workers);
            let train_arc = Arc::clone(&fx.train_set);
            let inputs = RunInputs {
                worker_engine,
                eval_engine,
                batch_source: Arc::new(move |id| {
                    Box::new(Batcher::new(
                        Arc::clone(&train_arc),
                        shards[id].clone(),
                        32,
                        Pcg64::new(7, id as u64),
                    )) as Box<dyn BatchSource>
                }),
                init_params: &fx.init,
                test: &fx.test,
                train_probe: &fx.probe,
            };
            let cfg = TrainConfig {
                policy: Policy::Hybrid {
                    schedule: Schedule::Step { step },
                    strict: false,
                },
                workers,
                lr: 0.01,
                duration: Duration::from_secs_f64(secs),
                delay: DelayModel::paper_default(),
                seed: 7,
                eval_interval: Duration::from_millis(300),
                k_max: None,
                compute_floor: Duration::from_secs_f64(compute_ms / 1000.0),
                shards: 1,
                wire: hybrid_sgd::coordinator::WireFormat::Dense,
                steps: None,
                elastic: false,
                min_quorum: 1,
                stream: None,
                aggregate: hybrid_sgd::coordinator::AggregateMode::Mean,
                partition: hybrid_sgd::data::Partition::Iid,
                trace: None,
                param_dtype: hybrid_sgd::coordinator::ParamDtype::F32,
            };
            let m = train(&cfg, &inputs).expect("xla run failed");
            report("AOT XLA (jnp)", &m);
        }
        Err(e) => println!("  AOT XLA: SKIP ({e})"),
    }
}
