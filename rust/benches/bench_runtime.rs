//! Runtime-layer benchmarks: parameter-server shard-scaling on the native
//! engine (no artifacts needed), AOT executable latency per model and
//! variant (L2), the fused PS-update kernel vs the native loop (L1 vs L3),
//! and the native-Rust engine as the baseline comparator.
//!
//! The artifact-dependent sections skip gracefully when `artifacts/` is
//! absent; the shard-scaling section always runs.

use hybrid_sgd::coordinator::compress::{submission_bytes, GradEncoder, WireFormat};
use hybrid_sgd::coordinator::params::ParamStore;
use hybrid_sgd::coordinator::{Aggregator, Policy, ShardLayout};
use hybrid_sgd::engine::GradEngine;
use hybrid_sgd::native::MlpEngine;
use hybrid_sgd::runtime::{init_params, Manifest, UpdateOp, XlaEngine};
use hybrid_sgd::util::bench::{black_box, Bencher};
use hybrid_sgd::util::rng::Pcg64;
use std::sync::Arc;
use std::time::Instant;

/// Server-side throughput of the sharded parameter server: S shard threads
/// each consume the identical stream of G full-dim gradients (their slice
/// of it — exactly the per-arrival work `run_shard` does: aggregate +
/// update + snapshot publish). Wall time is the slowest shard; throughput
/// must grow monotonically from S = 1 to S = 4 on a multi-core host.
fn bench_shard_scaling() {
    println!("== sharded PS: server-side gradient throughput (native) ==");
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let dim = 111_936; // transformer-scale flat θ
    let grads_n = if quick { 200 } else { 1_000 };
    let workers = 8;
    let mut rng = Pcg64::seeded(42);
    // A small recycled pool stands in for the arrival stream (distinct
    // values, bounded memory: 16 × dim × 4 B ≈ 7 MB).
    let pool: Vec<Arc<Vec<f32>>> = (0..16)
        .map(|_| {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            Arc::new(g)
        })
        .collect();
    let init = vec![0.1f32; dim];

    let mut last = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let layout = ShardLayout::new(dim, shards);
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for r in layout.ranges() {
                let pool = &pool;
                let init = &init[r.clone()];
                s.spawn(move || {
                    let mut store = ParamStore::new(init.to_vec(), 0.01);
                    let mut agg = Aggregator::new(Policy::Async, r.len(), workers);
                    for i in 0..grads_n {
                        let g = &pool[i % pool.len()];
                        let v = store.version();
                        agg.on_gradient(&mut store, &g[r.clone()], i % workers, v, 1.0);
                    }
                    black_box(store.version());
                });
            }
        });
        let secs = t0.elapsed().as_secs_f64();
        let thr = grads_n as f64 / secs;
        println!(
            "  S={shards}: {:>8.0} grads/s  ({:.1} ms total{})",
            thr,
            secs * 1e3,
            if thr > last { "" } else { "  [no scaling — core-bound?]" }
        );
        last = thr;
    }
    println!();
}

/// End-to-end wire-format throughput on the native stack: encode G
/// gradients per format, then drive the per-arrival server work
/// (aggregate + update + snapshot publish) from the encoded payloads —
/// dense vs top-k 1% vs int8 at identical gradient counts, with the
/// bytes-on-wire each format put on the channel. Equal-*bandwidth*
/// comparisons divide throughput by these byte counts.
fn bench_wire_throughput() {
    println!("== wire formats: end-to-end encode + server apply throughput (native) ==");
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let dim = 111_936; // transformer-scale flat θ
    let grads_n = if quick { 100 } else { 500 };
    let workers = 8;
    let layout = ShardLayout::new(dim, 1);
    let mut rng = Pcg64::seeded(44);
    let pool: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            g
        })
        .collect();
    let init = vec![0.1f32; dim];

    let mut dense_thr = 0.0f64;
    for wire in [
        WireFormat::Dense,
        WireFormat::parse("topk:0.01").unwrap(),
        WireFormat::Int8,
        WireFormat::parse("topk+int8:0.01").unwrap(),
    ] {
        let mut enc = GradEncoder::new(wire.clone(), dim, layout.shards());
        let mut store = ParamStore::new(init.clone(), 0.01);
        let mut agg = Aggregator::new(Policy::Async, dim, workers);
        let mut payloads = Vec::new();
        let mut bytes = 0u64;
        let t0 = Instant::now();
        for i in 0..grads_n {
            enc.encode(&pool[i % pool.len()], &layout, &mut payloads);
            bytes += submission_bytes(&payloads, &layout);
            let v = store.version();
            agg.on_gradient_view(
                &mut store,
                payloads[0].view(layout.range(0)),
                i % workers,
                v,
                1.0,
            );
        }
        black_box(store.version());
        let secs = t0.elapsed().as_secs_f64();
        let thr = grads_n as f64 / secs;
        if wire.is_dense() {
            dense_thr = thr;
        }
        println!(
            "  {:<16} {:>8.0} grads/s  {:>7.2} MB on wire ({:>5.1}x vs dense{})",
            wire.to_string(),
            thr,
            bytes as f64 / 1e6,
            (grads_n as f64 * dim as f64 * 4.0) / bytes as f64,
            if dense_thr > 0.0 && thr < dense_thr * 0.5 {
                ", slower apply"
            } else {
                ""
            }
        );
    }
    println!();
}

fn main() {
    bench_shard_scaling();
    bench_wire_throughput();

    let Ok(man) = Manifest::load("artifacts") else {
        println!("SKIP bench_runtime (AOT sections): artifacts/ not built (run `make artifacts`)");
        return;
    };
    let mut b = Bencher::new();
    println!("== runtime: AOT executable latency (grad, per call) ==");

    for (model, batch, xd, yd) in [
        ("mlp", 32usize, 20usize, 1usize),
        ("cnn_mnist", 32, 784, 1),
        ("cnn_cifar", 32, 3072, 1),
        ("transformer", 8, 64, 64),
    ] {
        let mut rng = Pcg64::seeded(3);
        let entry = man.model(model).unwrap();
        let params = init_params(entry, &mut rng).unwrap();
        let mut x = vec![0.0f32; batch * xd];
        rng.fill_normal(&mut x, 0.5);
        if model == "transformer" {
            for v in x.iter_mut() {
                *v = (v.abs() * 60.0).min(63.0).floor();
            }
        }
        let y: Vec<i32> = (0..batch * yd).map(|i| (i % 10) as i32).collect();
        let mut g = vec![0.0f32; params.len()];
        let mut eng = XlaEngine::new(&man, model, Some(batch), "jnp", false).unwrap();
        let r = b.bench(&format!("grad {model} b{batch} jnp"), || {
            black_box(eng.grad(&params, &x, &y, &mut g).unwrap());
        });
        let samples = if model == "transformer" { batch * yd } else { batch };
        println!(
            "      -> {:.0} samples/s",
            r.throughput(samples as f64)
        );
    }

    println!("\n== L1 ablation: pallas vs jnp variants (identical numerics) ==");
    for variant in ["jnp", "pallas"] {
        for model in ["mlp", "cnn_mnist"] {
            if man.graph(model, "grad", 32, variant).is_err() {
                continue;
            }
            let mut rng = Pcg64::seeded(4);
            let entry = man.model(model).unwrap();
            let params = init_params(entry, &mut rng).unwrap();
            let xd = entry.x_dim;
            let mut x = vec![0.0f32; 32 * xd];
            rng.fill_normal(&mut x, 0.5);
            let y: Vec<i32> = (0..32).map(|i| (i % 10) as i32).collect();
            let mut g = vec![0.0f32; params.len()];
            let mut eng = XlaEngine::new(&man, model, Some(32), variant, false).unwrap();
            b.bench(&format!("grad {model} b32 {variant}"), || {
                black_box(eng.grad(&params, &x, &y, &mut g).unwrap());
            });
        }
    }

    println!("\n== PS update: fused AOT kernel vs native loop ==");
    {
        let mut rng = Pcg64::seeded(5);
        let n = man.model("mlp").unwrap().param_count;
        let mut params = vec![0.1f32; n];
        let mut gsum = vec![0.0f32; n];
        rng.fill_normal(&mut gsum, 1.0);
        for variant in ["jnp", "pallas"] {
            if man.op("sgd_update", "mlp", variant).is_err() {
                continue;
            }
            let mut op = UpdateOp::new(&man, "mlp", variant).unwrap();
            b.bench(&format!("sgd_update xla {variant} d={n}"), || {
                op.apply(&mut params, &gsum, 0.00125).unwrap();
            });
        }
        b.bench(&format!("sgd_update native loop d={n}"), || {
            for (p, &gv) in params.iter_mut().zip(&gsum) {
                *p -= 0.00125 * gv;
            }
            black_box(&params);
        });
    }

    println!("\n== native baseline engine (coordinator benches use this) ==");
    {
        let mut rng = Pcg64::seeded(6);
        let dims = vec![20usize, 64, 64, 10];
        let params = MlpEngine::init_params(&dims, &mut rng);
        let mut eng = MlpEngine::new(dims, 32);
        let mut x = vec![0.0f32; 32 * 20];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<i32> = (0..32).map(|i| (i % 10) as i32).collect();
        let mut g = vec![0.0f32; params.len()];
        b.bench("grad mlp b32 native-rust", || {
            black_box(eng.grad(&params, &x, &y, &mut g).unwrap());
        });
    }

    b.summary();
}
