//! End-to-end benchmark: regenerate every paper table at reduced scale and
//! report the measured hybrid−async differences next to the paper's values.
//! This is the per-table/figure harness mandated by the reproduction: one
//! bench case per table (figures 8-10 derive from tables 3-5; figures 4-7
//! derive from the table 1-2 comparisons — the `all` CLI command writes
//! their CSVs).
//!
//! Scale: `BENCH_QUICK=1` → seconds (native engine); default → a few
//! minutes (XLA engine, reduced budgets); `BENCH_PAPER=1` → the paper's
//! full 25x5x100 s protocol (hours).

use hybrid_sgd::experiments::config::{DatasetKind, EngineKind, ExpConfig};
use hybrid_sgd::experiments::tables::run_table;
use std::time::Instant;

fn base_for(id: usize, quick: bool, paper: bool) -> ExpConfig {
    let dataset = match id {
        1 => DatasetKind::Mnist,
        2 => DatasetKind::Cifar,
        _ => DatasetKind::Random,
    };
    let mut cfg = ExpConfig::default_for(dataset);
    if paper {
        cfg = cfg.paper_scale();
    } else if quick {
        cfg = cfg.quick();
        cfg.engine = EngineKind::Native;
        if dataset != DatasetKind::Random {
            // native engine only implements the MLP; quick mode exercises
            // the pipeline shape, not the CNN numerics
            cfg.dataset = DatasetKind::Random;
            cfg.compute_ms = 0.0;
        }
        cfg.secs = 2.0;
        cfg.rounds = 1;
    } else {
        // container-scale defaults, single round to keep `cargo bench` sane
        cfg.rounds = 1;
    }
    cfg
}

fn main() {
    hybrid_sgd::util::logging::set_level(hybrid_sgd::util::logging::Level::Warn);
    let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
    let paper = std::env::var("BENCH_PAPER").map_or(false, |v| v == "1");
    println!(
        "== table regeneration ({}) ==",
        if paper {
            "paper scale"
        } else if quick {
            "quick / native"
        } else {
            "container scale / XLA"
        }
    );

    let mut wins = 0usize;
    let mut cols = 0usize;
    for id in 1..=5usize {
        let cfg = base_for(id, quick, paper);
        let t0 = Instant::now();
        match run_table(id, &cfg) {
            Ok(table) => {
                println!("{}", table.to_markdown());
                println!(
                    "table {id}: {:.1}s wall, hybrid wins accuracy in {:.0}% of columns\n",
                    t0.elapsed().as_secs_f64(),
                    table.win_fraction() * 100.0
                );
                wins += table
                    .measured
                    .iter()
                    .filter(|d| d.test_acc > 0.0)
                    .count();
                cols += table.measured.len();
            }
            Err(e) => println!("table {id}: SKIP ({e})\n"),
        }
    }
    println!("== overall: hybrid beats async on accuracy in {wins}/{cols} configurations ==");
    println!("(paper: 23/24 across Tables 1-5; shape target is a clear majority)");
}
