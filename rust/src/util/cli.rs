//! Command-line argument parsing.
//!
//! `clap` is unavailable offline, so the binary and examples use this small
//! parser: subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and auto-generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand (optional), named options, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (testable).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I, has_subcommand: bool) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        if has_subcommand {
            if let Some(first) = it.peek() {
                if !first.starts_with('-') {
                    args.subcommand = it.next();
                }
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.opts
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map_or(false, |n| !n.starts_with("--"))
                {
                    let val = it.next().unwrap();
                    args.opts.insert(stripped.to_string(), val);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse(has_subcommand: bool) -> Args {
        Self::parse_from(std::env::args().skip(1), has_subcommand)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self.opts.get(name).map_or(false, |v| v == "true" || v == "1")
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.parse_or(name, default)
    }

    /// Fallible core of the typed accessors: `Ok(None)` if the option is
    /// absent, `Err` with a user-facing message if present but malformed.
    fn parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String> {
        match self.get(name) {
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| format!("invalid value for --{name}: {s:?}")),
            None => Ok(None),
        }
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.parsed(name) {
            Ok(v) => v.unwrap_or(default),
            Err(msg) => die(&msg),
        }
    }

    /// Fallible core of the list accessors: parse a comma-separated list,
    /// reporting which element was malformed.
    fn list<T: std::str::FromStr>(&self, name: &str, default: &[T]) -> Result<Vec<T>, String>
    where
        T: Clone,
    {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    let p = p.trim();
                    p.parse()
                        .map_err(|_| format!("invalid element {p:?} in --{name} {s:?}"))
                })
                .collect(),
            None => Ok(default.to_vec()),
        }
    }

    /// Comma-separated list of f64, e.g. `--stds 0.25,0.5,1.0`.
    pub fn f64_list(&self, name: &str, default: &[f64]) -> Vec<f64> {
        self.list(name, default).unwrap_or_else(|msg| die(&msg))
    }

    /// Comma-separated list of usize.
    pub fn usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        self.list(name, default).unwrap_or_else(|msg| die(&msg))
    }
}

/// Malformed user input is an error exit (status 2), stated as such on
/// stderr — never a panic, and never a silent fallback to the default.
fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|s| s.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse_from(toks("table --n 3 --algo hybrid --quick"), true);
        assert_eq!(a.subcommand.as_deref(), Some("table"));
        assert_eq!(a.usize_or("n", 0), 3);
        assert_eq!(a.str_or("algo", "x"), "hybrid");
        assert!(a.flag("quick"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn equals_form_and_lists() {
        let a = Args::parse_from(toks("--batch=64 --stds 0.25,0.5 --sizes 8,16"), false);
        assert_eq!(a.usize_or("batch", 0), 64);
        assert_eq!(a.f64_list("stds", &[]), vec![0.25, 0.5]);
        assert_eq!(a.usize_list("sizes", &[]), vec![8, 16]);
        assert_eq!(a.f64_list("missing", &[1.0]), vec![1.0]);
    }

    #[test]
    fn trailing_flag_and_positionals() {
        let a = Args::parse_from(toks("run file.txt --verbose"), true);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["file.txt"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn no_subcommand_when_first_is_option() {
        let a = Args::parse_from(toks("--x 1"), true);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.usize_or("x", 0), 1);
    }

    // The public accessors exit the process on malformed input, so the
    // error paths are tested through the fallible cores they wrap.

    #[test]
    fn parsed_reports_malformed_scalars() {
        let a = Args::parse_from(toks("--n nope --k 3"), false);
        let err = a.parsed::<usize>("n").unwrap_err();
        assert!(err.contains("--n") && err.contains("nope"), "{err}");
        assert_eq!(a.parsed::<usize>("k").unwrap(), Some(3));
        assert_eq!(a.parsed::<usize>("missing").unwrap(), None);
    }

    #[test]
    fn list_reports_the_malformed_element() {
        let a = Args::parse_from(toks("--stds 0.25,oops,1.0"), false);
        let err = a.list::<f64>("stds", &[]).unwrap_err();
        assert!(err.contains("\"oops\"") && err.contains("--stds"), "{err}");
        let a = Args::parse_from(toks("--sizes 8,x"), false);
        assert!(a.list::<usize>("sizes", &[]).is_err());
        // Well-formed and absent lists still go through.
        let a = Args::parse_from(toks("--stds 0.25,0.5"), false);
        assert_eq!(a.list::<f64>("stds", &[]).unwrap(), vec![0.25, 0.5]);
        assert_eq!(a.list::<f64>("missing", &[1.0]).unwrap(), vec![1.0]);
    }
}
