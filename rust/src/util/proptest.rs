//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Coordinator invariants are checked against many randomly generated
//! configurations: a seeded [`Gen`] produces inputs, `check` runs the
//! property over `cases` seeds, and on failure it retries with simpler
//! inputs (halved sizes) to report a smaller counterexample, then panics
//! with the failing seed so the case is replayable.

use crate::util::rng::Pcg64;

/// Input generator handed to properties; wraps a seeded RNG with sized
/// sampling helpers. `size` shrinks during counterexample search.
pub struct Gen {
    pub rng: Pcg64,
    pub size: usize,
}

impl Gen {
    /// usize in [lo, hi] scaled so that larger `size` explores larger values.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = lo + ((hi - lo) * self.size.max(1)) / 100;
        let hi_eff = hi_eff.clamp(lo, hi);
        lo + self.rng.below((hi_eff - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.rng.fill_normal(&mut v, scale);
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `prop` over `cases` generated inputs. The property returns
/// `Err(message)` (or panics) to signal failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed = std::env::var("PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let mut gen = Gen {
            rng: Pcg64::new(seed, 17),
            size: 100,
        };
        if let Err(msg) = prop(&mut gen) {
            // Shrink attempt: same seed at reduced sizes; report the smallest
            // size that still fails.
            let mut smallest = (100usize, msg.clone());
            for size in [50usize, 25, 10, 5, 2, 1] {
                let mut g = Gen {
                    rng: Pcg64::new(seed, 17),
                    size,
                };
                if let Err(m) = prop(&mut g) {
                    smallest = (size, m);
                }
            }
            panic!(
                "property `{name}` failed (seed={seed}, smallest failing size={}): {}\n\
                 replay with PROPTEST_SEED={seed}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("sum-commutes", 50, |g| {
            count += 1;
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            prop_assert!((a + b - (b + a)).abs() < 1e-12, "a+b != b+a");
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always-small` failed")]
    fn failing_property_panics_with_seed() {
        check("always-small", 20, |g| {
            let n = g.usize_in(0, 1000);
            prop_assert!(n < 5, "n={n} too big");
            Ok(())
        });
    }

    #[test]
    fn gen_respects_bounds() {
        let mut g = Gen {
            rng: Pcg64::seeded(1),
            size: 100,
        };
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
        let xs = g.vec_f32(16, 1.0);
        assert_eq!(xs.len(), 16);
    }
}
