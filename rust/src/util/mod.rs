//! Infrastructure substrates built in-repo (the offline image carries only
//! the `xla` crate's dependency closure — no serde / clap / criterion /
//! proptest / rand; see DESIGN.md §1.4).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod plot;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod trace;
