//! Leveled stderr logging with run-relative timestamps.
//!
//! Tiny on purpose: the coordinator logs lifecycle events and per-flush
//! diagnostics; `HYBRID_SGD_LOG=debug|info|warn|off` selects the level
//! (default `info`). While a run is active its injected `Clock` is
//! registered here ([`set_run_clock`]), so log timestamps share the run's
//! timebase — real offsets under the trainer, *virtual* time under the
//! simulator — and line up with the metric series and trace exports.
//! Outside a run, timestamps fall back to seconds since process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("HYBRID_SGD_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Force a level (tests / quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

type RunClock = Arc<dyn Fn() -> Duration + Send + Sync>;

/// The active run's clock, if any: (registration token, reader). Tokens
/// make un-registration race-safe when runs overlap (tests run trainers
/// concurrently): dropping a guard only clears the entry it installed.
static RUN_CLOCK: Mutex<Option<(u64, RunClock)>> = Mutex::new(None);
static RUN_CLOCK_TOKEN: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Route log timestamps through a run's injected clock until the returned
/// guard drops. A later registration displaces an earlier one (the newest
/// run wins); the displaced guard's drop is then a no-op.
pub fn set_run_clock(f: RunClock) -> RunClockGuard {
    let token = RUN_CLOCK_TOKEN.fetch_add(1, Ordering::Relaxed);
    *RUN_CLOCK.lock().unwrap() = Some((token, f));
    RunClockGuard { token }
}

/// Clears the [`set_run_clock`] registration on drop (if still current).
pub struct RunClockGuard {
    token: u64,
}

impl Drop for RunClockGuard {
    fn drop(&mut self) {
        let mut slot = RUN_CLOCK.lock().unwrap();
        if matches!(*slot, Some((t, _)) if t == self.token) {
            *slot = None;
        }
    }
}

pub fn elapsed_secs() -> f64 {
    let run = RUN_CLOCK.lock().unwrap().as_ref().map(|(_, f)| Arc::clone(f));
    match run {
        // Call outside the lock: the reader may be arbitrary user code.
        Some(f) => f().as_secs_f64(),
        None => START.get_or_init(Instant::now).elapsed().as_secs_f64(),
    }
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if (l as u8) <= level() && l != Level::Off {
        eprintln!("[{:>9.3}s {:<5} {}] {}", elapsed_secs(), format!("{l:?}").to_lowercase(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $module, format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $module, format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $module, format_args!($($fmt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug > Level::Info);
        assert!(Level::Info > Level::Warn);
        assert!(Level::Warn > Level::Off);
    }

    #[test]
    fn set_level_silences() {
        set_level(Level::Off);
        log(Level::Info, "test", format_args!("should not print"));
        set_level(Level::Info);
    }

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed_secs();
        let b = elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn run_clock_overrides_then_restores_the_wall_offset() {
        {
            let _g = set_run_clock(Arc::new(|| Duration::from_secs(1234)));
            assert_eq!(elapsed_secs(), 1234.0);
        }
        // Guard dropped: back to the (small) process-start offset.
        assert!(elapsed_secs() < 1234.0);
        // A newer registration displaces an older one, and the older
        // guard's late drop must not clear the newer clock.
        let g1 = set_run_clock(Arc::new(|| Duration::from_secs(1)));
        let g2 = set_run_clock(Arc::new(|| Duration::from_secs(2)));
        assert_eq!(elapsed_secs(), 2.0);
        drop(g1);
        assert_eq!(elapsed_secs(), 2.0);
        drop(g2);
        assert!(elapsed_secs() < 1.0 || elapsed_secs() != 2.0);
    }
}
