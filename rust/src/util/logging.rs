//! Leveled stderr logging with wall-clock offsets.
//!
//! Tiny on purpose: the coordinator logs lifecycle events and per-flush
//! diagnostics; `HYBRID_SGD_LOG=debug|info|warn|off` selects the level
//! (default `info`). Timestamps are seconds since process start so traces
//! from a training run line up with the metric series.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("HYBRID_SGD_LOG").as_deref() {
        Ok("off") => Level::Off,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Force a level (tests / quiet benches).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn elapsed_secs() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments) {
    if (l as u8) <= level() && l != Level::Off {
        eprintln!("[{:>9.3}s {:<5} {}] {}", elapsed_secs(), format!("{l:?}").to_lowercase(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $module, format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $module, format_args!($($fmt)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($fmt:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $module, format_args!($($fmt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Debug > Level::Info);
        assert!(Level::Info > Level::Warn);
        assert!(Level::Warn > Level::Off);
    }

    #[test]
    fn set_level_silences() {
        set_level(Level::Off);
        log(Level::Info, "test", format_args!("should not print"));
        set_level(Level::Info);
    }

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed_secs();
        let b = elapsed_secs();
        assert!(b >= a);
    }
}
