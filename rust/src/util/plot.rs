//! ASCII line charts.
//!
//! The figure-regeneration pipeline has no plotting library, so figures are
//! emitted as (a) CSV files under `results/` and (b) terminal ASCII charts
//! rendered by this module — enough to eyeball the paper's curve *shapes*
//! (who leads, where curves cross).

/// One labelled curve. Each curve gets a distinct glyph.
pub struct Curve<'a> {
    pub label: &'a str,
    pub t: &'a [f64],
    pub v: &'a [f64],
}

const GLYPHS: [char; 6] = ['*', '+', 'o', 'x', '#', '@'];

/// Render curves into a `width` x `height` character grid with axes and a
/// legend. Curves are linearly mapped into the shared bounding box.
pub fn render(title: &str, curves: &[Curve], width: usize, height: usize) -> String {
    assert!(width >= 16 && height >= 4);
    let (mut tmin, mut tmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut vmin, mut vmax) = (f64::INFINITY, f64::NEG_INFINITY);
    for c in curves {
        for &t in c.t {
            tmin = tmin.min(t);
            tmax = tmax.max(t);
        }
        for &v in c.v {
            if v.is_finite() {
                vmin = vmin.min(v);
                vmax = vmax.max(v);
            }
        }
    }
    if !tmin.is_finite() || !vmin.is_finite() {
        return format!("{title}\n  (no data)\n");
    }
    if (vmax - vmin).abs() < 1e-12 {
        vmax = vmin + 1.0;
    }
    if (tmax - tmin).abs() < 1e-12 {
        tmax = tmin + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (ci, c) in curves.iter().enumerate() {
        let g = GLYPHS[ci % GLYPHS.len()];
        for (&t, &v) in c.t.iter().zip(c.v) {
            if !v.is_finite() {
                continue;
            }
            let x = ((t - tmin) / (tmax - tmin) * (width - 1) as f64).round() as usize;
            let y = ((v - vmin) / (vmax - vmin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - y.min(height - 1);
            grid[row][x.min(width - 1)] = g;
        }
    }

    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    for (i, row) in grid.iter().enumerate() {
        let axis_val = vmax - (vmax - vmin) * i as f64 / (height - 1) as f64;
        out.push_str(&format!("{axis_val:>10.4} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}  {:<w$.2}{:>.2}\n",
        "t(s)",
        tmin,
        tmax,
        w = width - 4
    ));
    for (ci, c) in curves.iter().enumerate() {
        out.push_str(&format!(
            "{:>12} {} = {}\n",
            "",
            GLYPHS[ci % GLYPHS.len()],
            c.label
        ));
    }
    out
}

/// Horizontal bar chart for (label, value) pairs — used by the table figures
/// (Fig. 8–10 plot per-configuration averages).
pub fn bars(title: &str, items: &[(String, f64)], width: usize) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if items.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let maxabs = items
        .iter()
        .map(|(_, v)| v.abs())
        .fold(f64::MIN_POSITIVE, f64::max);
    let lw = items.iter().map(|(l, _)| l.len()).max().unwrap();
    for (label, v) in items {
        let n = ((v.abs() / maxabs) * width as f64).round() as usize;
        let bar: String = std::iter::repeat(if *v >= 0.0 { '█' } else { '░' })
            .take(n.max(1))
            .collect();
        out.push_str(&format!("  {label:>lw$} | {bar} {v:.4}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_single_curve() {
        let t: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let v: Vec<f64> = t.iter().map(|x| (x / 5.0).sin()).collect();
        let s = render(
            "sine",
            &[Curve {
                label: "sin",
                t: &t,
                v: &v,
            }],
            60,
            12,
        );
        assert!(s.contains("sine"));
        assert!(s.contains("* = sin"));
        assert!(s.lines().count() > 12);
    }

    #[test]
    fn handles_empty_and_flat() {
        let s = render("empty", &[], 20, 5);
        assert!(s.contains("no data"));
        let t = [0.0, 1.0];
        let v = [2.0, 2.0];
        let s = render(
            "flat",
            &[Curve {
                label: "c",
                t: &t,
                v: &v,
            }],
            20,
            5,
        );
        assert!(s.contains("flat"));
    }

    #[test]
    fn bar_chart() {
        let items = vec![("a".to_string(), 1.0), ("bb".to_string(), -0.5)];
        let s = bars("diffs", &items, 20);
        assert!(s.contains('█'));
        assert!(s.contains('░'));
    }
}
