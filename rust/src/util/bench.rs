//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Bench targets are built with `harness = false` and drive this module:
//! warm-up, timed iterations until a wall-clock budget or iteration cap,
//! mean / σ / p50 / p99, and throughput reporting. `BENCH_QUICK=1` shrinks
//! budgets for CI-style smoke runs.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean_ns / 1e9
    }

    /// items/sec given the per-iteration item count.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_secs()
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner. Collects results for a final summary table.
pub struct Bencher {
    pub results: Vec<BenchResult>,
    budget: Duration,
    min_iters: u64,
    max_iters: u64,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        let quick = std::env::var("BENCH_QUICK").map_or(false, |v| v == "1");
        Bencher {
            results: Vec::new(),
            budget: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(2)
            },
            min_iters: 5,
            max_iters: if quick { 200 } else { 100_000 },
        }
    }

    /// Override the per-case time budget.
    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f` repeatedly; `f` should perform one logical operation.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warm-up: a few calls, not timed.
        for _ in 0..3.min(self.min_iters) {
            f();
        }
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.budget || (samples_ns.len() as u64) < self.min_iters)
            && (samples_ns.len() as u64) < self.max_iters
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let mean = crate::util::stats::mean(&samples_ns);
        let std = crate::util::stats::std_dev(&samples_ns);
        let p50 = crate::util::stats::percentile(&samples_ns, 50.0);
        let p99 = crate::util::stats::percentile(&samples_ns, 99.0);
        let res = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: mean,
            std_ns: std,
            p50_ns: p50,
            p99_ns: p99,
        };
        println!(
            "  {name:<44} {:>10}/iter  (p50 {:>10}, p99 {:>10}, n={})",
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p99),
            res.iters
        );
        self.results.push(res.clone());
        res
    }

    /// Print a closing summary.
    pub fn summary(&self) {
        println!("\n== benchmark summary ({} cases) ==", self.results.len());
        for r in &self.results {
            println!(
                "  {:<44} mean {:>10}  ±{:>10}",
                r.name,
                fmt_ns(r.mean_ns),
                fmt_ns(r.std_ns)
            );
        }
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("BENCH_QUICK", "1");
        let mut b = Bencher::new().with_budget(Duration::from_millis(20));
        let r = b.bench("noop-sum", || {
            let s: u64 = black_box((0..100u64).sum());
            black_box(s);
        });
        assert!(r.iters >= 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns);
    }

    #[test]
    fn formats_scale() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2e9).contains(" s"));
    }
}
