//! Minimal JSON parser / writer.
//!
//! The offline image has no `serde`, so the framework carries its own JSON
//! implementation for the two places JSON crosses a boundary: reading
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) and dumping
//! metric series / experiment reports under `results/`.
//!
//! Scope: full JSON data model, UTF-8, `\uXXXX` escapes (incl. surrogate
//! pairs), no trailing commas / comments. Numbers parse as f64 — manifest
//! integers fit losslessly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest fields are mandatory.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed convenience: required string field.
    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a string"))?
            .to_string())
    }

    /// Typed convenience: required numeric field as usize.
    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a number"))
    }

    /// Typed convenience: required numeric field as f64.
    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a number"))
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- serialisation ------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

/// The one number-formatting rule, shared by the tree writer and the
/// streaming writer: integral values within exact-i64 range print without a
/// fraction, everything else uses Rust's shortest-roundtrip `{}` — so a
/// value survives print → parse bit-for-bit (metrics replay relies on it).
fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- streaming writer -------------------------------------------------

/// Forward-only incremental JSON writer (the Chic `Utf8JsonWriter`
/// pattern): containers are opened and appended to without ever
/// materialising a [`Json`] tree, so a multi-hour metrics stream costs one
/// line of buffer at a time instead of the whole series in memory.
///
/// Output is compact (no whitespace) and uses the same escaping and
/// shortest-roundtrip number formatting as [`Json::to_string_compact`], so
/// everything it emits parses back bit-for-bit via [`parse`].
///
/// Structural misuse — a value where a key is required, `end_object` inside
/// an array, a second top-level value — is a programmer error and panics;
/// this type never sees untrusted input.
#[derive(Debug, Default)]
pub struct Utf8JsonWriter {
    out: String,
    /// One frame per open container: `b'{'` or `b'['`, with the number of
    /// elements emitted so far (for comma placement).
    stack: Vec<(u8, usize)>,
    /// A key has been written and its value has not.
    key_pending: bool,
    /// A complete top-level value has been emitted.
    done: bool,
}

impl Utf8JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Finish and take the document. Panics if a container is still open.
    pub fn finish(self) -> String {
        assert!(self.stack.is_empty(), "unclosed container in JSON writer");
        assert!(self.done, "empty JSON writer finished");
        self.out
    }

    /// Comma/colon bookkeeping before any value or container start.
    fn pre_value(&mut self) {
        match self.stack.last_mut() {
            Some((b'{', _)) => {
                assert!(self.key_pending, "object value without a key");
                self.key_pending = false;
            }
            Some((b'[', n)) => {
                if *n > 0 {
                    self.out.push(',');
                }
                *n += 1;
            }
            None => {
                assert!(!self.done, "second top-level JSON value");
                self.done = true;
            }
        }
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        match self.stack.last_mut() {
            Some((b'{', n)) => {
                assert!(!self.key_pending, "two keys in a row");
                if *n > 0 {
                    self.out.push(',');
                }
                *n += 1;
            }
            _ => panic!("key outside an object"),
        }
        write_escaped(&mut self.out, k);
        self.out.push(':');
        self.key_pending = true;
        self
    }

    pub fn begin_object(&mut self) -> &mut Self {
        self.pre_value();
        self.stack.push((b'{', 0));
        self.out.push('{');
        self
    }

    pub fn end_object(&mut self) -> &mut Self {
        assert!(!self.key_pending, "key without a value");
        match self.stack.pop() {
            Some((b'{', _)) => self.out.push('}'),
            _ => panic!("end_object without a matching begin_object"),
        }
        self
    }

    pub fn begin_array(&mut self) -> &mut Self {
        self.pre_value();
        self.stack.push((b'[', 0));
        self.out.push('[');
        self
    }

    pub fn end_array(&mut self) -> &mut Self {
        match self.stack.pop() {
            Some((b'[', _)) => self.out.push(']'),
            _ => panic!("end_array without a matching begin_array"),
        }
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.pre_value();
        write_escaped(&mut self.out, s);
        self
    }

    pub fn num(&mut self, n: f64) -> &mut Self {
        self.pre_value();
        write_num(&mut self.out, n);
        self
    }

    pub fn bool(&mut self, b: bool) -> &mut Self {
        self.pre_value();
        self.out.push_str(if b { "true" } else { "false" });
        self
    }

    pub fn null(&mut self) -> &mut Self {
        self.pre_value();
        self.out.push_str("null");
        self
    }

    /// Splice an already-built [`Json`] value (compact form).
    pub fn value(&mut self, v: &Json) -> &mut Self {
        self.pre_value();
        self.out.push_str(&v.to_string_compact());
        self
    }
}

// ---- lazy path scanning -----------------------------------------------

/// One step of a scan path: an object key or an array index.
#[derive(Debug, PartialEq, Eq)]
enum Seg {
    Key(String),
    Index(usize),
}

/// Parse `a.b[2].c` into segments. A leading index (`[0].x`) is allowed.
fn parse_path(path: &str) -> anyhow::Result<Vec<Seg>> {
    let mut segs = Vec::new();
    for part in path.split('.') {
        let mut rest = part;
        // Key part before any `[`, then zero or more `[n]` suffixes.
        let key_end = rest.find('[').unwrap_or(rest.len());
        let key = &rest[..key_end];
        if !key.is_empty() {
            segs.push(Seg::Key(key.to_string()));
        } else if key_end != 0 || part.is_empty() {
            anyhow::bail!("empty segment in path `{path}`");
        }
        rest = &rest[key_end..];
        while let Some(stripped) = rest.strip_prefix('[') {
            let close = stripped
                .find(']')
                .ok_or_else(|| anyhow::anyhow!("unclosed `[` in path `{path}`"))?;
            let idx: usize = stripped[..close]
                .parse()
                .map_err(|_| anyhow::anyhow!("bad index in path `{path}`"))?;
            segs.push(Seg::Index(idx));
            rest = &stripped[close + 1..];
        }
        if !rest.is_empty() {
            anyhow::bail!("trailing garbage `{rest}` in path `{path}`");
        }
    }
    Ok(segs)
}

/// Lazily extract the value at `path` (e.g. `"shards[2].k"`) from a JSON
/// document — the ADR-002 pattern: tokenize forward, [`Parser::skip_value`]
/// past everything off-path, and build a [`Json`] tree only for the target
/// subtree. Never parses past the end of the match, so pulling one field
/// out of a large status document stays O(prefix), not O(document).
///
/// Returns `Ok(None)` when the path does not exist (missing key, index out
/// of range, or a path step applied to the wrong container kind); `Err`
/// only on malformed JSON along the scanned prefix or a malformed path.
pub fn scan_path(input: &str, path: &str) -> anyhow::Result<Option<Json>> {
    let segs = parse_path(path)?;
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    'seg: for seg in &segs {
        p.skip_ws();
        match seg {
            Seg::Key(want) => {
                if p.peek() != Some(b'{') {
                    return Ok(None);
                }
                p.pos += 1;
                p.skip_ws();
                if p.peek() == Some(b'}') {
                    return Ok(None);
                }
                loop {
                    p.skip_ws();
                    let k = p.string()?;
                    p.skip_ws();
                    p.expect(b':')?;
                    if k == *want {
                        continue 'seg; // parser now sits at the value
                    }
                    p.skip_value()?;
                    p.skip_ws();
                    match p.bump()? {
                        b',' => continue,
                        b'}' => return Ok(None),
                        c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
                    }
                }
            }
            Seg::Index(want) => {
                if p.peek() != Some(b'[') {
                    return Ok(None);
                }
                p.pos += 1;
                p.skip_ws();
                if p.peek() == Some(b']') {
                    return Ok(None);
                }
                let mut i = 0usize;
                loop {
                    if i == *want {
                        continue 'seg;
                    }
                    p.skip_value()?;
                    p.skip_ws();
                    match p.bump()? {
                        b',' => i += 1,
                        b']' => return Ok(None),
                        c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
                    }
                }
            }
        }
    }
    p.value().map(Some)
}

/// Parse a JSON document. Errors carry a byte offset for debugging.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected `{}` at byte {}, got `{}`",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(a)),
                c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                        } else {
                            hi as u32
                        };
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("invalid \\u escape"))?,
                        );
                    }
                    c => anyhow::bail!("invalid escape `\\{}`", c as char),
                },
                _ => {
                    // Re-decode UTF-8 starting at this byte.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        anyhow::bail!("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    /// Skip one value without building a tree — the lazy-scan workhorse.
    /// Structural (container punctuation, string escapes) errors are
    /// caught; scalar contents are skipped byte-wise, their validation
    /// deferred to whoever eventually parses them.
    fn skip_value(&mut self) -> anyhow::Result<()> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.skip_string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_value()?;
                    self.skip_ws();
                    match self.bump()? {
                        b',' => continue,
                        b'}' => return Ok(()),
                        c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_value()?;
                    self.skip_ws();
                    match self.bump()? {
                        b',' => continue,
                        b']' => return Ok(()),
                        c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
                    }
                }
            }
            Some(b'"') => self.skip_string(),
            Some(b't') => self.lit("true", Json::Null).map(|_| ()),
            Some(b'f') => self.lit("false", Json::Null).map(|_| ()),
            Some(b'n') => self.lit("null", Json::Null).map(|_| ()),
            Some(_) => {
                let start = self.pos;
                if self.peek() == Some(b'-') {
                    self.pos += 1;
                }
                while matches!(
                    self.peek(),
                    Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                ) {
                    self.pos += 1;
                }
                if self.pos == start {
                    anyhow::bail!("unexpected byte at {}", start);
                }
                Ok(())
            }
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    /// Skip a string without decoding it. Byte-wise is UTF-8-safe:
    /// continuation bytes are ≥ 0x80, so they can never alias `"` or `\`.
    fn skip_string(&mut self) -> anyhow::Result<()> {
        self.expect(b'"')?;
        loop {
            match self.bump()? {
                b'"' => return Ok(()),
                b'\\' => {
                    self.bump()?;
                }
                _ => {}
            }
        }
    }

    fn hex4(&mut self) -> anyhow::Result<u16> {
        let mut v = 0u16;
        for _ in 0..4 {
            let b = self.bump()?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| anyhow::anyhow!("invalid hex digit"))?;
            v = v * 16 + d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number `{s}` at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
        assert!(parse("nul").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn typed_field_helpers() {
        let v = parse(r#"{"name": "mlp", "batch": 32}"#).unwrap();
        assert_eq!(v.str_field("name").unwrap(), "mlp");
        assert_eq!(v.usize_field("batch").unwrap(), 32);
        assert!(v.str_field("missing").is_err());
        assert!(v.str_field("batch").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        let v = Json::Num(32.0);
        assert_eq!(v.to_string_compact(), "32");
    }

    // ---- streaming writer ------------------------------------------

    #[test]
    fn streaming_writer_builds_nested_documents() {
        let mut w = Utf8JsonWriter::new();
        w.begin_object();
        w.key("s").str("test_loss");
        w.key("t").num(1.5);
        w.key("v").num(-3.0);
        w.key("tags").begin_array().str("a\nb").num(7.0).end_array();
        w.key("inner").begin_object().key("ok").bool(true).end_object();
        w.key("none").null();
        w.end_object();
        let s = w.finish();
        let v = parse(&s).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("test_loss"));
        assert_eq!(v.get("t").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("tags").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("inner").unwrap().get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
    }

    #[test]
    fn streaming_writer_scalar_and_empty_containers() {
        let mut w = Utf8JsonWriter::new();
        w.num(42.0);
        assert_eq!(w.finish(), "42");
        let mut w = Utf8JsonWriter::new();
        w.begin_array().end_array();
        assert_eq!(w.finish(), "[]");
        let mut w = Utf8JsonWriter::new();
        w.begin_object().end_object();
        assert_eq!(w.finish(), "{}");
    }

    #[test]
    #[should_panic(expected = "object value without a key")]
    fn streaming_writer_rejects_value_without_key() {
        let mut w = Utf8JsonWriter::new();
        w.begin_object().num(1.0);
    }

    #[test]
    #[should_panic(expected = "unclosed container")]
    fn streaming_writer_rejects_unclosed_container() {
        let mut w = Utf8JsonWriter::new();
        w.begin_array();
        w.finish();
    }

    // ---- lazy path scanning ----------------------------------------

    #[test]
    fn scan_path_extracts_nested_values() {
        let doc = r#"{"a": {"b": [10, {"c": "hit"}, 30]}, "z": [1,2]}"#;
        assert_eq!(
            scan_path(doc, "a.b[1].c").unwrap(),
            Some(Json::Str("hit".into()))
        );
        assert_eq!(scan_path(doc, "a.b[2]").unwrap(), Some(Json::Num(30.0)));
        assert_eq!(scan_path(doc, "z[0]").unwrap(), Some(Json::Num(1.0)));
        assert_eq!(
            scan_path(doc, "a.b").unwrap().unwrap().as_arr().unwrap().len(),
            3
        );
        // Missing key, out-of-range index, wrong container kind: None.
        assert_eq!(scan_path(doc, "a.x").unwrap(), None);
        assert_eq!(scan_path(doc, "a.b[3]").unwrap(), None);
        assert_eq!(scan_path(doc, "z.k").unwrap(), None);
        assert_eq!(scan_path(doc, "a[0]").unwrap(), None);
    }

    #[test]
    fn scan_path_handles_escapes_and_stops_early() {
        // Keys and values with \uXXXX escapes (incl. a surrogate pair).
        let doc = r#"{"ké": "café", "emoji": "😀", "after": 1}"#;
        assert_eq!(
            scan_path(doc, "ké").unwrap(),
            Some(Json::Str("café".into()))
        );
        assert_eq!(
            scan_path(doc, "emoji").unwrap(),
            Some(Json::Str("😀".into()))
        );
        // Lazy: garbage *after* the matched value is never scanned.
        let doc = r#"{"hit": 7, "rest": <not json>"#;
        assert_eq!(scan_path(doc, "hit").unwrap(), Some(Json::Num(7.0)));
        // ...but structural garbage before the match is an error.
        assert!(scan_path(r#"{"a" 1, "hit": 7}"#, "hit").is_err());
    }

    #[test]
    fn scan_path_rejects_malformed_paths() {
        assert!(scan_path("{}", "").is_err());
        assert!(scan_path("{}", "a..b").is_err());
        assert!(scan_path("{}", "a[").is_err());
        assert!(scan_path("{}", "a[x]").is_err());
        assert!(scan_path("{}", "a[0]b").is_err());
    }

    // ---- property tests --------------------------------------------

    use crate::util::rng::Pcg64;

    /// Random string over a troublesome alphabet: quotes, backslashes,
    /// control characters (printed as \uXXXX), multibyte and astral chars.
    fn gen_string(rng: &mut Pcg64) -> String {
        const ALPHABET: &[char] = &[
            'a', 'b', 'Z', '0', ' ', '"', '\\', '\n', '\r', '\t', '\u{1}', '\u{1f}', 'é', 'ß',
            '中', '😀', '/',
        ];
        let len = rng.below(8) as usize;
        (0..len)
            .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize])
            .collect()
    }

    fn gen_num(rng: &mut Pcg64) -> f64 {
        match rng.below(4) {
            0 => rng.below(2000) as f64 - 1000.0,
            1 => rng.uniform(-1e3, 1e3),
            2 => rng.uniform(-1.0, 1.0) * 1e18,
            _ => f64::from_bits(rng.next_u64() >> 2), // finite, weird mantissas
        }
    }

    /// Random Json tree. Object keys are path-safe (`k0`, `k1`, ...) and
    /// unique per object so scan-vs-get agreement is well-defined.
    fn gen_json(rng: &mut Pcg64, depth: usize) -> Json {
        let scalar = depth == 0 || rng.chance(0.4);
        if scalar {
            match rng.below(4) {
                0 => Json::Null,
                1 => Json::Bool(rng.chance(0.5)),
                2 => Json::Num(gen_num(rng)),
                _ => Json::Str(gen_string(rng)),
            }
        } else if rng.chance(0.5) {
            let n = rng.below(4) as usize;
            Json::Arr((0..n).map(|_| gen_json(rng, depth - 1)).collect())
        } else {
            let n = rng.below(4) as usize;
            Json::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }

    /// Emit a Json tree through the streaming writer, leaf by leaf.
    fn stream_out(w: &mut Utf8JsonWriter, v: &Json) {
        match v {
            Json::Null => {
                w.null();
            }
            Json::Bool(b) => {
                w.bool(*b);
            }
            Json::Num(n) => {
                w.num(*n);
            }
            Json::Str(s) => {
                w.str(s);
            }
            Json::Arr(a) => {
                w.begin_array();
                for x in a {
                    stream_out(w, x);
                }
                w.end_array();
            }
            Json::Obj(m) => {
                w.begin_object();
                for (k, x) in m {
                    w.key(k);
                    stream_out(w, x);
                }
                w.end_object();
            }
        }
    }

    #[test]
    fn prop_streaming_writer_output_parses_back_equal() {
        let mut rng = Pcg64::seeded(0xbeef);
        for _ in 0..300 {
            let v = gen_json(&mut rng, 5);
            let mut w = Utf8JsonWriter::new();
            stream_out(&mut w, &v);
            let s = w.finish();
            let back = parse(&s).unwrap_or_else(|e| panic!("unparseable {s:?}: {e}"));
            assert_eq!(back, v, "doc {s:?}");
            // And the streaming output is byte-identical to the tree writer.
            assert_eq!(s, v.to_string_compact());
        }
    }

    /// Collect every (path, value) pair reachable with the scan syntax.
    fn all_paths<'a>(v: &'a Json, prefix: &str, out: &mut Vec<(String, &'a Json)>) {
        if !prefix.is_empty() {
            out.push((prefix.to_string(), v));
        }
        match v {
            Json::Arr(a) => {
                for (i, x) in a.iter().enumerate() {
                    all_paths(x, &format!("{prefix}[{i}]"), out);
                }
            }
            Json::Obj(m) => {
                for (k, x) in m {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    all_paths(x, &p, out);
                }
            }
            _ => {}
        }
    }

    #[test]
    fn prop_scan_path_agrees_with_full_parse() {
        let mut rng = Pcg64::seeded(0xcafe);
        let mut nontrivial = 0;
        for round in 0..200 {
            let v = gen_json(&mut rng, 6);
            // Alternate pretty/compact so whitespace handling is covered.
            let doc = if round % 2 == 0 {
                v.to_string_pretty()
            } else {
                v.to_string_compact()
            };
            let mut paths = Vec::new();
            all_paths(&v, "", &mut paths);
            nontrivial += paths.len();
            for (path, expect) in &paths {
                let got = scan_path(&doc, path)
                    .unwrap_or_else(|e| panic!("scan {path:?} of {doc:?}: {e}"));
                assert_eq!(got.as_ref(), Some(*expect), "path {path:?} in {doc:?}");
            }
            // Paths that miss must come back None, not Err.
            assert_eq!(scan_path(&doc, "definitely_absent[9].x").unwrap(), None);
        }
        assert!(nontrivial > 500, "generator too timid: {nontrivial}");
    }
}
