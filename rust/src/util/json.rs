//! Minimal JSON parser / writer.
//!
//! The offline image has no `serde`, so the framework carries its own JSON
//! implementation for the two places JSON crosses a boundary: reading
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) and dumping
//! metric series / experiment reports under `results/`.
//!
//! Scope: full JSON data model, UTF-8, `\uXXXX` escapes (incl. surrogate
//! pairs), no trailing commas / comments. Numbers parse as f64 — manifest
//! integers fit losslessly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ---- accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest fields are mandatory.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Typed convenience: required string field.
    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a string"))?
            .to_string())
    }

    /// Typed convenience: required numeric field as usize.
    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|n| n as usize)
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a number"))
    }

    /// Typed convenience: required numeric field as f64.
    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key `{key}` is not a number"))
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // ---- serialisation ------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Errors carry a byte offset for debugging.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected `{}` at byte {}, got `{}`",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => anyhow::bail!("expected `,` or `}}`, got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(a)),
                c => anyhow::bail!("expected `,` or `]`, got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let hi = self.hex4()?;
                        let code = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                        } else {
                            hi as u32
                        };
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow::anyhow!("invalid \\u escape"))?,
                        );
                    }
                    c => anyhow::bail!("invalid escape `\\{}`", c as char),
                },
                _ => {
                    // Re-decode UTF-8 starting at this byte.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        anyhow::bail!("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> anyhow::Result<u16> {
        let mut v = 0u16;
        for _ in 0..4 {
            let b = self.bump()?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| anyhow::anyhow!("invalid hex digit"))?;
            v = v * 16 + d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number `{s}` at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}x").is_err());
        assert!(parse("nul").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn typed_field_helpers() {
        let v = parse(r#"{"name": "mlp", "batch": 32}"#).unwrap();
        assert_eq!(v.str_field("name").unwrap(), "mlp");
        assert_eq!(v.usize_field("batch").unwrap(), 32);
        assert!(v.str_field("missing").is_err());
        assert!(v.str_field("batch").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        let v = Json::Num(32.0);
        assert_eq!(v.to_string_compact(), "32");
    }
}
