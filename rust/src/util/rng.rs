//! Deterministic pseudo-random number generation.
//!
//! The offline image has no `rand` crate, so we implement the generators the
//! framework needs: a PCG-64 (XSL-RR) stream generator with cheap splitting
//! (every worker / dataset / round derives an independent stream from a root
//! seed), uniform and Gaussian sampling, shuffling, and common init
//! distributions used by `runtime::init`.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Passes practrand at the sizes we care about and is the same family JAX's
/// host-side seeding uses. Each `Pcg64` is an independent stream selected by
/// the odd `inc` value.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (split). Deterministic in
    /// (self-state, tag); advances `self` once.
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::new(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's nearly-divisionless bounded sampling.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (no cached spare: branch-free hot loops
    /// matter more than halving the trig count here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.normal() as f32) * std;
        }
    }

    /// Fill a slice with U(-limit, limit) f32 values.
    pub fn fill_uniform_sym(&mut self, out: &mut [f32], limit: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(-(limit as f64), limit as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Gamma(shape, 1) via the Marsaglia–Tsang squeeze (shape > 0).
    /// Shapes below 1 use the boost `Gamma(shape + 1) · U^(1/shape)`.
    /// Feeds the Dirichlet draws behind `partition=dirichlet:<alpha>`.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0 && shape.is_finite());
        if shape < 1.0 {
            let g = self.gamma(shape + 1.0);
            let u = loop {
                let u = self.next_f64();
                if u > 0.0 {
                    break u;
                }
            };
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x * x * x * x {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seeded(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::seeded(1);
        let mut x = root.split(0);
        let mut y = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| y.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = Pcg64::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_moments() {
        // Gamma(k, 1) has mean k and variance k — check both branches of
        // the sampler (shape ≥ 1 and the sub-1 boost).
        for &shape in &[0.3f64, 2.5] {
            let mut rng = Pcg64::seeded(13);
            let n = 50_000;
            let mut sum = 0.0;
            let mut sum2 = 0.0;
            for _ in 0..n {
                let v = rng.gamma(shape);
                assert!(v >= 0.0 && v.is_finite());
                sum += v;
                sum2 += v * v;
            }
            let mean = sum / n as f64;
            let var = sum2 / n as f64 - mean * mean;
            assert!((mean - shape).abs() < 0.05, "shape {shape}: mean {mean}");
            assert!((var - shape).abs() < 0.15, "shape {shape}: var {var}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
