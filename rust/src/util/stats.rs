//! Numeric summaries and time-series utilities.
//!
//! Supports the experiment pipeline: metric series are recorded against
//! wall-clock time per round, interpolated onto a common grid, averaged
//! across rounds, and differenced between algorithms ("difference ... averaged
//! over the entire training interval" — the paper's table metric).

/// Mean of a slice. Returns 0 for the empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator). 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    // total_cmp (NaN sorts above +inf) matches the NaN policy in
    // compress.rs: a stray NaN sample must not panic the report path.
    let mut s: Vec<f64> = xs.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
    }
}

/// A sampled time series: strictly increasing times with one value each.
#[derive(Clone, Debug, Default)]
pub struct Series {
    pub t: Vec<f64>,
    pub v: Vec<f64>,
}

/// Equality is *bitwise* per sample (`f64::to_bits`), so `NaN == NaN` and
/// replays of pathological (diverging) runs still compare equal — the
/// simulator's reproducibility tests rely on this.
impl PartialEq for Series {
    fn eq(&self, other: &Self) -> bool {
        let bits = |xs: &[f64], ys: &[f64]| {
            xs.len() == ys.len()
                && xs
                    .iter()
                    .zip(ys)
                    .all(|(x, y)| x.to_bits() == y.to_bits())
        };
        bits(&self.t, &other.t) && bits(&self.v, &other.v)
    }
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: f64, v: f64) {
        debug_assert!(self.t.last().map_or(true, |&last| t >= last));
        self.t.push(t);
        self.v.push(v);
    }

    pub fn len(&self) -> usize {
        self.t.len()
    }

    pub fn is_empty(&self) -> bool {
        self.t.is_empty()
    }

    /// Piecewise-linear interpolation at time `t`, clamped to the endpoints
    /// (constant extrapolation — matches how a monitor would report the last
    /// known metric).
    pub fn at(&self, t: f64) -> f64 {
        assert!(!self.is_empty(), "interpolating empty series");
        if t <= self.t[0] {
            return self.v[0];
        }
        if t >= *self.t.last().unwrap() {
            return *self.v.last().unwrap();
        }
        // binary search for the bracketing segment
        let mut lo = 0;
        let mut hi = self.t.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.t[mid] <= t {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (t0, t1) = (self.t[lo], self.t[hi]);
        let (v0, v1) = (self.v[lo], self.v[hi]);
        if t1 == t0 {
            v0
        } else {
            v0 + (v1 - v0) * (t - t0) / (t1 - t0)
        }
    }

    /// Resample onto an explicit grid.
    pub fn resample(&self, grid: &[f64]) -> Vec<f64> {
        grid.iter().map(|&t| self.at(t)).collect()
    }
}

/// Uniform grid of `n` points over [0, horizon].
pub fn time_grid(horizon: f64, n: usize) -> Vec<f64> {
    assert!(n >= 2);
    (0..n)
        .map(|i| horizon * i as f64 / (n - 1) as f64)
        .collect()
}

/// Average several same-length sample vectors point-wise.
pub fn average_rows(rows: &[Vec<f64>]) -> Vec<f64> {
    assert!(!rows.is_empty());
    let n = rows[0].len();
    let mut out = vec![0.0; n];
    for row in rows {
        assert_eq!(row.len(), n);
        for (o, x) in out.iter_mut().zip(row) {
            *o += x;
        }
    }
    for o in &mut out {
        *o /= rows.len() as f64;
    }
    out
}

/// The paper's table statistic: mean over the grid of `(ours - baseline)`.
pub fn interval_mean_diff(ours: &[f64], baseline: &[f64]) -> f64 {
    assert_eq!(ours.len(), baseline.len());
    mean(&ours
        .iter()
        .zip(baseline)
        .map(|(a, b)| a - b)
        .collect::<Vec<_>>())
}

/// Online mean/max/count accumulator for hot-path counters.
#[derive(Clone, Debug, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Accum {
    pub fn add(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // One poisoned ack-latency sample must not panic the p99 report.
        // total_cmp sorts NaN above +inf, so low/mid percentiles of a
        // mostly-finite sample stay finite.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn series_interpolation() {
        let mut s = Series::new();
        s.push(0.0, 0.0);
        s.push(2.0, 4.0);
        s.push(4.0, 0.0);
        assert_eq!(s.at(1.0), 2.0);
        assert_eq!(s.at(3.0), 2.0);
        assert_eq!(s.at(-1.0), 0.0); // clamp left
        assert_eq!(s.at(10.0), 0.0); // clamp right
        assert_eq!(s.at(2.0), 4.0); // exact knot
    }

    #[test]
    fn grid_and_resample() {
        let g = time_grid(10.0, 11);
        assert_eq!(g.len(), 11);
        assert_eq!(g[0], 0.0);
        assert_eq!(g[10], 10.0);
        let mut s = Series::new();
        s.push(0.0, 1.0);
        s.push(10.0, 11.0);
        let r = s.resample(&g);
        assert!((r[5] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn row_average_and_diff() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        assert_eq!(average_rows(&rows), vec![2.0, 3.0]);
        assert_eq!(interval_mean_diff(&[2.0, 3.0], &[1.0, 1.0]), 1.5);
    }

    #[test]
    fn accum_tracks_extremes() {
        let mut a = Accum::default();
        for x in [3.0, -1.0, 7.0] {
            a.add(x);
        }
        assert_eq!(a.n, 3);
        assert_eq!(a.min, -1.0);
        assert_eq!(a.max, 7.0);
        assert_eq!(a.mean(), 3.0);
    }
}
