//! Gradient-lifecycle flight recorder.
//!
//! A fixed-capacity, lock-free ring of span/instant events covering the
//! full life of a gradient — worker compute → encode → wire → shard queue →
//! buffer accumulate → flush wait → apply/publish — plus flush, membership
//! and eviction instants. Writers claim slots with one atomic
//! `fetch_add` on a power-of-two cursor and never block; when the ring
//! wraps, the oldest events are overwritten (flight-recorder semantics)
//! and accounted as dropped. Every recorded span also feeds a per-stage
//! log2-bucketed latency histogram (the staleness-histogram shape from
//! the status document, widened to microsecond scale), so p50/p99 per
//! stage are available live without draining the ring.
//!
//! Timestamps are nanoseconds on the run's injected [`Clock`] timebase:
//! threaded/TCP runs stamp with `clock.now()` (and frontends, which have
//! no clock, stamp through [`TraceRing::real_now`] against an epoch set
//! to the same `Instant` the run's `RealClock` started), while the DES
//! simulator stamps with virtual event times — so a seeded `--sim` run
//! exports a bitwise-identical trace on every replay.
//!
//! The export format is Chrome `trace_event` JSON (load in
//! `chrome://tracing` or Perfetto); the offline `hybrid-sgd trace`
//! analyzer reads the same file back and prints a critical-path table.

use crate::util::json::Utf8JsonWriter;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Number of lifecycle stages (spans + instants).
pub const STAGE_COUNT: usize = 12;

/// Stages that are spans (have a duration) — the first `SPAN_COUNT`
/// discriminants of [`Stage`]; the rest are instants.
pub const SPAN_COUNT: usize = 7;

/// Latency histogram buckets: log2 of microseconds, bucket `b` covering
/// `[2^(b-1), 2^b)` µs (bucket 0 = sub-microsecond). 24 buckets reach
/// ~8.4 s, far beyond any per-stage latency this system produces.
pub const LAT_BUCKETS: usize = 24;

/// One stage of the gradient lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// Worker: forward/backward on one minibatch (includes the modeled
    /// straggler delay and compute floor — the paper's heterogeneity).
    Compute = 0,
    /// Worker: wire-format gradient encoding (per-shard split/quantize).
    Encode = 1,
    /// Worker: submit fan-out until the last shard reply arrives.
    Wire = 2,
    /// Server: enqueue on the shard channel until `run_shard` dequeues.
    Queue = 3,
    /// Server: aggregation buffered the gradient (no publish yet).
    Accumulate = 4,
    /// Server: a blocked worker's wait from park to flush release.
    FlushWait = 5,
    /// Server: aggregation applied and published a new snapshot.
    Apply = 6,
    /// Instant: a synchronous flush/barrier fired (aux = k applied).
    Flush = 7,
    /// Instant: elastic membership join.
    Join = 8,
    /// Instant: elastic membership leave.
    Leave = 9,
    /// Instant: a frontend evicted a worker (timeout / slot reuse).
    Evict = 10,
    /// Instant: the shard published a fresh snapshot (aux = bytes copied
    /// into the snapshot pool — the delta path's memory traffic).
    Publish = 11,
}

/// All stages, in discriminant order (spans first, then instants).
pub const STAGES: [Stage; STAGE_COUNT] = [
    Stage::Compute,
    Stage::Encode,
    Stage::Wire,
    Stage::Queue,
    Stage::Accumulate,
    Stage::FlushWait,
    Stage::Apply,
    Stage::Flush,
    Stage::Join,
    Stage::Leave,
    Stage::Evict,
    Stage::Publish,
];

impl Stage {
    /// Lower-case stable name (wire/status/export identifier).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Compute => "compute",
            Stage::Encode => "encode",
            Stage::Wire => "wire",
            Stage::Queue => "queue",
            Stage::Accumulate => "accumulate",
            Stage::FlushWait => "flush_wait",
            Stage::Apply => "apply",
            Stage::Flush => "flush",
            Stage::Join => "join",
            Stage::Leave => "leave",
            Stage::Evict => "evict",
            Stage::Publish => "publish",
        }
    }

    /// True for stages with a duration; instants are zero-length.
    pub fn is_span(self) -> bool {
        (self as u8) < SPAN_COUNT as u8
    }

    /// Inverse of `name` (used by the offline analyzer).
    pub fn from_name(s: &str) -> Option<Stage> {
        STAGES.iter().copied().find(|st| st.name() == s)
    }

    fn from_u8(v: u8) -> Option<Stage> {
        STAGES.get(v as usize).copied()
    }
}

/// One drained event. `t_ns`/`dur_ns` are on the run clock's timebase;
/// `seq` is the writer's own submission counter (monotone per writer);
/// `aux` is stage-specific (flush k, snapshot version, wire bytes, …).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub stage: Stage,
    pub t_ns: u64,
    pub dur_ns: u64,
    pub worker: u32,
    pub shard: u32,
    pub seq: u64,
    pub aux: u64,
}

/// The result of draining the ring: events in claim (record) order plus
/// the drop accounting. Conservation: `recorded == retained + dropped`.
#[derive(Clone, Debug)]
pub struct TraceDump {
    pub events: Vec<TraceEvent>,
    /// Total events ever recorded (claims issued).
    pub recorded: u64,
    /// Events readable at drain time (== `events.len()`).
    pub retained: u64,
    /// Overwritten by wraparound or torn by an in-flight writer.
    pub dropped: u64,
}

/// Bucket index for a latency of `us` microseconds: log2, saturating.
/// Same `leading_zeros` shape as the staleness histogram in the status
/// document, widened from 6 to [`LAT_BUCKETS`] buckets.
pub fn lat_bucket(us: u64) -> usize {
    ((64 - us.leading_zeros()) as usize).min(LAT_BUCKETS - 1)
}

/// Inclusive upper bound (µs) of bucket `b` — the quantile estimate
/// reported for any sample that landed in it.
pub fn bucket_bound_us(b: usize) -> u64 {
    if b == 0 {
        0
    } else {
        (1u64 << b) - 1
    }
}

/// Estimate a quantile (`q` in 0..=1) from log2 bucket counts: the upper
/// bound of the first bucket whose cumulative count reaches `q * total`.
pub fn quantile_from_buckets(buckets: &[u64; LAT_BUCKETS], q: f64) -> u64 {
    let total: u64 = buckets.iter().sum();
    if total == 0 {
        return 0;
    }
    let target = ((total as f64) * q).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (b, &c) in buckets.iter().enumerate() {
        cum += c;
        if cum >= target {
            return bucket_bound_us(b);
        }
    }
    bucket_bound_us(LAT_BUCKETS - 1)
}

/// Live per-stage summary derived from the histograms (ring not drained).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageSummary {
    pub count: u64,
    pub p50_us: u64,
    pub p99_us: u64,
}

/// One ring slot. Writers fill the payload words `Relaxed`, then publish
/// with a `Release` store of `stamp = claim + 1` (0 = never written /
/// write in progress). `check` mixes every payload word with the claim,
/// so a slot assembled from two racing writers after a full ring lap is
/// detected at drain time and dropped instead of surfacing torn data.
#[derive(Debug)]
struct Slot {
    stamp: AtomicU64,
    t_ns: AtomicU64,
    dur_ns: AtomicU64,
    /// kind(8) | worker(28) | shard(28)
    meta: AtomicU64,
    seq: AtomicU64,
    aux: AtomicU64,
    check: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            stamp: AtomicU64::new(0),
            t_ns: AtomicU64::new(0),
            dur_ns: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            aux: AtomicU64::new(0),
            check: AtomicU64::new(0),
        }
    }
}

fn mix(claim: u64, t: u64, d: u64, m: u64, s: u64, a: u64) -> u64 {
    let mut h = 0x9E37_79B9_7F4A_7C15u64;
    for w in [claim, t, d, m, s, a] {
        h = (h ^ w).wrapping_mul(0x100_0000_01B3).rotate_left(23);
    }
    h
}

fn pack_meta(stage: Stage, worker: u32, shard: u32) -> u64 {
    ((stage as u64) << 56) | ((worker as u64 & 0x0FFF_FFFF) << 28) | (shard as u64 & 0x0FFF_FFFF)
}

/// The flight recorder. Shared as `Arc<TraceRing>`; recording is a claim
/// `fetch_add` plus a handful of `Relaxed` stores — it never blocks, and
/// a missing ring (`Option::None` on the hot paths) costs one branch.
pub struct TraceRing {
    head: AtomicU64,
    mask: u64,
    slots: Vec<Slot>,
    hist: Vec<[AtomicU64; LAT_BUCKETS]>,
    epoch: OnceLock<Instant>,
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.slots.len())
            .field("recorded", &self.head.load(Ordering::Relaxed))
            .finish()
    }
}

impl TraceRing {
    /// `capacity` is rounded up to the next power of two (min 8).
    pub fn new(capacity: usize) -> TraceRing {
        let cap = capacity.max(8).next_power_of_two();
        let mut slots = Vec::with_capacity(cap);
        for _ in 0..cap {
            slots.push(Slot::new());
        }
        let mut hist = Vec::with_capacity(SPAN_COUNT);
        for _ in 0..SPAN_COUNT {
            hist.push(std::array::from_fn(|_| AtomicU64::new(0)));
        }
        TraceRing {
            head: AtomicU64::new(0),
            mask: (cap - 1) as u64,
            slots,
            hist,
            epoch: OnceLock::new(),
        }
    }

    /// Default capacity: 64 Ki events (~3.5 MiB), several minutes of a
    /// busy run before wraparound.
    pub fn with_default_capacity() -> TraceRing {
        TraceRing::new(1 << 16)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Anchor the real-time epoch — callers that have no `Clock` handle
    /// (the transport frontends) stamp with [`Self::real_now`] instead.
    /// Set this to the run `RealClock`'s start instant so both timebases
    /// agree; only the first call wins.
    pub fn set_epoch(&self, at: Instant) {
        let _ = self.epoch.set(at);
    }

    /// Nanoseconds since the epoch (self-anchoring on first use if
    /// [`Self::set_epoch`] was never called).
    pub fn real_now(&self) -> u64 {
        let e = *self.epoch.get_or_init(Instant::now);
        Instant::now().saturating_duration_since(e).as_nanos() as u64
    }

    /// Total events ever recorded.
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Record a span. `start_ns`..`end_ns` on the run clock's timebase.
    pub fn span(
        &self,
        stage: Stage,
        worker: u32,
        shard: u32,
        start_ns: u64,
        end_ns: u64,
        seq: u64,
        aux: u64,
    ) {
        debug_assert!(stage.is_span());
        let dur = end_ns.saturating_sub(start_ns);
        let h = &self.hist[stage as usize];
        h[lat_bucket(dur / 1_000)].fetch_add(1, Ordering::Relaxed);
        self.record(stage, worker, shard, start_ns, dur, seq, aux);
    }

    /// Record an instant (zero-duration marker).
    pub fn instant(&self, stage: Stage, worker: u32, shard: u32, t_ns: u64, seq: u64, aux: u64) {
        debug_assert!(!stage.is_span());
        self.record(stage, worker, shard, t_ns, 0, seq, aux);
    }

    fn record(
        &self,
        stage: Stage,
        worker: u32,
        shard: u32,
        t_ns: u64,
        dur_ns: u64,
        seq: u64,
        aux: u64,
    ) {
        let claim = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(claim & self.mask) as usize];
        let meta = pack_meta(stage, worker, shard);
        // Invalidate first so a concurrent drain never accepts a
        // half-updated slot under the *old* stamp.
        slot.stamp.store(0, Ordering::Release);
        slot.t_ns.store(t_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.meta.store(meta, Ordering::Relaxed);
        slot.seq.store(seq, Ordering::Relaxed);
        slot.aux.store(aux, Ordering::Relaxed);
        slot.check
            .store(mix(claim, t_ns, dur_ns, meta, seq, aux), Ordering::Relaxed);
        slot.stamp.store(claim + 1, Ordering::Release);
    }

    /// Snapshot the readable window. Events come back in claim (record)
    /// order, so each writer's events appear in its program order; slots
    /// overwritten by wraparound or caught mid-write are dropped, never
    /// surfaced torn (the per-slot checksum rejects a slot assembled
    /// from two racing writers).
    pub fn drain(&self) -> TraceDump {
        let recorded = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let lo = recorded.saturating_sub(cap);
        let mut events = Vec::with_capacity((recorded - lo) as usize);
        for claim in lo..recorded {
            let slot = &self.slots[(claim & self.mask) as usize];
            if slot.stamp.load(Ordering::Acquire) != claim + 1 {
                continue; // overwritten by a later lap, or mid-write
            }
            let t_ns = slot.t_ns.load(Ordering::Relaxed);
            let dur_ns = slot.dur_ns.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            let seq = slot.seq.load(Ordering::Relaxed);
            let aux = slot.aux.load(Ordering::Relaxed);
            let check = slot.check.load(Ordering::Relaxed);
            // Re-validate after the payload reads: a writer racing this
            // drain flips the stamp to 0 before touching the payload.
            if slot.stamp.load(Ordering::Acquire) != claim + 1 {
                continue;
            }
            if check != mix(claim, t_ns, dur_ns, meta, seq, aux) {
                continue;
            }
            let stage = match Stage::from_u8((meta >> 56) as u8) {
                Some(s) => s,
                None => continue,
            };
            events.push(TraceEvent {
                stage,
                t_ns,
                dur_ns,
                worker: ((meta >> 28) & 0x0FFF_FFFF) as u32,
                shard: (meta & 0x0FFF_FFFF) as u32,
                seq,
                aux,
            });
        }
        let retained = events.len() as u64;
        TraceDump {
            events,
            recorded,
            retained,
            dropped: recorded - retained,
        }
    }

    /// Raw histogram counts for one span stage.
    pub fn hist_counts(&self, stage: Stage) -> [u64; LAT_BUCKETS] {
        debug_assert!(stage.is_span());
        std::array::from_fn(|b| self.hist[stage as usize][b].load(Ordering::Relaxed))
    }

    /// Live per-stage {count, p50, p99} from the histograms.
    pub fn stage_summaries(&self) -> [StageSummary; SPAN_COUNT] {
        std::array::from_fn(|s| {
            let buckets: [u64; LAT_BUCKETS] =
                std::array::from_fn(|b| self.hist[s][b].load(Ordering::Relaxed));
            StageSummary {
                count: buckets.iter().sum(),
                p50_us: quantile_from_buckets(&buckets, 0.50),
                p99_us: quantile_from_buckets(&buckets, 0.99),
            }
        })
    }

    /// Append the live `"stages"` object to a status document being
    /// built: `{"compute":{"count":..,"p50_us":..,"p99_us":..},...}`,
    /// span stages with at least one sample only.
    pub fn write_stages_json(&self, w: &mut Utf8JsonWriter) {
        let sums = self.stage_summaries();
        w.begin_object();
        for (i, sum) in sums.iter().enumerate() {
            if sum.count == 0 {
                continue;
            }
            w.key(STAGES[i].name());
            w.begin_object();
            w.key("count").num(sum.count as f64);
            w.key("p50_us").num(sum.p50_us as f64);
            w.key("p99_us").num(sum.p99_us as f64);
            w.end_object();
        }
        w.end_object();
    }
}

// ---- Chrome trace_event export -------------------------------------------

/// Serialize a dump as Chrome `trace_event` JSON (object form, complete
/// "X" events for spans and "i" instants, µs timestamps). Worker-side
/// stages land on pid 1 / tid = worker id, server-side stages on pid 2 /
/// tid = shard id, so the two planes render as separate process lanes.
/// Byte-determinism: output depends only on the dump contents, so two
/// identical seeded sim runs export identical bytes.
pub fn chrome_trace_json(dump: &TraceDump) -> String {
    let mut w = Utf8JsonWriter::new();
    w.begin_object();
    w.key("displayTimeUnit").str("ms");
    w.key("recorded").num(dump.recorded as f64);
    w.key("retained").num(dump.retained as f64);
    w.key("dropped").num(dump.dropped as f64);
    w.key("traceEvents");
    w.begin_array();
    for (pid, name) in [(1u32, "workers"), (2u32, "shards")] {
        w.begin_object();
        w.key("name").str("process_name");
        w.key("ph").str("M");
        w.key("pid").num(pid as f64);
        w.key("args");
        w.begin_object();
        w.key("name").str(name);
        w.end_object();
        w.end_object();
    }
    for ev in &dump.events {
        let worker_side = matches!(ev.stage, Stage::Compute | Stage::Encode | Stage::Wire);
        let (pid, tid) = if worker_side {
            (1u32, ev.worker)
        } else {
            (2u32, ev.shard)
        };
        w.begin_object();
        w.key("name").str(ev.stage.name());
        w.key("cat").str("grad");
        w.key("ph").str(if ev.stage.is_span() { "X" } else { "i" });
        w.key("ts").num(ev.t_ns as f64 / 1000.0);
        if ev.stage.is_span() {
            w.key("dur").num(ev.dur_ns as f64 / 1000.0);
        } else {
            w.key("s").str("p");
        }
        w.key("pid").num(pid as f64);
        w.key("tid").num(tid as f64);
        w.key("args");
        w.begin_object();
        w.key("worker").num(ev.worker as f64);
        w.key("shard").num(ev.shard as f64);
        w.key("seq").num(ev.seq as f64);
        w.key("aux").num(ev.aux as f64);
        w.key("t_ns").num(ev.t_ns as f64);
        w.key("dur_ns").num(ev.dur_ns as f64);
        w.end_object();
        w.end_object();
    }
    w.end_array();
    w.end_object();
    w.finish()
}

/// Drain `ring` and write the Chrome trace to `path`.
pub fn export_chrome_trace(ring: &TraceRing, path: &str) -> std::io::Result<TraceDump> {
    let dump = ring.drain();
    std::fs::write(path, chrome_trace_json(&dump))?;
    Ok(dump)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lat_bucket_is_log2_saturating() {
        assert_eq!(lat_bucket(0), 0);
        assert_eq!(lat_bucket(1), 1);
        assert_eq!(lat_bucket(2), 2);
        assert_eq!(lat_bucket(3), 2);
        assert_eq!(lat_bucket(4), 3);
        assert_eq!(lat_bucket(1023), 10);
        assert_eq!(lat_bucket(1024), 11);
        assert_eq!(lat_bucket(u64::MAX), LAT_BUCKETS - 1);
        // bucket b covers [2^(b-1), 2^b): its bound is the last value in it
        for b in 1..LAT_BUCKETS - 1 {
            assert_eq!(lat_bucket(bucket_bound_us(b)), b);
            assert_eq!(lat_bucket(bucket_bound_us(b) + 1), b + 1);
        }
    }

    #[test]
    fn quantiles_come_from_cumulative_bucket_mass() {
        let mut buckets = [0u64; LAT_BUCKETS];
        assert_eq!(quantile_from_buckets(&buckets, 0.5), 0);
        // 90 samples in bucket 3 (4..8 µs), 10 in bucket 10 (512..1024 µs)
        buckets[3] = 90;
        buckets[10] = 10;
        assert_eq!(quantile_from_buckets(&buckets, 0.50), bucket_bound_us(3));
        assert_eq!(quantile_from_buckets(&buckets, 0.90), bucket_bound_us(3));
        assert_eq!(quantile_from_buckets(&buckets, 0.99), bucket_bound_us(10));
    }

    #[test]
    fn ring_drains_in_claim_order_with_conservation() {
        let ring = TraceRing::new(8);
        for i in 0..5u64 {
            ring.span(Stage::Compute, 1, 0, i * 100, i * 100 + 50, i, 0);
        }
        let d = ring.drain();
        assert_eq!(d.recorded, 5);
        assert_eq!(d.retained, 5);
        assert_eq!(d.dropped, 0);
        assert_eq!(d.recorded, d.retained + d.dropped);
        let seqs: Vec<u64> = d.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        // overflow the ring: oldest events are dropped, newest retained
        for i in 5..20u64 {
            ring.span(Stage::Compute, 1, 0, i * 100, i * 100 + 50, i, 0);
        }
        let d = ring.drain();
        assert_eq!(d.recorded, 20);
        assert_eq!(d.retained, 8);
        assert_eq!(d.dropped, 12);
        let seqs: Vec<u64> = d.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<_>>());
    }

    #[test]
    fn histograms_feed_stage_summaries() {
        let ring = TraceRing::new(64);
        // 3 applies at ~4 µs, 1 at ~1 ms
        for i in 0..3 {
            ring.span(Stage::Apply, 0, 2, 0, 4_000, i, 0);
        }
        ring.span(Stage::Apply, 0, 2, 0, 1_000_000, 3, 0);
        let s = ring.stage_summaries();
        assert_eq!(s[Stage::Apply as usize].count, 4);
        assert_eq!(s[Stage::Apply as usize].p50_us, bucket_bound_us(lat_bucket(4)));
        assert_eq!(s[Stage::Apply as usize].p99_us, bucket_bound_us(lat_bucket(1_000)));
        assert_eq!(s[Stage::Compute as usize].count, 0);
        // the stages JSON only carries sampled stages
        let mut w = Utf8JsonWriter::new();
        ring.write_stages_json(&mut w);
        let json = w.finish();
        assert!(json.contains("\"apply\":{\"count\":4"));
        assert!(!json.contains("compute"));
    }

    #[test]
    fn chrome_export_is_valid_json_with_both_planes() {
        let ring = TraceRing::new(64);
        ring.span(Stage::Compute, 3, 0, 1_000, 2_000, 0, 0);
        ring.span(Stage::Apply, 3, 1, 2_500, 2_600, 0, 7);
        ring.instant(Stage::Flush, 0, 1, 2_700, 0, 4);
        let json = chrome_trace_json(&ring.drain());
        let doc = crate::util::json::parse(&json).expect("chrome trace parses");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name metadata + 3 events
        assert_eq!(events.len(), 5);
        assert_eq!(doc.get("dropped").unwrap().as_usize(), Some(0));
        let apply = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("apply"))
            .unwrap();
        assert_eq!(apply.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(apply.get("pid").unwrap().as_usize(), Some(2));
        assert_eq!(apply.get("tid").unwrap().as_usize(), Some(1));
        let flush = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("flush"))
            .unwrap();
        assert_eq!(flush.get("ph").unwrap().as_str(), Some("i"));
        // determinism: serializing the same dump twice is byte-identical
        assert_eq!(json, chrome_trace_json(&ring.drain()));
    }

    /// The satellite property test: N concurrent writers, a ring far
    /// smaller than the event volume. The ring must never block or
    /// surface torn events; counts must conserve and each writer's
    /// retained sequence must be monotone in claim order.
    #[test]
    fn ring_never_blocks_or_tears_under_concurrent_writers() {
        const WRITERS: u64 = 8;
        const PER_WRITER: u64 = 20_000;
        let ring = Arc::new(TraceRing::new(1 << 10));
        let mut handles = Vec::new();
        for w in 0..WRITERS {
            let ring = Arc::clone(&ring);
            handles.push(std::thread::spawn(move || {
                for seq in 0..PER_WRITER {
                    // Every field is derived from (writer, seq) so a torn
                    // slot would be internally inconsistent.
                    let t = w * 1_000_000 + seq * 10;
                    ring.span(Stage::Wire, w as u32, (w % 4) as u32, t, t + w + seq, seq, w ^ seq);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let d = ring.drain();
        // conservation: every claim is accounted for exactly once
        assert_eq!(d.recorded, WRITERS * PER_WRITER);
        assert_eq!(d.recorded, d.retained + d.dropped);
        assert_eq!(d.retained, d.events.len() as u64);
        assert!(d.retained > 0, "a full ring of events must survive");
        let mut last_seq = vec![None::<u64>; WRITERS as usize];
        for ev in &d.events {
            let w = ev.worker as u64;
            // no tearing: all fields agree with the writer's derivation
            assert_eq!(ev.shard as u64, w % 4, "torn event: {ev:?}");
            assert_eq!(ev.t_ns, w * 1_000_000 + ev.seq * 10, "torn event: {ev:?}");
            assert_eq!(ev.dur_ns, w + ev.seq, "torn event: {ev:?}");
            assert_eq!(ev.aux, w ^ ev.seq, "torn event: {ev:?}");
            // per-writer sequences are strictly monotone in claim order
            if let Some(prev) = last_seq[w as usize] {
                assert!(ev.seq > prev, "writer {w}: seq {} after {prev}", ev.seq);
            }
            last_seq[w as usize] = Some(ev.seq);
        }
        // histograms saw every span even when the ring wrapped
        let sums = ring.stage_summaries();
        assert_eq!(sums[Stage::Wire as usize].count, WRITERS * PER_WRITER);
    }

    #[test]
    fn real_now_is_monotone_against_the_epoch() {
        let ring = TraceRing::new(8);
        ring.set_epoch(Instant::now());
        let a = ring.real_now();
        let b = ring.real_now();
        assert!(b >= a);
    }
}
