//! # hybrid-sgd
//!
//! Production-grade reproduction of *"Hybrid Approach to Parallel Stochastic
//! Gradient Descent"* (Vora, Patel, Joshi; 2024) on a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L3** (`coordinator`) — a **sharded** Rust parameter server with three
//!   gradient aggregation policies: synchronous (barrier), asynchronous
//!   (apply-on-arrival) and the paper's **smooth-switch hybrid** (a growing
//!   threshold `K(n)` batches buffered gradients into increasingly
//!   synchronous aggregated updates). The flat θ splits into `S` contiguous
//!   shards, each owned by its own server thread; workers receive O(1)
//!   version-token replies and refresh parameters through zero-copy
//!   `Arc`-swapped snapshots. `S = 1` reproduces the single-server
//!   semantics bitwise, keeping the paper's comparisons valid. Gradient
//!   traffic rides a selectable wire format (`coordinator::compress`):
//!   dense f32, top-k sparsification with error feedback, or int8
//!   quantization — encoded worker-side into recycled buffers, accumulated
//!   sparsely shard-side, with bytes-on-wire accounting for
//!   equal-bandwidth comparisons. Time is a
//!   capability (`coordinator::clock`), and `coordinator::sim` replays the
//!   whole pipeline deterministically in virtual time with fault injection
//!   (crashes, stragglers, message loss, shard stalls) behind a one-line
//!   scenario DSL.
//! - **transport** (`transport`) — the process boundary: a versioned,
//!   CRC32-checked binary frame codec and a `Transport` trait with an
//!   in-process implementation (bitwise-identical to the channel protocol)
//!   and a TCP one (`hybrid-sgd serve` / `hybrid-sgd join`) with
//!   reconnect-with-backoff, heartbeat half-open detection, and
//!   frame-granularity byte accounting (DESIGN.md §2.6).
//! - **L2** (`python/compile/model.py`) — JAX forward/backward graphs for the
//!   paper's workloads (MLP, CNN-MNIST, CNN-CIFAR, plus a transformer LM),
//!   AOT-lowered to HLO text at build time.
//! - **L1** (`python/compile/kernels/`) — Pallas kernels for the compute hot
//!   spots (tiled matmul, fused SGD update, gradient-buffer reduction).
//! - **runtime** — loads the AOT artifacts via the PJRT C API (`xla` crate,
//!   behind the off-by-default `pjrt` feature so the native backend builds
//!   offline) and executes them from the Rust hot path. Python never runs
//!   at training time.
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod native;
pub mod runtime;
pub mod transport;
pub mod util;
