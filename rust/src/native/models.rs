//! Pure-Rust gradient engines.
//!
//! These implement [`GradEngine`] analytically (manual backprop), serving
//! three roles: deterministic unit/property tests of the coordinator without
//! artifacts, micro-benchmarks where XLA latency would mask coordinator
//! costs, and a baseline comparator for the runtime-vs-native ablation bench.

use crate::engine::GradEngine;
use crate::native::linalg as la;
use crate::util::rng::Pcg64;

/// Fully-connected ReLU network `dims[0] → … → dims[L]` with NLL loss — the
/// native twin of the L2 JAX MLP. `dims = [in, out]` is softmax regression.
pub struct MlpEngine {
    dims: Vec<usize>,
    batch: usize,
    // scratch (no allocation per call)
    acts: Vec<Vec<f32>>,   // activations per layer, acts[0] = input copy
    deltas: Vec<Vec<f32>>, // gradient wrt layer outputs
}

impl MlpEngine {
    pub fn new(dims: Vec<usize>, batch: usize) -> Self {
        assert!(dims.len() >= 2);
        let acts = dims.iter().map(|&d| vec![0.0f32; batch * d]).collect();
        let deltas = dims.iter().map(|&d| vec![0.0f32; batch * d]).collect();
        MlpEngine {
            dims,
            batch,
            acts,
            deltas,
        }
    }

    /// Total parameter count: Σ (in·out + out) per layer.
    pub fn n_params(dims: &[usize]) -> usize {
        dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Glorot-uniform init of a flat parameter vector (layout: per layer,
    /// weights row-major [in × out] then bias [out]).
    pub fn init_params(dims: &[usize], rng: &mut Pcg64) -> Vec<f32> {
        let mut p = Vec::with_capacity(Self::n_params(dims));
        for w in dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let limit = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
            let mut weights = vec![0.0f32; fan_in * fan_out];
            rng.fill_uniform_sym(&mut weights, limit);
            p.extend_from_slice(&weights);
            p.extend(std::iter::repeat(0.0f32).take(fan_out));
        }
        p
    }

    /// Forward pass for `rows` samples; logits land in `self.acts.last()`.
    fn forward(&mut self, params: &[f32], x: &[f32], rows: usize) {
        self.acts[0][..rows * self.dims[0]].copy_from_slice(&x[..rows * self.dims[0]]);
        let mut off = 0;
        let n_layers = self.dims.len() - 1;
        for l in 0..n_layers {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &params[off..off + din * dout];
            let b = &params[off + din * dout..off + din * dout + dout];
            off += din * dout + dout;
            // split-borrow: acts[l] is input, acts[l+1] is output
            let (lo, hi) = self.acts.split_at_mut(l + 1);
            let input = &lo[l][..rows * din];
            let out = &mut hi[0][..rows * dout];
            la::matmul(input, w, out, rows, din, dout);
            la::add_row_broadcast(out, b, rows, dout);
            if l + 1 < n_layers {
                la::relu_inplace(out);
            }
        }
    }
}

impl GradEngine for MlpEngine {
    fn param_count(&self) -> usize {
        Self::n_params(&self.dims)
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn grad(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let rows = y.len();
        anyhow::ensure!(rows <= self.batch, "batch larger than engine capacity");
        self.forward(params, x, rows);
        let n_layers = self.dims.len() - 1;
        let classes = *self.dims.last().unwrap();

        // loss + dlogits
        let logits = self.acts.last_mut().unwrap();
        la::log_softmax_rows(&mut logits[..rows * classes], rows, classes);
        let last = self.deltas.len() - 1;
        let (loss, _) = la::nll_and_grad(
            &logits[..rows * classes],
            y,
            &mut self.deltas[last][..rows * classes],
            rows,
            classes,
        );

        // backprop
        grad_out.fill(0.0);
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for l in 0..n_layers {
            offsets.push(off);
            off += self.dims[l] * self.dims[l + 1] + self.dims[l + 1];
        }
        for l in (0..n_layers).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let off = offsets[l];
            // dW = actsᵀ[l] · delta[l+1]
            {
                let (dw, db) = grad_out[off..off + din * dout + dout].split_at_mut(din * dout);
                la::matmul_at_b_accum(
                    &self.acts[l][..rows * din],
                    &self.deltas[l + 1][..rows * dout],
                    dw,
                    rows,
                    din,
                    dout,
                );
                la::col_sum_accum(&self.deltas[l + 1][..rows * dout], db, rows, dout);
            }
            if l > 0 {
                // delta[l] = delta[l+1] · Wᵀ, masked by relu
                let w = &params[off..off + din * dout];
                let (lo, hi) = self.deltas.split_at_mut(l + 1);
                la::matmul_a_bt(
                    &hi[0][..rows * dout],
                    w,
                    &mut lo[l][..rows * din],
                    rows,
                    dout,
                    din,
                );
                la::relu_backward(&self.acts[l][..rows * din], &mut lo[l][..rows * din]);
            }
        }
        Ok(loss)
    }

    fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(f64, usize)> {
        let rows = y.len();
        anyhow::ensure!(rows <= self.batch, "batch larger than engine capacity");
        self.forward(params, x, rows);
        let classes = *self.dims.last().unwrap();
        let logits = self.acts.last_mut().unwrap();
        la::log_softmax_rows(&mut logits[..rows * classes], rows, classes);
        let last = self.deltas.len() - 1;
        let (mean_loss, correct) = la::nll_and_grad(
            &logits[..rows * classes],
            y,
            &mut self.deltas[last][..rows * classes],
            rows,
            classes,
        );
        Ok((mean_loss as f64 * rows as f64, correct))
    }
}

/// Convex quadratic bowl `J(θ) = ½‖θ − θ*‖²` — ignores the data; the exact
/// setting of the paper's convergence discussion (§3 assumes a differentiable
/// convex loss). Property tests drive all three policies on it and assert
/// monotone-ish convergence.
pub struct QuadraticEngine {
    pub target: Vec<f32>,
    batch: usize,
    /// Per-call gradient noise σ (simulates stochastic mini-batch noise).
    pub noise: f32,
    rng: Pcg64,
}

impl QuadraticEngine {
    pub fn new(target: Vec<f32>, batch: usize, noise: f32, seed: u64) -> Self {
        QuadraticEngine {
            target,
            batch,
            noise,
            rng: Pcg64::new(seed, 99),
        }
    }
}

impl GradEngine for QuadraticEngine {
    fn param_count(&self) -> usize {
        self.target.len()
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn grad(
        &mut self,
        params: &[f32],
        _x: &[f32],
        _y: &[i32],
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let mut loss = 0.0f64;
        for ((g, &p), &t) in grad_out.iter_mut().zip(params).zip(&self.target) {
            let d = p - t;
            loss += 0.5 * (d as f64) * (d as f64);
            let n = if self.noise > 0.0 {
                self.rng.normal_ms(0.0, self.noise as f64) as f32
            } else {
                0.0
            };
            *g = d + n;
        }
        Ok(loss as f32)
    }

    fn eval(&mut self, params: &[f32], _x: &[f32], _y: &[i32]) -> anyhow::Result<(f64, usize)> {
        let mut loss = 0.0f64;
        for (&p, &t) in params.iter().zip(&self.target) {
            let d = (p - t) as f64;
            loss += 0.5 * d * d;
        }
        Ok((loss, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of the MLP backprop.
    #[test]
    fn mlp_grad_matches_finite_difference() {
        let dims = vec![4, 6, 3];
        let batch = 5;
        let mut rng = Pcg64::seeded(1);
        let params = MlpEngine::init_params(&dims, &mut rng);
        let mut x = vec![0.0f32; batch * 4];
        rng.fill_normal(&mut x, 1.0);
        let y: Vec<i32> = (0..batch).map(|i| (i % 3) as i32).collect();

        let mut eng = MlpEngine::new(dims.clone(), batch);
        let mut g = vec![0.0f32; params.len()];
        eng.grad(&params, &x, &y, &mut g).unwrap();

        let eps = 1e-3f32;
        let mut checked = 0;
        for i in (0..params.len()).step_by(7) {
            let mut p_hi = params.clone();
            p_hi[i] += eps;
            let mut p_lo = params.clone();
            p_lo[i] -= eps;
            let mut scratch = vec![0.0f32; params.len()];
            let lhi = eng.grad(&p_hi, &x, &y, &mut scratch).unwrap();
            let llo = eng.grad(&p_lo, &x, &y, &mut scratch).unwrap();
            let fd = (lhi - llo) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 2e-2_f32.max(0.1 * fd.abs()),
                "param {i}: fd={fd} analytic={}",
                g[i]
            );
            checked += 1;
        }
        assert!(checked > 5);
    }

    #[test]
    fn mlp_sgd_reduces_loss() {
        // Plain sequential SGD on a separable toy problem must learn.
        let dims = vec![2, 16, 2];
        let batch = 16;
        let mut rng = Pcg64::seeded(2);
        let mut params = MlpEngine::init_params(&dims, &mut rng);
        let mut eng = MlpEngine::new(dims, batch);
        let mut g = vec![0.0f32; params.len()];
        // data: class = x0 > x1
        let make_batch = |rng: &mut Pcg64| {
            let mut x = vec![0.0f32; batch * 2];
            rng.fill_normal(&mut x, 1.0);
            let y: Vec<i32> = (0..batch)
                .map(|i| (x[i * 2] > x[i * 2 + 1]) as i32)
                .collect();
            (x, y)
        };
        let (x0, y0) = make_batch(&mut rng);
        let first = eng.grad(&params, &x0, &y0, &mut g).unwrap();
        for _ in 0..300 {
            let (x, y) = make_batch(&mut rng);
            eng.grad(&params, &x, &y, &mut g).unwrap();
            for (p, &gv) in params.iter_mut().zip(&g) {
                *p -= 0.1 * gv;
            }
        }
        let (xt, yt) = make_batch(&mut rng);
        let last = eng.grad(&params, &xt, &yt, &mut g).unwrap();
        assert!(
            last < first * 0.5,
            "loss did not drop: first={first} last={last}"
        );
    }

    #[test]
    fn eval_counts_correct() {
        let dims = vec![2, 2];
        let batch = 4;
        // Identity-ish weights: class = argmax(x)
        let params = vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0]; // W=I, b=0
        let mut eng = MlpEngine::new(dims, batch);
        let x = vec![3.0, -1.0, -2.0, 5.0, 1.0, 0.0, 0.0, 1.0];
        let y = vec![0, 1, 0, 1];
        let (loss_sum, correct) = eng.eval(&params, &x, &y).unwrap();
        assert_eq!(correct, 4);
        assert!(loss_sum > 0.0);
    }

    #[test]
    fn quadratic_descends_to_target() {
        let target = vec![1.0f32, -2.0, 3.0];
        let mut eng = QuadraticEngine::new(target.clone(), 1, 0.0, 0);
        let mut p = vec![0.0f32; 3];
        let mut g = vec![0.0f32; 3];
        for _ in 0..200 {
            eng.grad(&p, &[], &[], &mut g).unwrap();
            for (pv, &gv) in p.iter_mut().zip(&g) {
                *pv -= 0.1 * gv;
            }
        }
        for (pv, tv) in p.iter().zip(&target) {
            assert!((pv - tv).abs() < 1e-3);
        }
    }

    #[test]
    fn param_count_formula() {
        assert_eq!(MlpEngine::n_params(&[20, 64, 64, 10]), 20 * 64 + 64 + 64 * 64 + 64 + 64 * 10 + 10);
    }
}
