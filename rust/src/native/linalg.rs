//! Small dense linear algebra used by the native (pure-Rust) models.
//!
//! Row-major f32 matrices, no allocation inside the multiply kernels (callers
//! pass output buffers). The GEMM is a cache-blocked ikj loop — fast enough
//! that the *coordinator*, not the math, dominates native-engine benchmarks.

/// out[m×n] = a[m×k] · b[k×n]  (out is overwritten)
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // ikj order: innermost loop streams both b-row and out-row.
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out[k×n] += aᵀ[k×m] · b[m×n]  — accumulating transpose-A multiply
/// (the weight-gradient shape in backprop).
pub fn matmul_at_b_accum(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// out[m×k] = a[m×n] · bᵀ[n×k]  where b is [k×n] — the input-gradient shape.
pub fn matmul_a_bt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let orow = &mut out[i * k..(i + 1) * k];
        for (p, o) in orow.iter_mut().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            let mut acc = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *o = acc;
        }
    }
}

/// y = relu(x) in place; returns nothing. Callers that need the mask use
/// `relu_backward`.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// dx = dy ⊙ 1[x_post > 0], where `post` is the *post-activation* buffer.
pub fn relu_backward(post: &[f32], dy: &mut [f32]) {
    for (d, &p) in dy.iter_mut().zip(post) {
        if p <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Row-wise log-softmax in place over `[rows × cols]`.
pub fn log_softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v -= maxv;
            sum += v.exp();
        }
        let lse = sum.ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// Mean NLL loss over rows given log-probs, plus ∂loss/∂logits written into
/// `dlogits` (softmax(logits) − one-hot, scaled by 1/rows). Returns
/// (mean_loss, correct_count).
pub fn nll_and_grad(
    logp: &[f32],
    y: &[i32],
    dlogits: &mut [f32],
    rows: usize,
    cols: usize,
) -> (f32, usize) {
    assert_eq!(logp.len(), rows * cols);
    assert_eq!(dlogits.len(), rows * cols);
    assert_eq!(y.len(), rows);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    let inv = 1.0 / rows as f32;
    for r in 0..rows {
        let row = &logp[r * cols..(r + 1) * cols];
        let label = y[r] as usize;
        loss -= row[label] as f64;
        let mut best = 0usize;
        for c in 1..cols {
            if row[c] > row[best] {
                best = c;
            }
        }
        if best == label {
            correct += 1;
        }
        let drow = &mut dlogits[r * cols..(r + 1) * cols];
        for (c, d) in drow.iter_mut().enumerate() {
            let p = row[c].exp();
            *d = (p - if c == label { 1.0 } else { 0.0 }) * inv;
        }
    }
    ((loss / rows as f64) as f32, correct)
}

/// out += x (axpy with a=1) — bias-gradient style accumulation.
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    for (o, &v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// Column-sum of `[rows × cols]` accumulated into `out[cols]`.
pub fn col_sum_accum(x: &[f32], out: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        add_assign(out, &x[r * cols..(r + 1) * cols]);
    }
}

/// Broadcast-add a row vector to every row.
pub fn add_row_broadcast(x: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    for r in 0..rows {
        for (v, &b) in x[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *v += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1., 2., 3., 4.];
        let b = [5., 6., 7., 8.];
        let mut out = [0.0f32; 4];
        matmul(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [19., 22., 43., 50.]);
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let m = 3;
        let k = 2;
        let n = 4;
        let a: Vec<f32> = (0..m * k).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..m * n).map(|i| (i as f32).sin()).collect();
        let mut got = vec![0.0f32; k * n];
        matmul_at_b_accum(&a, &b, &mut got, m, k, n);
        // explicit aᵀ
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let mut want = vec![0.0f32; k * n];
        matmul(&at, &b, &mut want, k, m, n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn a_bt_matches_explicit_transpose() {
        let m = 2;
        let n = 3;
        let k = 4;
        let a: Vec<f32> = (0..m * n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32) * 0.25).collect();
        let mut got = vec![0.0f32; m * k];
        matmul_a_bt(&a, &b, &mut got, m, n, k);
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let mut want = vec![0.0f32; m * k];
        matmul(&a, &bt, &mut want, m, n, k);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5);
        }
    }

    #[test]
    fn log_softmax_normalises() {
        let mut x = vec![1.0f32, 2.0, 3.0, 10.0, 10.0, 10.0];
        log_softmax_rows(&mut x, 2, 3);
        for r in 0..2 {
            let s: f32 = x[r * 3..(r + 1) * 3].iter().map(|v| v.exp()).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        // uniform row → log(1/3)
        assert!((x[3] - (1.0f32 / 3.0).ln()).abs() < 1e-5);
    }

    #[test]
    fn nll_grad_sums_to_zero_per_row() {
        let mut logits = vec![0.5f32, -0.2, 0.1, 0.9, 0.0, -1.0];
        log_softmax_rows(&mut logits, 2, 3);
        let mut d = vec![0.0f32; 6];
        let (loss, _) = nll_and_grad(&logits, &[2, 0], &mut d, 2, 3);
        assert!(loss > 0.0);
        for r in 0..2 {
            let s: f32 = d[r * 3..(r + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "grad row sum {s}");
        }
    }

    #[test]
    fn relu_roundtrip() {
        let mut x = vec![-1.0f32, 2.0, -3.0, 4.0];
        relu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 4.0]);
        let mut dy = vec![1.0f32; 4];
        relu_backward(&x, &mut dy);
        assert_eq!(dy, vec![0.0, 1.0, 0.0, 1.0]);
    }
}
