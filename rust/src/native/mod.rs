//! Pure-Rust compute substrate: small dense linear algebra and analytic
//! gradient engines (manual backprop). Used for coordinator tests, property
//! checks, micro-benchmarks and as a no-artifact fallback; the production
//! path is `runtime::XlaEngine`.

pub mod linalg;
pub mod models;

pub use models::{MlpEngine, QuadraticEngine};
