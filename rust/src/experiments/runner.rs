//! The comparison runner: prepare a workload, run sync / async / hybrid from
//! identical initialisation for the same wall-clock budget, average rounds on
//! a common time grid, and compute the paper's interval-mean differences.

use super::config::{DatasetKind, EngineKind, ExpConfig};
use crate::coordinator::worker::BatchSource;
use crate::coordinator::{
    train, EvalSet, Policy, RunInputs, RunMetrics, Schedule, TrainConfig,
};
use crate::data::{random_cluster, synth_cifar, synth_mnist, Batcher, Dataset};
use crate::engine::{factory, EngineFactory};
use crate::log_info;
use crate::native::MlpEngine;
use crate::util::rng::Pcg64;
use crate::util::stats::{average_rows, interval_mean_diff, time_grid};
use std::sync::Arc;

/// The MLP dims shared by the JAX model, the native engine and the manifest.
pub const MLP_DIMS: [usize; 4] = [20, 64, 64, 10];

/// A prepared workload: datasets + engines + init, ready to train.
pub struct Workload {
    pub train_set: Arc<Dataset>,
    pub test: EvalSet,
    pub probe: EvalSet,
    pub init: Vec<f32>,
    pub worker_engine: EngineFactory,
    pub eval_engine: EngineFactory,
}

impl Workload {
    /// Generate datasets and engine factories for a config.
    pub fn prepare(cfg: &ExpConfig) -> anyhow::Result<Workload> {
        let mut rng = Pcg64::new(cfg.seed, 1);
        let (train_set, test_set) = match cfg.dataset {
            DatasetKind::Mnist => {
                let tr = synth_mnist::generate(cfg.train_n, &mut rng);
                let te = synth_mnist::generate(cfg.test_n, &mut rng);
                (tr, te)
            }
            DatasetKind::Cifar => {
                let tr = synth_cifar::generate(cfg.train_n, &mut rng);
                let te = synth_cifar::generate(cfg.test_n, &mut rng);
                (tr, te)
            }
            DatasetKind::Random => {
                // Paper: 10k samples, 80:20 split, newly sampled per config.
                let spec = random_cluster::ClusterSpec {
                    n_samples: cfg.train_n + cfg.test_n,
                    ..Default::default()
                };
                let full = random_cluster::generate(&spec, &mut rng);
                full.split(
                    cfg.train_n as f64 / (cfg.train_n + cfg.test_n) as f64,
                    &mut rng,
                )
            }
        };

        let model = cfg.dataset.model();
        let (worker_engine, eval_engine, init) = match &cfg.engine {
            EngineKind::Xla { variant } => {
                anyhow::ensure!(
                    cfg.hidden.is_none(),
                    "--hidden reshapes the native MLP; XLA artifacts have fixed shapes"
                );
                let dir = crate::runtime::default_artifact_dir();
                let manifest = crate::runtime::Manifest::load(&dir)?;
                let entry = manifest.model(model)?;
                let init = crate::runtime::init_params(entry, &mut rng)?;
                let (w, e) = crate::runtime::engine_factories(&dir, model, cfg.batch, variant)?;
                (w, e, init)
            }
            EngineKind::Native => {
                anyhow::ensure!(
                    cfg.dataset == DatasetKind::Random,
                    "native engine only implements the MLP (random dataset)"
                );
                let dims: Vec<usize> = match cfg.hidden {
                    Some(h) => vec![MLP_DIMS[0], h, h, MLP_DIMS[3]],
                    None => MLP_DIMS.to_vec(),
                };
                let init = MlpEngine::init_params(&dims, &mut rng);
                let batch = cfg.batch;
                let dims_w = dims.clone();
                let w = factory(move || {
                    Ok(Box::new(MlpEngine::new(dims_w.clone(), batch))
                        as Box<dyn crate::engine::GradEngine>)
                });
                let dims_e = dims.clone();
                let e = factory(move || {
                    Ok(Box::new(MlpEngine::new(dims_e.clone(), 100))
                        as Box<dyn crate::engine::GradEngine>)
                });
                (w, e, init)
            }
        };

        let test = EvalSet::from_dataset(&test_set, cfg.eval_test_n, &mut rng);
        let probe = EvalSet::from_dataset(&train_set, cfg.eval_probe_n, &mut rng);
        Ok(Workload {
            train_set: Arc::new(train_set),
            test,
            probe,
            init,
            worker_engine,
            eval_engine,
        })
    }

    /// Batch-source factory over this workload's shards.
    fn batch_source(
        &self,
        cfg: &ExpConfig,
        round: usize,
    ) -> Arc<dyn Fn(usize) -> Box<dyn BatchSource> + Send + Sync> {
        let shards = self
            .train_set
            .partition_indices(cfg.workers, &cfg.partition, cfg.seed);
        let train = Arc::clone(&self.train_set);
        let batch = cfg.batch;
        let seed = cfg.seed.wrapping_add(round as u64 * 7919);
        Arc::new(move |id| {
            Box::new(Batcher::new(
                Arc::clone(&train),
                shards[id].clone(),
                batch,
                Pcg64::new(seed, id as u64),
            )) as Box<dyn BatchSource>
        })
    }
}

/// The three algorithms under comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Hybrid,
    Async,
    Sync,
}

impl Algo {
    pub const ALL: [Algo; 3] = [Algo::Hybrid, Algo::Async, Algo::Sync];

    pub fn name(self) -> &'static str {
        match self {
            Algo::Hybrid => "hybrid",
            Algo::Async => "async",
            Algo::Sync => "sync",
        }
    }

    fn policy(self, schedule: Schedule) -> Policy {
        match self {
            Algo::Hybrid => Policy::Hybrid {
                schedule,
                strict: false,
            },
            Algo::Async => Policy::Async,
            Algo::Sync => Policy::Sync,
        }
    }
}

/// Round-averaged metric curves on the common grid.
#[derive(Clone, Debug)]
pub struct AveragedRun {
    pub grid: Vec<f64>,
    pub test_acc: Vec<f64>,
    pub test_loss: Vec<f64>,
    pub train_loss: Vec<f64>,
    pub grads_per_sec: f64,
    pub updates_total: f64,
    pub mean_staleness: f64,
}

/// Result of one full comparison (all algos, all rounds).
pub struct Comparison {
    pub cfg: ExpConfig,
    pub averaged: Vec<(Algo, AveragedRun)>,
    pub raw: Vec<(Algo, Vec<RunMetrics>)>,
}

/// The paper's table statistic: interval means of (hybrid − baseline).
#[derive(Clone, Copy, Debug)]
pub struct DiffRow {
    pub test_acc: f64,
    pub test_loss: f64,
    pub train_loss: f64,
}

impl Comparison {
    /// The averaged run of one algorithm. An algorithm missing from this
    /// comparison (e.g. asking for sync in a hybrid-vs-async table) is a
    /// configuration error reported as such, not a panic that aborts the
    /// whole multi-round run.
    pub fn averaged_for(&self, a: Algo) -> anyhow::Result<&AveragedRun> {
        self.averaged
            .iter()
            .find(|(x, _)| *x == a)
            .map(|(_, avg)| avg)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "algorithm `{}` is not part of this comparison (ran: {})",
                    a.name(),
                    self.averaged
                        .iter()
                        .map(|(x, _)| x.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// hybrid − baseline, averaged over the training interval.
    pub fn diff_vs(&self, baseline: Algo) -> anyhow::Result<DiffRow> {
        let ours = self.averaged_for(Algo::Hybrid)?;
        let base = self.averaged_for(baseline)?;
        Ok(DiffRow {
            test_acc: interval_mean_diff(&ours.test_acc, &base.test_acc),
            test_loss: interval_mean_diff(&ours.test_loss, &base.test_loss),
            train_loss: interval_mean_diff(&ours.train_loss, &base.train_loss),
        })
    }
}

/// Run the full comparison for a config.
pub fn run_comparison(cfg: &ExpConfig) -> anyhow::Result<Comparison> {
    run_comparison_algos(cfg, &Algo::ALL)
}

/// Run a chosen subset of algorithms (the paper drops sync after §7.1).
pub fn run_comparison_algos(cfg: &ExpConfig, algos: &[Algo]) -> anyhow::Result<Comparison> {
    let workload = Workload::prepare(cfg)?;
    let grid = time_grid(cfg.secs, cfg.grid_points);
    let mut raw: Vec<(Algo, Vec<RunMetrics>)> =
        algos.iter().map(|&a| (a, Vec::new())).collect();

    for round in 0..cfg.rounds {
        // Fresh init per round, identical across algorithms (paper §6).
        let mut round_rng = Pcg64::new(cfg.seed.wrapping_add(round as u64), 3);
        let init = match &cfg.engine {
            EngineKind::Xla { .. } => {
                let dir = crate::runtime::default_artifact_dir();
                let manifest = crate::runtime::Manifest::load(&dir)?;
                crate::runtime::init_params(manifest.model(cfg.dataset.model())?, &mut round_rng)?
            }
            EngineKind::Native => MlpEngine::init_params(&MLP_DIMS, &mut round_rng),
        };
        for &algo in algos {
            let tc = TrainConfig {
                policy: algo.policy(cfg.schedule()),
                workers: cfg.workers,
                lr: cfg.lr,
                duration: std::time::Duration::from_secs_f64(cfg.secs),
                delay: cfg.delay.clone(),
                seed: cfg.seed.wrapping_add(round as u64 * 31),
                eval_interval: std::time::Duration::from_secs_f64(
                    (cfg.secs / (cfg.grid_points as f64 - 1.0)).max(0.25),
                ),
                k_max: None,
                compute_floor: std::time::Duration::from_secs_f64(cfg.compute_ms / 1000.0),
                shards: cfg.shards,
                wire: cfg.compress.clone(),
                steps: cfg.steps,
                elastic: false,
                min_quorum: 1,
                stream: None,
                aggregate: cfg.aggregate.clone(),
                partition: cfg.partition.clone(),
                trace: None,
                param_dtype: cfg.param_dtype,
            };
            let inputs = RunInputs {
                worker_engine: Arc::clone(&workload.worker_engine),
                eval_engine: Arc::clone(&workload.eval_engine),
                batch_source: workload.batch_source(cfg, round),
                init_params: &init,
                test: &workload.test,
                train_probe: &workload.probe,
            };
            log_info!(
                "runner",
                "[{}] round {}/{} algo {}{}",
                cfg.tag(),
                round + 1,
                cfg.rounds,
                algo.name(),
                if cfg.sim.is_some() { " (sim)" } else { "" }
            );
            let m = match &cfg.sim {
                Some(sp) => {
                    // Virtual-time run: same TrainConfig, same inputs, but
                    // the budget is virtual and the result is bitwise
                    // reproducible from the seed.
                    let scn = sp.scenario(tc.clone())?;
                    // Log the replayable scenario line (EXPERIMENTS.md
                    // records sweeps by these).
                    log_info!("runner", "scenario: {scn}");
                    crate::coordinator::sim::simulate(&scn, &inputs)?
                }
                None => train(&tc, &inputs)?,
            };
            raw.iter_mut()
                .find(|(a, _)| *a == algo)
                .ok_or_else(|| {
                    anyhow::anyhow!("algorithm `{}` vanished from the result table", algo.name())
                })?
                .1
                .push(m);
        }
    }

    let averaged = raw
        .iter()
        .map(|(algo, runs)| (*algo, average_runs(runs, &grid)))
        .collect();
    Ok(Comparison {
        cfg: cfg.clone(),
        averaged,
        raw,
    })
}

/// Average per-round series on the grid.
pub fn average_runs(runs: &[RunMetrics], grid: &[f64]) -> AveragedRun {
    assert!(!runs.is_empty());
    let resample = |f: fn(&RunMetrics) -> &crate::util::stats::Series| {
        let rows: Vec<Vec<f64>> = runs.iter().map(|r| f(r).resample(grid)).collect();
        average_rows(&rows)
    };
    AveragedRun {
        grid: grid.to_vec(),
        test_acc: resample(|r| &r.test_acc),
        test_loss: resample(|r| &r.test_loss),
        train_loss: resample(|r| &r.train_loss),
        grads_per_sec: runs.iter().map(|r| r.grads_per_sec()).sum::<f64>() / runs.len() as f64,
        updates_total: runs.iter().map(|r| r.updates_total as f64).sum::<f64>() / runs.len() as f64,
        mean_staleness: runs.iter().map(|r| r.mean_staleness).sum::<f64>() / runs.len() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_cfg() -> ExpConfig {
        let mut c = ExpConfig::default_for(DatasetKind::Random).quick();
        c.engine = EngineKind::Native;
        c.secs = 1.0;
        c.workers = 3;
        c.train_n = 800;
        c.test_n = 200;
        c.delay = crate::coordinator::DelayModel::none();
        c.lr = 0.05;
        c.grid_points = 6;
        c
    }

    #[test]
    fn comparison_runs_all_algos_native() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let cfg = native_cfg();
        let cmp = run_comparison(&cfg).unwrap();
        assert_eq!(cmp.averaged.len(), 3);
        for (_, avg) in &cmp.averaged {
            assert_eq!(avg.test_acc.len(), cfg.grid_points);
            assert!(avg.grads_per_sec > 0.0);
        }
        // diff rows are finite
        let d = cmp.diff_vs(Algo::Async).unwrap();
        assert!(d.test_acc.is_finite() && d.test_loss.is_finite());
    }

    #[test]
    fn comparison_runs_on_the_simulator_reproducibly() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let mut cfg = native_cfg();
        cfg.workers = 2;
        cfg.secs = 0.4;
        cfg.sim = Some(crate::experiments::config::SimParams {
            grad_ms: 10.0,
            fault_spec: String::new(),
        });
        let a = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async]).unwrap();
        let b = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async]).unwrap();
        for ((algo_a, ra), (algo_b, rb)) in a.averaged.iter().zip(&b.averaged) {
            assert_eq!(algo_a, algo_b);
            assert!(ra.grads_per_sec > 0.0);
            // virtual-time runs replay bitwise from the seed
            assert_eq!(ra.test_acc, rb.test_acc);
            assert_eq!(ra.grads_per_sec, rb.grads_per_sec);
            assert_eq!(ra.updates_total, rb.updates_total);
        }
    }

    #[test]
    fn subset_comparison_skips_sync() {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        let cfg = native_cfg();
        let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async]).unwrap();
        assert_eq!(cmp.averaged.len(), 2);
        // asking for an algorithm that did not run is an error, not a panic
        let err = cmp.diff_vs(Algo::Sync).unwrap_err();
        assert!(err.to_string().contains("sync"), "{err}");
        assert!(cmp.averaged_for(Algo::Hybrid).is_ok());
    }

    #[test]
    fn average_runs_combines_rounds() {
        let grid = vec![0.0, 1.0, 2.0];
        let mut a = RunMetrics::default();
        a.test_acc.push(0.0, 10.0);
        a.test_acc.push(2.0, 30.0);
        a.test_loss.push(0.0, 2.0);
        a.test_loss.push(2.0, 1.0);
        a.train_loss.push(0.0, 2.0);
        a.train_loss.push(2.0, 1.0);
        a.wall_time = 2.0;
        a.gradients_total = 10;
        let mut b = a.clone();
        b.test_acc.v = vec![20.0, 40.0];
        let avg = average_runs(&[a, b], &grid);
        assert_eq!(avg.test_acc, vec![15.0, 25.0, 35.0]);
    }
}
