//! Regeneration of the paper's Figures 4–10.
//!
//! Figures 4–7 are the metric-vs-time curves (test accuracy, test loss,
//! train loss for all three algorithms) on MNIST / CIFAR at each
//! (step, batch) combination. Figures 8–10 plot the table 3/4/5 diffs
//! against batch size / step size / delay σ. Output: CSV under `results/`
//! plus ASCII charts on stdout (no plotting library offline).

use super::config::{DatasetKind, ExpConfig};
use super::runner::{run_comparison, Comparison};
use super::tables::{run_table, Table};
use crate::util::plot::{bars, render, Curve};

/// A rendered figure: chart text + the CSV rows that back it.
pub struct Figure {
    pub id: usize,
    pub title: String,
    pub chart: String,
    /// (filename, csv content) pairs.
    pub csv: Vec<(String, String)>,
}

/// Figures 4/5 (MNIST) and 6/7 (CIFAR): one figure covers two batch sizes at
/// one step multiple.
pub fn curve_figure(id: usize, base: &ExpConfig) -> anyhow::Result<Figure> {
    let (dataset, mult, label) = match id {
        4 => (DatasetKind::Mnist, 3.0, "MNIST step 300"),
        5 => (DatasetKind::Mnist, 5.0, "MNIST step 500"),
        6 => (DatasetKind::Cifar, 3.0, "CIFAR-10 step 300"),
        7 => (DatasetKind::Cifar, 5.0, "CIFAR-10 step 500"),
        _ => anyhow::bail!("curve figures are 4-7"),
    };
    let mut chart = String::new();
    let mut csv = Vec::new();
    for batch in [32usize, 64] {
        let mut cfg = base.clone();
        cfg.dataset = dataset;
        cfg.step_mult = mult;
        cfg.batch = batch;
        let cmp = run_comparison(&cfg)?;
        chart.push_str(&comparison_charts(
            &format!("Figure {id}: {label}, batch {batch}"),
            &cmp,
        ));
        csv.push((
            format!("figure{id}_b{batch}.csv"),
            comparison_csv(&cmp),
        ));
    }
    Ok(Figure {
        id,
        title: label.to_string(),
        chart,
        csv,
    })
}

/// ASCII charts for one comparison (acc / test loss / train loss).
pub fn comparison_charts(title: &str, cmp: &Comparison) -> String {
    let mut out = String::new();
    for (metric, get) in [
        ("test accuracy (%)", 0usize),
        ("test loss", 1),
        ("train loss", 2),
    ] {
        let curves: Vec<Curve> = cmp
            .averaged
            .iter()
            .map(|(algo, avg)| Curve {
                label: algo.name(),
                t: &avg.grid,
                v: match get {
                    0 => &avg.test_acc,
                    1 => &avg.test_loss,
                    _ => &avg.train_loss,
                },
            })
            .collect();
        out.push_str(&render(&format!("{title} — {metric}"), &curves, 64, 14));
        out.push('\n');
    }
    out
}

/// CSV with one row per grid point: t, then per-algo acc/test_loss/train_loss.
pub fn comparison_csv(cmp: &Comparison) -> String {
    let mut s = String::from("t");
    for (algo, _) in &cmp.averaged {
        let n = algo.name();
        s.push_str(&format!(",{n}_acc,{n}_test_loss,{n}_train_loss"));
    }
    s.push('\n');
    let grid = &cmp.averaged[0].1.grid;
    for (i, t) in grid.iter().enumerate() {
        s.push_str(&format!("{t:.3}"));
        for (_, avg) in &cmp.averaged {
            s.push_str(&format!(
                ",{:.5},{:.5},{:.5}",
                avg.test_acc[i], avg.test_loss[i], avg.train_loss[i]
            ));
        }
        s.push('\n');
    }
    s
}

/// Figures 8/9/10: the table 3/4/5 metric diffs as bar charts.
pub fn diff_figure(id: usize, base: &ExpConfig) -> anyhow::Result<Figure> {
    let (table_id, xlabel) = match id {
        8 => (3usize, "batch size"),
        9 => (4, "step size"),
        10 => (5, "delay (mean, std)"),
        _ => anyhow::bail!("diff figures are 8-10"),
    };
    let table = run_table(table_id, base)?;
    Ok(figure_from_table(id, xlabel, &table))
}

/// Build a diff figure from an already-computed table (avoids rerunning).
pub fn figure_from_table(id: usize, xlabel: &str, table: &Table) -> Figure {
    let mut chart = String::new();
    for (metric, get) in [
        ("Δ test accuracy", 0usize),
        ("Δ test loss", 1),
        ("Δ train loss", 2),
    ] {
        let items: Vec<(String, f64)> = table
            .col_labels
            .iter()
            .zip(&table.measured)
            .map(|(l, m)| {
                (
                    l.clone(),
                    match get {
                        0 => m.test_acc,
                        1 => m.test_loss,
                        _ => m.train_loss,
                    },
                )
            })
            .collect();
        chart.push_str(&bars(
            &format!("Figure {id}: {metric} (hybrid − async) vs {xlabel}"),
            &items,
            40,
        ));
        chart.push('\n');
    }
    let mut csv = format!("{xlabel},d_test_acc,d_test_loss,d_train_loss\n");
    for (l, m) in table.col_labels.iter().zip(&table.measured) {
        csv.push_str(&format!(
            "{l},{:.5},{:.5},{:.5}\n",
            m.test_acc, m.test_loss, m.train_loss
        ));
    }
    Figure {
        id,
        title: format!("average metric difference vs {xlabel}"),
        chart,
        csv: vec![(format!("figure{id}.csv"), csv)],
    }
}

/// Dispatch by figure number.
pub fn run_figure(id: usize, base: &ExpConfig) -> anyhow::Result<Figure> {
    match id {
        4..=7 => curve_figure(id, base),
        8..=10 => diff_figure(id, base),
        _ => anyhow::bail!("figures are numbered 4-10"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::runner::DiffRow;

    #[test]
    fn figure_from_table_renders() {
        let t = Table {
            id: 3,
            title: "demo".into(),
            col_labels: vec!["8".into(), "16".into()],
            measured: vec![
                DiffRow {
                    test_acc: 4.0,
                    test_loss: -0.1,
                    train_loss: -0.1,
                },
                DiffRow {
                    test_acc: 2.0,
                    test_loss: -0.05,
                    train_loss: -0.04,
                },
            ],
            paper: vec![],
            comparisons: vec![],
        };
        let f = figure_from_table(8, "batch size", &t);
        assert!(f.chart.contains("Figure 8"));
        assert!(f.csv[0].1.contains("8,4.00000"));
    }
}
