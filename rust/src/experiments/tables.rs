//! Regeneration of the paper's Tables 1–5.
//!
//! Every table reports the same statistic: the difference between the hybrid
//! algorithm and the asynchronous baseline (test accuracy / test loss /
//! train loss), averaged over the entire training interval — positive
//! accuracy and negative losses mean the hybrid wins. Paper reference values
//! are embedded so the printed output shows expected-vs-measured side by
//! side (shape, not absolute, is the reproduction target — see DESIGN.md §5).

use super::config::{DatasetKind, ExpConfig};
use super::runner::{run_comparison, run_comparison_algos, Algo, Comparison, DiffRow};
use crate::coordinator::DelayModel;

/// A regenerated table: columns of configurations, three metric rows.
pub struct Table {
    pub id: usize,
    pub title: String,
    pub col_labels: Vec<String>,
    /// Measured diffs per column.
    pub measured: Vec<DiffRow>,
    /// Paper-reported diffs per column.
    pub paper: Vec<DiffRow>,
    /// The comparisons backing each column (kept for figure generation).
    pub comparisons: Vec<Comparison>,
}

impl Table {
    /// Markdown rendering with paper values in parentheses.
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("### Table {}: {}\n\n", self.id, self.title));
        s.push_str("| metric |");
        for l in &self.col_labels {
            s.push_str(&format!(" {l} |"));
        }
        s.push_str("\n|---|");
        for _ in &self.col_labels {
            s.push_str("---|");
        }
        s.push('\n');
        let rows: [(&str, fn(&DiffRow) -> f64); 3] = [
            ("Test Accuracy", |d| d.test_acc),
            ("Test loss", |d| d.test_loss),
            ("Train loss", |d| d.train_loss),
        ];
        for (name, get) in rows {
            s.push_str(&format!("| {name} |"));
            for (m, p) in self.measured.iter().zip(&self.paper) {
                s.push_str(&format!(" {:+.3} (paper {:+.3}) |", get(m), get(p)));
            }
            s.push('\n');
        }
        s.push('\n');
        s
    }

    /// Shape check: fraction of columns where hybrid beats async on accuracy.
    pub fn win_fraction(&self) -> f64 {
        let wins = self.measured.iter().filter(|d| d.test_acc > 0.0).count();
        wins as f64 / self.measured.len().max(1) as f64
    }
}

fn d(acc: f64, test: f64, train: f64) -> DiffRow {
    DiffRow {
        test_acc: acc,
        test_loss: test,
        train_loss: train,
    }
}

/// Tables 1 & 2: (step, batch) grid on MNIST / CIFAR. All three algorithms
/// run (the paper's plots include sync), diffs reported vs async.
fn image_table(
    id: usize,
    dataset: DatasetKind,
    base: &ExpConfig,
    paper: Vec<DiffRow>,
) -> anyhow::Result<Table> {
    let combos = [(3.0, 32), (3.0, 64), (5.0, 32), (5.0, 64)];
    let mut measured = Vec::new();
    let mut comparisons = Vec::new();
    let mut labels = Vec::new();
    for (mult, batch) in combos {
        let mut cfg = base.clone();
        cfg.dataset = dataset;
        cfg.step_mult = mult;
        cfg.batch = batch;
        let cmp = run_comparison(&cfg)?;
        measured.push(cmp.diff_vs(Algo::Async)?);
        comparisons.push(cmp);
        labels.push(format!("({},{})", (mult / base.lr as f64) as i64, batch));
    }
    Ok(Table {
        id,
        title: format!(
            "hybrid − async averaged over the training interval, {} dataset",
            if dataset == DatasetKind::Mnist { "MNIST" } else { "CIFAR-10" }
        ),
        col_labels: labels,
        measured,
        paper,
        comparisons,
    })
}

pub fn table1(base: &ExpConfig) -> anyhow::Result<Table> {
    image_table(
        1,
        DatasetKind::Mnist,
        base,
        vec![
            d(1.374, -0.047, -0.047),
            d(-0.516, 0.001, -0.001),
            d(1.366, -0.053, -0.054),
            d(1.291, -0.022, -0.023),
        ],
    )
}

pub fn table2(base: &ExpConfig) -> anyhow::Result<Table> {
    image_table(
        2,
        DatasetKind::Cifar,
        base,
        vec![
            d(4.849, -0.137, -0.139),
            d(2.435, -0.066, -0.067),
            d(3.468, -0.092, -0.091),
            d(2.884, -0.080, -0.082),
        ],
    )
}

/// Table 3: batch-size sweep on the random dataset (step 500). The paper
/// drops the sync baseline from §7.2 onward; so do we.
pub fn table3(base: &ExpConfig) -> anyhow::Result<Table> {
    let batches = [8usize, 16, 32, 64, 128];
    let paper = vec![
        d(4.896, -0.141, -0.143),
        d(5.183, -0.141, -0.141),
        d(4.222, -0.117, -0.114),
        d(3.304, -0.089, -0.088),
        d(2.599, -0.072, -0.068),
    ];
    let mut measured = Vec::new();
    let mut comparisons = Vec::new();
    let mut labels = Vec::new();
    for batch in batches {
        let mut cfg = base.clone();
        cfg.dataset = DatasetKind::Random;
        cfg.step_mult = 5.0;
        cfg.batch = batch;
        // paper: a newly sampled dataset per configuration
        cfg.seed = base.seed.wrapping_add(batch as u64);
        let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async])?;
        measured.push(cmp.diff_vs(Algo::Async)?);
        comparisons.push(cmp);
        labels.push(format!("{batch}"));
    }
    Ok(Table {
        id: 3,
        title: "batch-size sweep (random dataset, step 500)".into(),
        col_labels: labels,
        measured,
        paper,
        comparisons,
    })
}

/// Table 4: step-size sweep (multiples of 1/lr) at batch 32.
pub fn table4(base: &ExpConfig) -> anyhow::Result<Table> {
    let mults = [1.0, 3.0, 5.0, 7.0, 10.0];
    let paper = vec![
        d(0.136, -0.016, -0.013),
        d(3.857, -0.110, -0.110),
        d(3.915, -0.118, -0.121),
        d(3.083, -0.084, -0.079),
        d(2.967, -0.074, -0.075),
    ];
    let mut measured = Vec::new();
    let mut comparisons = Vec::new();
    let mut labels = Vec::new();
    for mult in mults {
        let mut cfg = base.clone();
        cfg.dataset = DatasetKind::Random;
        cfg.batch = 32;
        cfg.step_mult = mult;
        cfg.seed = base.seed.wrapping_add((mult * 10.0) as u64);
        let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async])?;
        measured.push(cmp.diff_vs(Algo::Async)?);
        comparisons.push(cmp);
        labels.push(format!("{}/lr", mult as i64));
    }
    Ok(Table {
        id: 4,
        title: "step-size sweep (random dataset, batch 32)".into(),
        col_labels: labels,
        measured,
        paper,
        comparisons,
    })
}

/// Table 5: communication-delay sweep (N(0, σ), σ ∈ 0.25..1.25).
pub fn table5(base: &ExpConfig) -> anyhow::Result<Table> {
    let stds = [0.25, 0.5, 0.75, 1.0, 1.25];
    let paper = vec![
        d(3.915, -0.117, -0.120),
        d(1.920, -0.035, -0.039),
        d(3.012, -0.081, -0.079),
        d(2.879, -0.079, -0.075),
        d(5.184, -0.156, -0.166),
    ];
    let mut measured = Vec::new();
    let mut comparisons = Vec::new();
    let mut labels = Vec::new();
    for std in stds {
        let mut cfg = base.clone();
        cfg.dataset = DatasetKind::Random;
        cfg.batch = 32;
        cfg.step_mult = 5.0;
        cfg.delay = DelayModel::paper_default().with_std(std);
        cfg.seed = base.seed.wrapping_add((std * 100.0) as u64);
        let cmp = run_comparison_algos(&cfg, &[Algo::Hybrid, Algo::Async])?;
        measured.push(cmp.diff_vs(Algo::Async)?);
        comparisons.push(cmp);
        labels.push(format!("(0,{std})"));
    }
    Ok(Table {
        id: 5,
        title: "communication-delay sweep (random dataset, batch 32, step 500)".into(),
        col_labels: labels,
        measured,
        paper,
        comparisons,
    })
}

/// Dispatch by table number.
pub fn run_table(id: usize, base: &ExpConfig) -> anyhow::Result<Table> {
    match id {
        1 => table1(base),
        2 => table2(base),
        3 => table3(base),
        4 => table4(base),
        5 => table5(base),
        _ => anyhow::bail!("tables are numbered 1-5"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_measured_and_paper() {
        let t = Table {
            id: 9,
            title: "demo".into(),
            col_labels: vec!["(300,32)".into()],
            measured: vec![d(1.0, -0.1, -0.1)],
            paper: vec![d(1.374, -0.047, -0.047)],
            comparisons: vec![],
        };
        let md = t.to_markdown();
        assert!(md.contains("Table 9"));
        assert!(md.contains("+1.000 (paper +1.374)"));
        assert_eq!(t.win_fraction(), 1.0);
    }
}
