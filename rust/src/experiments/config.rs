//! Experiment configuration: one struct that fully determines a
//! sync/async/hybrid comparison run (paper §6).
//!
//! Scale presets: the paper trains 25 workers × 5 rounds × 100 s per
//! configuration on a 28-core node. This container has one core, so the
//! default preset scales down (8 workers, 2 rounds, 10 s) while `--paper-scale`
//! restores the original numbers; the *relative* comparison (identical init,
//! identical budget across algorithms) is what the tables measure.

use crate::coordinator::{AggregateMode, DelayModel, ParamDtype, WireFormat};
use crate::data::Partition;

/// Virtual-time simulation parameters (`--sim`): run on the deterministic
/// discrete-event simulator instead of the threaded trainer. `secs` then
/// means *virtual* seconds, so sweeps replay bit-identically from their
/// seeds regardless of host load.
#[derive(Clone, Debug, PartialEq)]
pub struct SimParams {
    /// Virtual compute time per gradient, in milliseconds (`--grad-ms`).
    pub grad_ms: f64,
    /// Fault-injection clause list (`--fault-spec`, see
    /// `coordinator::sim::FaultPlan`); empty = fault-free.
    pub fault_spec: String,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams {
            grad_ms: 5.0,
            fault_spec: String::new(),
        }
    }
}

impl SimParams {
    /// Build the simulator scenario for one run (the single construction
    /// site shared by the `train` command and the comparison runner).
    pub fn scenario(
        &self,
        train: crate::coordinator::TrainConfig,
    ) -> anyhow::Result<crate::coordinator::sim::Scenario> {
        Ok(crate::coordinator::sim::Scenario {
            train,
            grad_time: std::time::Duration::from_secs_f64(self.grad_ms / 1000.0),
            faults: crate::coordinator::sim::FaultPlan::parse(&self.fault_spec)?,
        })
    }
}

/// Which dataset feeds the run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DatasetKind {
    /// Procedural MNIST lookalike (28×28 grayscale digits).
    Mnist,
    /// Procedural CIFAR lookalike (32×32 RGB scenes).
    Cifar,
    /// The paper's random 20-dim 10-class Gaussian clusters.
    Random,
}

impl DatasetKind {
    pub fn model(self) -> &'static str {
        match self {
            DatasetKind::Mnist => "cnn_mnist",
            DatasetKind::Cifar => "cnn_cifar",
            DatasetKind::Random => "mlp",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "mnist" => DatasetKind::Mnist,
            "cifar" => DatasetKind::Cifar,
            "random" => DatasetKind::Random,
            _ => anyhow::bail!("unknown dataset `{s}` (mnist|cifar|random)"),
        })
    }
}

/// How gradients are computed.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineKind {
    /// AOT XLA artifacts (the production path). Variant: "jnp" | "pallas".
    Xla { variant: String },
    /// Pure-Rust backprop (mlp only) — coordinator-focused benches/tests.
    Native,
}

/// One comparison configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    pub dataset: DatasetKind,
    pub engine: EngineKind,
    pub workers: usize,
    pub batch: usize,
    pub lr: f32,
    /// Threshold step = step_mult / lr gradient arrivals (paper notation:
    /// step sizes as multiples of the reciprocal learning rate).
    pub step_mult: f64,
    pub rounds: usize,
    pub secs: f64,
    pub delay: DelayModel,
    pub seed: u64,
    /// Dataset sizes (the Random dataset is split 80:20 afterwards).
    pub train_n: usize,
    pub test_n: usize,
    /// Eval probe caps.
    pub eval_test_n: usize,
    pub eval_probe_n: usize,
    /// Metric grid resolution for round averaging.
    pub grid_points: usize,
    /// Per-gradient compute-cost floor in ms (simulates the paper's ray +
    /// PyTorch per-iteration cost for models whose AOT executables are much
    /// faster here; the CNNs are already in-regime and use 0).
    pub compute_ms: f64,
    /// Estimated gradient arrivals/sec for this (dataset, workers, budget)
    /// on this container — used to scale the paper's threshold step sizes.
    pub arrival_rate_est: f64,
    /// Parameter-server shard count (`--shards`); 1 = single server thread.
    pub shards: usize,
    /// Gradient wire format (`--compress`); dense reproduces the
    /// uncompressed pipeline bitwise.
    pub compress: WireFormat,
    /// Per-worker gradient-submission budget (`--steps`); the run ends
    /// when every worker has spent it (deterministic alternative to the
    /// wall-clock budget — `secs` remains the hard deadline).
    pub steps: Option<u64>,
    /// When set, runs execute on the virtual-time simulator (`--sim`).
    pub sim: Option<SimParams>,
    /// Server-side aggregation mode (`--aggregate`); `mean` reproduces the
    /// historical flush bitwise, the rest are Byzantine defenses
    /// (DESIGN.md §2.10).
    pub aggregate: AggregateMode,
    /// How training data is dealt across workers (`--partition`); `iid`
    /// reproduces the historical contiguous sharding bitwise,
    /// `dirichlet:<alpha>` skews class proportions per worker.
    pub partition: Partition,
    /// Storage precision of published parameter snapshots
    /// (`--param-dtype f32|f16|bf16`); master weights stay f32 and `f32`
    /// reproduces the historical pipeline bitwise (DESIGN.md §2.12).
    pub param_dtype: ParamDtype,
    /// Override of the native MLP's hidden width (`--hidden H` ⇒ dims
    /// [20, H, H, 10]); `None` keeps the paper's [20, 64, 64, 10]. Native
    /// engine only — big-model memory/geometry testing (DESIGN.md §2.12),
    /// e.g. H=4096 puts one unsharded slice just past the 64 MiB frame cap.
    pub hidden: Option<usize>,
}

/// The paper's K cap (25 workers) is reached after step×(25−1) arrivals; at
/// their smallest step (300) that is 7200 arrivals over a 100 s run. We keep
/// the async→sync transition spanning the same *fraction* of the training
/// interval by scaling step sizes with the ratio of expected arrivals
/// (DESIGN.md §1: scale substitutions preserve relative dynamics).
pub const PAPER_ARRIVALS: f64 = 7500.0;

/// The paper fixes lr = 0.01 (§6); step sizes are defined as multiples of
/// its reciprocal. Our lr defaults may be budget-scaled per dataset, but the
/// step-size *units* stay anchored to the paper's lr so Table 4's x-axis
/// keeps its meaning.
pub const PAPER_LR: f64 = 0.01;

impl ExpConfig {
    /// Container-scale defaults for a dataset.
    pub fn default_for(dataset: DatasetKind) -> ExpConfig {
        let (train_n, test_n) = match dataset {
            DatasetKind::Mnist => (6_000, 1_000),
            DatasetKind::Cifar => (4_000, 800),
            DatasetKind::Random => (8_000, 2_000), // paper: 10k total, 80:20
        };
        ExpConfig {
            dataset,
            engine: EngineKind::Xla {
                variant: "jnp".into(),
            },
            workers: 8,
            batch: 32,
            // The paper fixes 0.01 over 100 s on 28 cores; the CNN budgets
            // here are ~10x shorter on 1 core, so their lr is budget-scaled.
            lr: match dataset {
                DatasetKind::Random => 0.01,
                _ => 0.05,
            },
            step_mult: 5.0,
            rounds: 2,
            secs: match dataset {
                DatasetKind::Random => 10.0,
                DatasetKind::Mnist => 12.0,
                DatasetKind::Cifar => 20.0,
            },
            delay: DelayModel::paper_default(),
            seed: 42,
            train_n,
            test_n,
            eval_test_n: 500,
            eval_probe_n: 500,
            grid_points: 41,
            compute_ms: match dataset {
                DatasetKind::Random => 20.0,
                _ => 0.0,
            },
            arrival_rate_est: match dataset {
                DatasetKind::Random => 200.0,
                DatasetKind::Mnist => 34.0,
                DatasetKind::Cifar => 12.0,
            },
            shards: 1,
            compress: WireFormat::Dense,
            steps: None,
            sim: None,
            aggregate: AggregateMode::Mean,
            partition: Partition::Iid,
            param_dtype: ParamDtype::F32,
            hidden: None,
        }
    }

    /// Step-size scale: expected arrivals this run / the paper's arrivals,
    /// clamped to at most 1 (never *slow* the transition beyond the paper's).
    pub fn step_scale(&self) -> f64 {
        ((self.arrival_rate_est * self.secs) / PAPER_ARRIVALS).min(1.0)
    }

    /// The paper's full-scale settings (hours of wall clock on one core).
    pub fn paper_scale(mut self) -> ExpConfig {
        self.workers = 25;
        self.rounds = 5;
        self.secs = 100.0;
        match self.dataset {
            DatasetKind::Mnist => {
                self.train_n = 60_000;
                self.test_n = 10_000;
            }
            DatasetKind::Cifar => {
                self.train_n = 50_000;
                self.test_n = 10_000;
            }
            DatasetKind::Random => {
                self.train_n = 8_000;
                self.test_n = 2_000;
            }
        }
        self
    }

    /// Smoke-test scale (seconds per table).
    pub fn quick(mut self) -> ExpConfig {
        self.rounds = 1;
        self.secs = 3.0;
        self.workers = 4;
        self.train_n = self.train_n.min(2_000);
        self.test_n = self.test_n.min(500);
        self.eval_test_n = 300;
        self.eval_probe_n = 300;
        self
    }

    /// The threshold schedule: the paper's step (multiple of 1/paper-lr)
    /// scaled to this container's arrival rate.
    pub fn schedule(&self) -> crate::coordinator::Schedule {
        let paper_step = self.step_mult / PAPER_LR;
        let step = (paper_step * self.step_scale()).round().max(1.0) as usize;
        crate::coordinator::Schedule::Step { step }
    }

    /// A short tag for file names / logs.
    pub fn tag(&self) -> String {
        format!(
            "{}_s{}_b{}_w{}",
            self.dataset.model(),
            (self.step_mult / self.lr as f64).round() as i64,
            self.batch,
            self.workers
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ExpConfig::default_for(DatasetKind::Random);
        assert_eq!(c.dataset.model(), "mlp");
        // 200/s x 10s = 2000 expected arrivals; scale = 2000/7500
        let expect = (500.0f64 * 2000.0 / 7500.0).round() as usize;
        assert_eq!(c.schedule(), crate::coordinator::Schedule::Step { step: expect });
        assert!(c.tag().contains("mlp_s500_b32"));
    }

    #[test]
    fn paper_scale_restores_paper_numbers() {
        let c = ExpConfig::default_for(DatasetKind::Mnist).paper_scale();
        assert_eq!(c.workers, 25);
        assert_eq!(c.rounds, 5);
        assert_eq!(c.secs, 100.0);
        assert_eq!(c.train_n, 60_000);
    }

    #[test]
    fn dataset_parse() {
        assert_eq!(DatasetKind::parse("mnist").unwrap(), DatasetKind::Mnist);
        assert!(DatasetKind::parse("imagenet").is_err());
    }
}
