//! Experiment harness: configurations, the multi-round runner, and the
//! table/figure regeneration pipeline (paper §7).

pub mod config;
pub mod figures;
pub mod report;
pub mod runner;
pub mod tables;

/// CLI entrypoint (the `hybrid-sgd` binary delegates here).
pub fn cli_main() -> anyhow::Result<()> {
    report::cli_main()
}
