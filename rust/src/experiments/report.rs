//! The `hybrid-sgd` CLI: single runs, comparisons, and table/figure
//! regeneration with file output under `results/`.

use super::config::{DatasetKind, EngineKind, ExpConfig};
use super::figures::{comparison_charts, figure_from_table, run_figure};
use super::runner::{run_comparison, Algo};
use super::tables::run_table;
use crate::coordinator::{DelayModel, Policy};
use crate::util::cli::Args;
use std::io::Write as _;
use std::path::Path;

const USAGE: &str = "\
hybrid-sgd — parameter-server SGD with sync / async / smooth-switch hybrid aggregation

USAGE:
  hybrid-sgd <command> [options]

COMMANDS:
  inspect                      list models & artifacts from the manifest
  train                        one training run, print metrics
  serve                        run the parameter server over TCP (workers `join`)
  join                         run one gradient worker against a `serve` process
  status                       poll a `serve` process's read-only ops endpoint
                               (--follow streams push-based deltas instead)
  trace <FILE>                 analyze a --trace export: critical-path table
                               per stage (--connect streams live summaries)
  compare                      run hybrid vs async vs sync, print charts
  table <1-5>                  regenerate a paper table
  figure <4-10>                regenerate a paper figure
  all                          regenerate every table and figure
  help                         this text

COMMON OPTIONS:
  --dataset mnist|cifar|random   workload (default per command)
  --engine xla:jnp|xla:pallas|native
  --policy async|sync|hybrid:step:500|hybrid-strict:<sched>|adaptive[:t]  (train only)
  --workers N      --batch N     --lr F        --secs F
  --rounds N       --seed N      --step-mult F --delay-std F
  --delay-dist normal|lognormal  per-gradient delay family (default normal;
                                 lognormal = heavy-tailed WAN-RTT shape, with
                                 the mean/--delay-std pair read in log-space)
  --delay-regions N              WAN regional correlation groups: workers map
                                 round-robin onto N regions sharing one fixed
                                 delay multiplier each (default 0 = off)
  --aggregate MODE               server aggregation: mean | clip:<c> |
                                 trimmed:<f> | median  (default mean; the
                                 robust modes defend against Byzantine
                                 gradients — DESIGN.md §2.10; trimmed/median
                                 need a buffering policy, i.e. not async)
  --partition iid|dirichlet:<a>  data dealing across workers (default iid;
                                 dirichlet skews class shares per worker —
                                 small alpha = heterogeneous shards)
  --shards N                     parameter-server shards (default 1)
  --compress FMT                 gradient wire format: dense | topk:<k|frac> | int8
                                 | topk+int8:<k|frac>  (default dense; topk uses
                                 error feedback — see coordinator::compress)
  --param-dtype f32|f16|bf16     storage precision of published parameter
                                 snapshots (default f32 = bitwise-identical
                                 to the historical pipeline; f16/bf16 halve
                                 snapshot + refresh-wire memory — master
                                 weights stay f32, DESIGN.md §2.12)
  --hidden N                     native MLP hidden width: dims [20, N, N, 10]
                                 (default 64 = the paper's model; big-model
                                 geometry testing — N=4096 puts one unsharded
                                 slice past the 64 MiB frame cap, exercising
                                 chunked delta refresh. join must repeat it)
  --sim                          run on the deterministic virtual-time simulator
                                 (--secs becomes virtual seconds; bitwise-reproducible)
  --fault-spec SPEC              inject faults, e.g. \"crash:3@5,stall:0@1..2,slow:*@2..4*8\"
                                 (implies --sim; see coordinator::sim::FaultPlan).
                                 Byzantine clauses: byz-scale:W:F@T (scaled
                                 gradients), byz-flip:W@T (sign-flipped),
                                 byz-nan:W@T (NaN-poisoned; rejected and
                                 counted at the server boundary)
  --grad-ms F                    virtual per-gradient compute time in ms (sim, default 5)
  --steps N                      stop after N gradient submissions per worker
                                 (deterministic budget; --secs stays the hard
                                 deadline). Works threaded, --sim, serve & join.
  --elastic                      elastic membership: renormalize K(n) and sync
                                 barriers to the live worker set as workers
                                 join/leave/crash (train, serve, --sim). The
                                 sim DSL gains join:+N@T / leave:W@T clauses.
  --min-quorum N                 barrier-denominator floor under --elastic
                                 (default 1): the barrier never shrinks below
                                 N workers; a depleted run waits for joiners.
  --metrics-out FILE             write the run's metrics as JSON (train/serve)
  --metrics-stream FILE          append each metric sample to FILE as JSONL while
                                 the run progresses (train/serve/--sim); replayable
                                 bit-for-bit via coordinator::replay_stream
  --metrics-cap N                with --metrics-stream: keep only the newest ~N
                                 samples per series in memory (the file keeps all)
  --trace FILE                   flight-record the gradient lifecycle (compute /
                                 encode / wire / queue / accumulate / flush-wait
                                 / apply spans plus flush & membership instants)
                                 and export Chrome trace_event JSON to FILE when
                                 the run ends (train / serve / join; open in
                                 ui.perfetto.dev or feed `hybrid-sgd trace`).
                                 Under --sim timestamps are virtual, so the same
                                 seeded scenario exports byte-identical traces.
  --trace-capacity N             flight-recorder ring size in events (default
                                 65536, rounded up to a power of two; wraparound
                                 overwrites the oldest events and the export
                                 reports them as dropped)
  --quick                        smoke scale (seconds)
  --paper-scale                  the paper's 25 workers x 5 rounds x 100 s
  --out DIR                      results directory (default results/)

MULTI-PROCESS (see EXPERIMENTS.md for the localhost recipe):
  serve --listen HOST:PORT --workers N [--shards S --policy P --steps N ...]
  join  --connect HOST:PORT --workers N [--compress topk:0.01 --steps N ...]
  join must repeat the server's --workers/--seed/--dataset/--batch so its
  data shard and seed streams match the in-process run; the server assigns
  the worker id at attach. Transport tuning: --hb-ms (heartbeat interval,
  default 500), --hb-timeout-ms (half-open cutoff, default 5000),
  --connect-timeout-ms (dial budget incl. backoff, default 10000),
  --reconnect-attempts (default 2). Server side: --frontend reactor|threaded
  picks the event-driven poll loop (default) or the legacy
  thread-per-connection frontend (same wire protocol, comparison baseline).
  Ops plane: status --connect HOST:PORT prints the server's live status
  document (membership, per-shard K(n)/buffer/version, byte rates) without
  taking a worker slot; --path workers.active extracts one value. Add
  --follow to subscribe instead of polling: the server pushes one delta
  per --interval-ms (default 1000, floor 10) until --count N deltas arrive
  or the run ends. `trace --connect HOST:PORT` follows the same stream but
  prints only the per-stage p50/p99 latency summaries (needs a server
  started with --trace). `trace FILE` analyzes an exported trace offline:
  validates the document and prints the critical-path breakdown;
  --require-stages compute,apply makes missing stages an error (CI).
";

/// Build an `ExpConfig` from CLI options.
fn config_from(args: &Args, default_dataset: DatasetKind) -> anyhow::Result<ExpConfig> {
    let dataset = match args.get("dataset") {
        Some(d) => DatasetKind::parse(d)?,
        None => default_dataset,
    };
    let mut cfg = ExpConfig::default_for(dataset);
    if args.flag("quick") {
        cfg = cfg.quick();
    }
    if args.flag("paper-scale") {
        cfg = cfg.paper_scale();
    }
    cfg.workers = args.usize_or("workers", cfg.workers);
    cfg.batch = args.usize_or("batch", cfg.batch);
    cfg.lr = args.f64_or("lr", cfg.lr as f64) as f32;
    cfg.secs = args.f64_or("secs", cfg.secs);
    cfg.rounds = args.usize_or("rounds", cfg.rounds);
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.step_mult = args.f64_or("step-mult", cfg.step_mult);
    cfg.arrival_rate_est = args.f64_or("arrival-rate", cfg.arrival_rate_est);
    cfg.compute_ms = args.f64_or("compute-ms", cfg.compute_ms);
    cfg.shards = args.usize_or("shards", cfg.shards).max(1);
    if let Some(c) = args.get("compress") {
        cfg.compress = crate::coordinator::WireFormat::parse(c)?;
    }
    if let Some(s) = args.get("steps") {
        let n: u64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --steps `{s}` (expected a positive integer)"))?;
        anyhow::ensure!(n > 0, "--steps must be at least 1");
        cfg.steps = Some(n);
    }
    if let Some(std) = args.get("delay-std") {
        cfg.delay = DelayModel::paper_default().with_std(std.parse()?);
    }
    if let Some(d) = args.get("delay-dist") {
        cfg.delay.dist = crate::coordinator::DelayDist::parse(d)?;
    }
    cfg.delay.regions = args.usize_or("delay-regions", cfg.delay.regions);
    if let Some(a) = args.get("aggregate") {
        cfg.aggregate = crate::coordinator::AggregateMode::parse(a)?;
    }
    if let Some(p) = args.get("partition") {
        cfg.partition = crate::data::Partition::parse(p)?;
    }
    if let Some(d) = args.get("param-dtype") {
        cfg.param_dtype = crate::coordinator::ParamDtype::parse(d)
            .ok_or_else(|| anyhow::anyhow!("bad --param-dtype `{d}` (expected f32|f16|bf16)"))?;
    }
    if let Some(h) = args.get("hidden") {
        let h: usize = h
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --hidden `{h}` (expected a positive width)"))?;
        anyhow::ensure!(h > 0, "--hidden must be positive");
        cfg.hidden = Some(h);
    }
    if args.flag("sim") || args.get("fault-spec").is_some() || args.get("grad-ms").is_some() {
        // Validate the fault spec at parse time so typos fail fast.
        let fault_spec = args.str_or("fault-spec", "");
        crate::coordinator::sim::FaultPlan::parse(&fault_spec)?;
        cfg.sim = Some(super::config::SimParams {
            grad_ms: args.f64_or("grad-ms", 5.0),
            fault_spec,
        });
    }
    cfg.engine = match args.str_or("engine", "xla:jnp").as_str() {
        "native" => EngineKind::Native,
        "xla:jnp" => EngineKind::Xla {
            variant: "jnp".into(),
        },
        "xla:pallas" => EngineKind::Xla {
            variant: "pallas".into(),
        },
        other => anyhow::bail!("unknown engine `{other}`"),
    };
    Ok(cfg)
}

fn results_dir(args: &Args) -> anyhow::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(args.str_or("out", "results"));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

fn save(dir: &Path, name: &str, content: &str) -> anyhow::Result<()> {
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    f.write_all(content.as_bytes())?;
    println!("wrote {}", path.display());
    Ok(())
}

pub fn cli_main() -> anyhow::Result<()> {
    let args = Args::parse(true);
    match args.subcommand.as_deref() {
        Some("inspect") => cmd_inspect(),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("join") => cmd_join(&args),
        Some("status") => cmd_status(&args),
        Some("trace") => cmd_trace(&args),
        Some("compare") => cmd_compare(&args),
        Some("table") => cmd_table(&args),
        Some("figure") => cmd_figure(&args),
        Some("all") => cmd_all(&args),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_inspect() -> anyhow::Result<()> {
    let dir = crate::runtime::default_artifact_dir();
    let man = crate::runtime::Manifest::load(&dir)?;
    println!("manifest: {}", dir.join("manifest.json").display());
    println!("\nmodels:");
    for m in &man.models {
        println!(
            "  {:<12} {:<12} params={:<8} x_dim={:<6} classes={} layers={}",
            m.name,
            m.kind,
            m.param_count,
            m.x_dim,
            m.classes,
            m.layers.len()
        );
    }
    println!("\ngraph artifacts:");
    for a in &man.artifacts {
        // A directory-like artifact path would previously panic the whole
        // inspect; report it as a malformed-manifest error instead.
        let file = a.path.file_name().ok_or_else(|| {
            anyhow::anyhow!(
                "artifact for model `{}` has a path with no file name: `{}`",
                a.model,
                a.path.display()
            )
        })?;
        println!(
            "  {:<14} {:<5} batch={:<4} variant={:<7} {}",
            a.model,
            a.kind,
            a.batch,
            a.variant,
            file.to_string_lossy()
        );
    }
    println!("\nops:");
    for o in &man.ops {
        println!(
            "  {:<14} {:<8} variant={:<7} params={}",
            o.op, o.model, o.variant, o.param_count
        );
    }
    Ok(())
}

/// The `TrainConfig` a CLI invocation describes (shared by `train` and
/// `serve`, so the two paths cannot drift).
fn train_config_from(args: &Args, cfg: &ExpConfig) -> anyhow::Result<crate::coordinator::TrainConfig> {
    let policy = Policy::parse(&args.str_or("policy", &format!("hybrid:{}", cfg.schedule())))?;
    let min_quorum = args.usize_or("min-quorum", 1);
    anyhow::ensure!(min_quorum >= 1, "--min-quorum must be at least 1");
    Ok(crate::coordinator::TrainConfig {
        policy,
        workers: cfg.workers,
        lr: cfg.lr,
        duration: std::time::Duration::from_secs_f64(cfg.secs),
        delay: cfg.delay.clone(),
        seed: cfg.seed,
        eval_interval: std::time::Duration::from_millis(500),
        k_max: None,
        compute_floor: std::time::Duration::from_secs_f64(cfg.compute_ms / 1000.0),
        shards: cfg.shards,
        wire: cfg.compress.clone(),
        steps: cfg.steps,
        elastic: args.flag("elastic"),
        min_quorum,
        stream: metrics_stream_from(args)?,
        aggregate: cfg.aggregate.clone(),
        partition: cfg.partition.clone(),
        trace: trace_ring_from(args)?,
        param_dtype: cfg.param_dtype,
    })
}

/// The optional gradient-lifecycle flight recorder (`--trace FILE`): a
/// shared ring the run stamps span events into, exported as Chrome
/// `trace_event` JSON to `FILE` when the run completes. `--trace-capacity`
/// overrides the default ring size (rounded up to a power of two).
fn trace_ring_from(
    args: &Args,
) -> anyhow::Result<Option<std::sync::Arc<crate::util::trace::TraceRing>>> {
    if args.get("trace").is_none() {
        anyhow::ensure!(
            args.get("trace-capacity").is_none(),
            "--trace-capacity needs --trace FILE (there is no ring to size)"
        );
        return Ok(None);
    }
    let ring = match args.get("trace-capacity") {
        Some(cap) => {
            let n: usize = cap.parse().map_err(|_| {
                anyhow::anyhow!("bad --trace-capacity `{cap}` (expected a positive integer)")
            })?;
            anyhow::ensure!(n > 0, "--trace-capacity must be at least 1");
            crate::util::trace::TraceRing::new(n)
        }
        None => crate::util::trace::TraceRing::with_default_capacity(),
    };
    Ok(Some(std::sync::Arc::new(ring)))
}

/// The optional JSONL metrics sink (`--metrics-stream FILE`), with
/// `--metrics-cap N` bounding the in-memory series to a sliding window
/// while the file keeps everything (long-horizon runs).
fn metrics_stream_from(
    args: &Args,
) -> anyhow::Result<Option<std::sync::Arc<crate::coordinator::MetricsStream>>> {
    let Some(path) = args.get("metrics-stream") else {
        anyhow::ensure!(
            args.get("metrics-cap").is_none(),
            "--metrics-cap needs --metrics-stream (the cap drops in-memory \
             samples that only the stream file retains)"
        );
        return Ok(None);
    };
    let mut stream = crate::coordinator::MetricsStream::create(Path::new(path))?;
    if let Some(cap) = args.get("metrics-cap") {
        let n: usize = cap.parse().map_err(|_| {
            anyhow::anyhow!("bad --metrics-cap `{cap}` (expected a positive integer)")
        })?;
        anyhow::ensure!(n > 0, "--metrics-cap must be at least 1");
        stream = stream.with_cap(n);
    }
    Ok(Some(std::sync::Arc::new(stream)))
}

/// Transport tuning from CLI flags (defaults match `NetOptions`).
fn net_options(args: &Args) -> crate::transport::NetOptions {
    crate::transport::NetOptions {
        hb_interval: std::time::Duration::from_millis(args.u64_or("hb-ms", 500)),
        hb_timeout: std::time::Duration::from_millis(args.u64_or("hb-timeout-ms", 5000)),
        connect_timeout: std::time::Duration::from_millis(
            args.u64_or("connect-timeout-ms", 10_000),
        ),
        reconnect_attempts: args.u64_or("reconnect-attempts", 2) as u32,
        ..crate::transport::NetOptions::default()
    }
}

fn write_metrics_out(args: &Args, m: &crate::coordinator::RunMetrics) -> anyhow::Result<()> {
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, m.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Export the flight recorder to the `--trace FILE` path once the run is
/// over (train, serve and join share this tail).
fn write_trace_out(
    args: &Args,
    ring: &Option<std::sync::Arc<crate::util::trace::TraceRing>>,
) -> anyhow::Result<()> {
    let (Some(path), Some(ring)) = (args.get("trace"), ring) else {
        return Ok(());
    };
    let dump = crate::util::trace::export_chrome_trace(ring, path)?;
    println!(
        "wrote {path} ({} span/instant events, {} dropped)",
        dump.events.len(),
        dump.dropped
    );
    Ok(())
}

fn print_run(tc: &crate::coordinator::TrainConfig, m: &crate::coordinator::RunMetrics) {
    println!("policy          : {}", tc.policy);
    println!("gradients       : {}", m.gradients_total);
    println!("updates         : {}", m.updates_total);
    println!("flushes         : {}", m.flushes);
    println!("shards          : {}", m.shards);
    if m.membership_epochs > 0 {
        println!(
            "membership      : {} transitions, {} live at end",
            m.membership_epochs,
            m.membership.v.last().copied().unwrap_or(0.0)
        );
    }
    println!("grads/sec       : {:.1}", m.grads_per_sec());
    println!("mean staleness  : {:.2}", m.mean_staleness);
    if !tc.aggregate.is_mean() {
        println!("aggregate       : {}", tc.aggregate);
    }
    if m.rejected_grads > 0 {
        println!(
            "rejected grads  : {} (non-finite payloads dropped at the server boundary)",
            m.rejected_grads
        );
    }
    if m.clipped_grads > 0 {
        println!("clipped grads   : {}", m.clipped_grads);
    }
    if !tc.wire.is_dense() {
        println!("wire format     : {}", tc.wire);
    }
    if m.bytes_sent > 0 {
        println!(
            "bytes on wire   : {} sent / {} received ({:.1}x vs dense)",
            m.bytes_sent,
            m.bytes_received,
            m.wire_compression()
        );
    }
    if let Some((tr, te, acc)) = m.final_metrics() {
        println!("final train loss: {tr:.4}");
        println!("final test loss : {te:.4}");
        println!("final test acc  : {acc:.2}%");
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args, DatasetKind::Random)?;
    let workload = super::runner::Workload::prepare(&cfg)?;
    let tc = train_config_from(args, &cfg)?;
    let inputs = crate::coordinator::RunInputs {
        worker_engine: std::sync::Arc::clone(&workload.worker_engine),
        eval_engine: std::sync::Arc::clone(&workload.eval_engine),
        batch_source: workload_batch_source(&workload, &cfg),
        init_params: &workload.init,
        test: &workload.test,
        train_probe: &workload.probe,
    };
    let m = match &cfg.sim {
        Some(sp) => {
            let scn = sp.scenario(tc.clone())?;
            println!("simulating      : {scn}");
            crate::coordinator::sim::simulate(&scn, &inputs)?
        }
        None => crate::coordinator::train(&tc, &inputs)?,
    };
    print_run(&tc, &m);
    write_metrics_out(args, &m)?;
    write_trace_out(args, &tc.trace)?;
    Ok(())
}

/// `hybrid-sgd serve --listen HOST:PORT ...`: the multi-process parameter
/// server. Workload preparation, policy and seeds are exactly `train`'s;
/// the workers arrive over TCP (`hybrid-sgd join`).
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args, DatasetKind::Random)?;
    anyhow::ensure!(
        cfg.sim.is_none(),
        "serve runs the threaded stack; --sim is single-process by design"
    );
    let listen = args
        .get("listen")
        .ok_or_else(|| anyhow::anyhow!("serve needs --listen HOST:PORT (e.g. 127.0.0.1:7070)"))?;
    let listener = std::net::TcpListener::bind(listen)
        .map_err(|e| anyhow::anyhow!("could not bind {listen}: {e}"))?;
    println!("listening       : {}", listener.local_addr()?);
    let workload = super::runner::Workload::prepare(&cfg)?;
    let tc = train_config_from(args, &cfg)?;
    let inputs = crate::coordinator::RunInputs {
        worker_engine: std::sync::Arc::clone(&workload.worker_engine),
        eval_engine: std::sync::Arc::clone(&workload.eval_engine),
        batch_source: workload_batch_source(&workload, &cfg),
        init_params: &workload.init,
        test: &workload.test,
        train_probe: &workload.probe,
    };
    let kind = crate::transport::FrontendKind::parse(&args.str_or("frontend", "reactor"))?;
    let m = crate::coordinator::serve_with(&tc, &inputs, listener, &net_options(args), kind)?;
    print_run(&tc, &m);
    write_metrics_out(args, &m)?;
    write_trace_out(args, &tc.trace)?;
    Ok(())
}

/// `hybrid-sgd join --connect HOST:PORT ...`: one gradient worker process.
/// Must repeat the server's --workers/--seed/--dataset/--batch so its data
/// shard and seed derivations match the in-process run.
fn cmd_join(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args, DatasetKind::Random)?;
    anyhow::ensure!(
        cfg.sim.is_none(),
        "join runs the threaded stack; --sim is single-process by design"
    );
    let connect = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("join needs --connect HOST:PORT"))?;
    let workload = super::runner::Workload::prepare(&cfg)?;
    let net = net_options(args);
    // Hard deadline: the server's budget plus the dial allowance, so a
    // worker never outlives a hung run.
    let deadline = std::time::Duration::from_secs_f64(cfg.secs) + net.connect_timeout;
    let trace = trace_ring_from(args)?;
    let report = crate::coordinator::join_remote(
        connect,
        &net,
        cfg.compress.clone(),
        cfg.delay.clone(),
        cfg.seed,
        std::time::Duration::from_secs_f64(cfg.compute_ms / 1000.0),
        cfg.steps,
        deadline,
        std::sync::Arc::clone(&workload.worker_engine),
        workload_batch_source(&workload, &cfg),
        Some(cfg.workers),
        trace.clone(),
    )?;
    println!("grads sent      : {}", report.grads_sent);
    println!("refreshes       : {}", report.refreshes);
    println!("unchanged acks  : {}", report.unchanged_replies);
    println!("bytes sent      : {} (frame granularity)", report.bytes_sent);
    write_trace_out(args, &trace)?;
    Ok(())
}

/// `hybrid-sgd status --connect HOST:PORT`: poll a serving process's
/// read-only ops endpoint. The document is validated by our own JSON
/// parser before a byte of it is printed; `--path a.b[2]` extracts one
/// value with the lazy reader instead of printing the whole document.
/// `--follow` subscribes instead of polling: the server pushes one
/// delta per `--interval-ms` and this prints each as a sequenced line
/// until `--count` deltas arrive (or forever without it).
fn cmd_status(args: &Args) -> anyhow::Result<()> {
    let connect = args
        .get("connect")
        .ok_or_else(|| anyhow::anyhow!("status needs --connect HOST:PORT"))?;
    if args.flag("follow") {
        return cmd_status_follow(args, connect);
    }
    let doc = crate::transport::tcp::query_status(connect, &net_options(args))?;
    let json = crate::util::json::parse(&doc)
        .map_err(|e| anyhow::anyhow!("server sent a malformed status document: {e}"))?;
    match args.get("path") {
        Some(p) => match crate::util::json::scan_path(&doc, p)? {
            Some(v) => println!("{}", v.to_string_compact()),
            None => anyhow::bail!("path `{p}` is not present in the status document"),
        },
        None => println!("{}", json.to_string_pretty()),
    }
    Ok(())
}

/// Shared `--interval-ms` / `--count` handling for the two follower
/// modes (`status --follow` and `trace --connect`).
fn follow_limits(args: &Args) -> anyhow::Result<(u32, Option<u64>)> {
    let interval = args.u64_or("interval-ms", 1000);
    anyhow::ensure!(
        interval >= 1 && interval <= u64::from(u32::MAX),
        "--interval-ms must be between 1 and {}",
        u32::MAX
    );
    let count = match args.get("count") {
        Some(c) => {
            let n: u64 = c.parse().map_err(|_| {
                anyhow::anyhow!("bad --count `{c}` (expected a positive integer)")
            })?;
            anyhow::ensure!(n > 0, "--count must be at least 1");
            Some(n)
        }
        None => None,
    };
    Ok((interval as u32, count))
}

fn cmd_status_follow(args: &Args, connect: &str) -> anyhow::Result<()> {
    let (interval_ms, count) = follow_limits(args)?;
    let path = args.get("path").map(str::to_owned);
    let mut seen = 0u64;
    let mut failure: Option<anyhow::Error> = None;
    crate::transport::tcp::follow_status(connect, &net_options(args), interval_ms, |seq, doc| {
        // The callback only steers the stream (true = keep following);
        // errors are parked and surfaced once `follow_status` returns.
        let line = (|| -> anyhow::Result<String> {
            let json = crate::util::json::parse(doc)
                .map_err(|e| anyhow::anyhow!("server sent a malformed status delta: {e}"))?;
            match &path {
                Some(p) => match crate::util::json::scan_path(doc, p)? {
                    Some(v) => Ok(v.to_string_compact()),
                    None => anyhow::bail!("path `{p}` is not present in the status delta"),
                },
                None => Ok(json.to_string_compact()),
            }
        })();
        match line {
            Ok(line) => {
                println!("[{seq}] {line}");
                seen += 1;
                count.map_or(true, |n| seen < n)
            }
            Err(e) => {
                failure = Some(e);
                false
            }
        }
    })?;
    if let Some(e) = failure {
        return Err(e);
    }
    if let Some(n) = count {
        anyhow::ensure!(
            seen >= n,
            "stream ended after {seen} of {n} requested deltas"
        );
    }
    Ok(())
}

/// The gradient-lifecycle span stages in pipeline order — the order the
/// critical-path table prints them in.
const LIFECYCLE_ORDER: [&str; 7] = [
    "compute",
    "encode",
    "wire",
    "queue",
    "accumulate",
    "flush_wait",
    "apply",
];

/// `hybrid-sgd trace FILE`: offline analyzer for a `--trace` export.
/// Validates the Chrome trace document with our own JSON parser and
/// prints a critical-path breakdown (count / total / p50 / p99 / share
/// per stage). `--require-stages a,b` turns a missing stage into an
/// error — CI runs it against the multiprocess smoke capture. With
/// `--connect HOST:PORT` it instead follows a serving process and
/// prints the live per-stage latency summaries from each status delta.
fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    if let Some(connect) = args.get("connect") {
        return cmd_trace_live(args, connect);
    }
    let path = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("usage: trace FILE [--require-stages a,b] | trace --connect HOST:PORT")
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("could not read {path}: {e}"))?;
    let report = analyze_trace(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    if let Some(req) = args.get("require-stages") {
        for stage in req.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            anyhow::ensure!(
                report.spans.contains_key(stage) || report.instants.contains_key(stage),
                "required stage `{stage}` never appears in the trace"
            );
        }
    }
    print!("{}", report.render());
    Ok(())
}

/// Per-stage aggregates extracted from a Chrome trace export.
struct TraceReport {
    /// Span durations in microseconds, keyed by stage name.
    spans: std::collections::BTreeMap<String, Vec<f64>>,
    /// Instant counts, keyed by stage name.
    instants: std::collections::BTreeMap<String, u64>,
    recorded: u64,
    dropped: u64,
}

/// Parse and validate a Chrome trace export: object shape, event phases,
/// non-negative timestamps/durations. Returns the per-stage aggregates.
fn analyze_trace(text: &str) -> anyhow::Result<TraceReport> {
    use crate::util::json::Json;
    let doc = crate::util::json::parse(text)
        .map_err(|e| anyhow::anyhow!("not valid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no `traceEvents` array (not a --trace export?)"))?;
    let num = |ev: &Json, key: &str| -> Option<f64> { ev.get(key).and_then(Json::as_f64) };
    let mut report = TraceReport {
        spans: Default::default(),
        instants: Default::default(),
        recorded: doc.get("recorded").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        dropped: doc.get("dropped").and_then(Json::as_f64).unwrap_or(0.0) as u64,
    };
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} has no `ph` phase"))?;
        if ph == "M" {
            continue; // process_name metadata
        }
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("event {i} ({ph}) has no `name`"))?;
        let ts = num(ev, "ts")
            .ok_or_else(|| anyhow::anyhow!("event {i} ({name}) has no numeric `ts`"))?;
        anyhow::ensure!(ts >= 0.0, "event {i} ({name}) has negative ts {ts}");
        match ph {
            "X" => {
                let dur = num(ev, "dur").ok_or_else(|| {
                    anyhow::anyhow!("span event {i} ({name}) has no numeric `dur`")
                })?;
                anyhow::ensure!(dur >= 0.0, "event {i} ({name}) has negative dur {dur}");
                report.spans.entry(name.to_string()).or_default().push(dur);
            }
            "i" => *report.instants.entry(name.to_string()).or_default() += 1,
            other => anyhow::bail!("event {i} ({name}) has unknown phase `{other}`"),
        }
    }
    anyhow::ensure!(
        !report.spans.is_empty() || !report.instants.is_empty(),
        "the trace contains no span or instant events"
    );
    Ok(report)
}

/// Nearest-rank percentile of an unsorted sample (q in 0..=1).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    let rank = ((n as f64 * q).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

impl TraceReport {
    /// The critical-path table: lifecycle stages in pipeline order (then
    /// any others alphabetically), share = fraction of total span time.
    fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "events          : {} recorded, {} dropped by wraparound",
            self.recorded, self.dropped
        );
        let grand: f64 = self.spans.values().flatten().sum();
        let _ = writeln!(
            out,
            "{:<12} {:>7} {:>12} {:>10} {:>10} {:>7}",
            "stage", "count", "total_us", "p50_us", "p99_us", "share"
        );
        let ordered = LIFECYCLE_ORDER
            .iter()
            .copied()
            .filter(|s| self.spans.contains_key(*s))
            .chain(
                self.spans
                    .keys()
                    .map(String::as_str)
                    .filter(|s| !LIFECYCLE_ORDER.contains(s)),
            );
        for stage in ordered {
            let mut durs = self.spans[stage].clone();
            durs.sort_by(f64::total_cmp);
            let total: f64 = durs.iter().sum();
            let share = if grand > 0.0 { 100.0 * total / grand } else { 0.0 };
            let _ = writeln!(
                out,
                "{:<12} {:>7} {:>12.1} {:>10.1} {:>10.1} {:>6.1}%",
                stage,
                durs.len(),
                total,
                percentile(&durs, 0.50),
                percentile(&durs, 0.99),
                share
            );
        }
        if !self.instants.is_empty() {
            let list: Vec<String> = self
                .instants
                .iter()
                .map(|(name, n)| format!("{name}={n}"))
                .collect();
            let _ = writeln!(out, "instants        : {}", list.join(" "));
        }
        out
    }
}

/// `hybrid-sgd trace --connect HOST:PORT`: follow a traced serving
/// process and print the per-stage p50/p99 summaries carried in each
/// pushed status delta.
fn cmd_trace_live(args: &Args, connect: &str) -> anyhow::Result<()> {
    let (interval_ms, count) = follow_limits(args)?;
    let mut seen = 0u64;
    let mut failure: Option<anyhow::Error> = None;
    crate::transport::tcp::follow_status(connect, &net_options(args), interval_ms, |seq, doc| {
        match live_stage_line(doc) {
            Ok(line) => {
                println!("[{seq}] {line}");
                seen += 1;
                count.map_or(true, |n| seen < n)
            }
            Err(e) => {
                failure = Some(e);
                false
            }
        }
    })?;
    if let Some(e) = failure {
        return Err(e);
    }
    if let Some(n) = count {
        anyhow::ensure!(
            seen >= n,
            "stream ended after {seen} of {n} requested deltas"
        );
    }
    Ok(())
}

/// One line of live per-stage summaries from a status delta's `stages`
/// object (present only when the server was started with `--trace`).
fn live_stage_line(doc: &str) -> anyhow::Result<String> {
    use crate::util::json::Json;
    let json = crate::util::json::parse(doc)
        .map_err(|e| anyhow::anyhow!("server sent a malformed status delta: {e}"))?;
    let stages = json.get("stages").ok_or_else(|| {
        anyhow::anyhow!("the status delta has no `stages` — start the server with --trace FILE")
    })?;
    let mut parts: Vec<String> = Vec::new();
    for stage in LIFECYCLE_ORDER {
        let Some(s) = stages.get(stage) else { continue };
        let field = |k: &str| s.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        parts.push(format!(
            "{stage}: n={} p50={}us p99={}us",
            field("count") as u64,
            field("p50_us") as u64,
            field("p99_us") as u64
        ));
    }
    if parts.is_empty() {
        return Ok("(no spans recorded yet)".to_string());
    }
    Ok(parts.join(" | "))
}

fn workload_batch_source(
    w: &super::runner::Workload,
    cfg: &ExpConfig,
) -> std::sync::Arc<dyn Fn(usize) -> Box<dyn crate::coordinator::worker::BatchSource> + Send + Sync>
{
    let shards = w
        .train_set
        .partition_indices(cfg.workers, &cfg.partition, cfg.seed);
    let train = std::sync::Arc::clone(&w.train_set);
    let batch = cfg.batch;
    let seed = cfg.seed;
    std::sync::Arc::new(move |id| {
        // `% len`: elastic joiners (simulated `join:+N` slots past the
        // launch complement) reuse a launch worker's data shard, keeping
        // every launch worker's data identical with or without churn.
        Box::new(crate::data::Batcher::new(
            std::sync::Arc::clone(&train),
            shards[id % shards.len()].clone(),
            batch,
            crate::util::rng::Pcg64::new(seed, id as u64),
        )) as Box<dyn crate::coordinator::worker::BatchSource>
    })
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = config_from(args, DatasetKind::Random)?;
    let cmp = run_comparison(&cfg)?;
    println!("{}", comparison_charts(&format!("compare [{}]", cfg.tag()), &cmp));
    println!("interval-mean diffs (hybrid − async):");
    let d = cmp.diff_vs(Algo::Async)?;
    println!("  test accuracy : {:+.3}", d.test_acc);
    println!("  test loss     : {:+.3}", d.test_loss);
    println!("  train loss    : {:+.3}", d.train_loss);
    for (algo, avg) in &cmp.averaged {
        println!(
            "  {:<7} {:>8.1} grads/s, {:>8.1} updates, staleness {:.2}",
            algo.name(),
            avg.grads_per_sec,
            avg.updates_total,
            avg.mean_staleness
        );
    }
    let dir = results_dir(args)?;
    save(
        &dir,
        &format!("compare_{}.csv", cfg.tag()),
        &super::figures::comparison_csv(&cmp),
    )?;
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let id: usize = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: table <1-5>"))?
        .parse()?;
    let base = config_from(args, DatasetKind::Random)?;
    let table = run_table(id, &base)?;
    let md = table.to_markdown();
    println!("{md}");
    println!(
        "hybrid beats async on accuracy in {:.0}% of configurations",
        table.win_fraction() * 100.0
    );
    let dir = results_dir(args)?;
    save(&dir, &format!("table{id}.md"), &md)?;
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id: usize = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: figure <4-10>"))?
        .parse()?;
    let base = config_from(args, DatasetKind::Random)?;
    let fig = run_figure(id, &base)?;
    println!("{}", fig.chart);
    let dir = results_dir(args)?;
    for (name, csv) in &fig.csv {
        save(&dir, name, csv)?;
    }
    Ok(())
}

fn cmd_all(args: &Args) -> anyhow::Result<()> {
    let dir = results_dir(args)?;
    let mut summary = String::from("# Regenerated tables and figures\n\n");
    for id in 1..=5usize {
        let base = config_from(args, DatasetKind::Random)?;
        let table = run_table(id, &base)?;
        let md = table.to_markdown();
        println!("{md}");
        summary.push_str(&md);
        save(&dir, &format!("table{id}.md"), &md)?;
        // figures 8-10 reuse tables 3-5
        if let Some(fig_id) = match id {
            3 => Some(8usize),
            4 => Some(9),
            5 => Some(10),
            _ => None,
        } {
            let xlabel = match fig_id {
                8 => "batch size",
                9 => "step size",
                _ => "delay (mean, std)",
            };
            let fig = figure_from_table(fig_id, xlabel, &table);
            println!("{}", fig.chart);
            for (name, csv) in &fig.csv {
                save(&dir, name, csv)?;
            }
        }
        // curve figures from tables 1-2 comparisons
        if id <= 2 {
            for (ci, cmp) in table.comparisons.iter().enumerate() {
                let fig_id = if id == 1 { 4 + ci / 2 } else { 6 + ci / 2 };
                let name = format!("figure{}_{}.csv", fig_id, cmp.cfg.tag());
                save(&dir, &name, &super::figures::comparison_csv(cmp))?;
            }
        }
    }
    save(&dir, "summary.md", &summary)?;
    Ok(())
}
