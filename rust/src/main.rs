//! `hybrid-sgd` CLI — train, compare algorithms, and regenerate the paper's
//! tables and figures. See README.md for usage.

fn main() {
    if let Err(e) = hybrid_sgd::experiments::cli_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
