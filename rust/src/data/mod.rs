//! Datasets, sharding and batching.
//!
//! All supervised datasets share one in-memory layout: row-major flat f32
//! features (`dim` per sample) and i32 class labels — exactly the tensor
//! interface the AOT grad/eval executables take. Generators are fully
//! procedural and seeded (the image has no network access; see DESIGN.md §1.2
//! for the MNIST/CIFAR substitution rationale).

pub mod random_cluster;
pub mod synth_cifar;
pub mod synth_mnist;
pub mod tokens;

use crate::util::rng::Pcg64;

/// How training samples are split across workers
/// (`partition=iid|dirichlet:<alpha>` in the scenario DSL).
#[derive(Clone, Debug, PartialEq)]
pub enum Partition {
    /// Round-robin dealing — the historical default, class-balanced.
    Iid,
    /// Label-skewed non-IID shards: per class, worker shares are drawn
    /// from Dirichlet(α·1`_W`). Small α concentrates each class on few
    /// workers (heterogeneous federated-style shards); large α recovers
    /// near-IID balance.
    Dirichlet(f64),
}

impl Partition {
    pub fn parse(s: &str) -> anyhow::Result<Partition> {
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        if let Some(rest) = s.strip_prefix("dirichlet:") {
            let alpha: f64 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad dirichlet alpha `{rest}`"))?;
            anyhow::ensure!(
                alpha.is_finite() && alpha > 0.0,
                "dirichlet alpha must be a positive finite number, got `{rest}`"
            );
            return Ok(Partition::Dirichlet(alpha));
        }
        anyhow::bail!("unknown partition `{s}` (expected `iid` or `dirichlet:<alpha>`)")
    }

    pub fn is_iid(&self) -> bool {
        matches!(self, Partition::Iid)
    }
}

impl std::fmt::Display for Partition {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Partition::Iid => write!(f, "iid"),
            Partition::Dirichlet(a) => write!(f, "dirichlet:{a}"),
        }
    }
}

/// An in-memory supervised dataset: `n` samples of `dim` features + label.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    pub classes: usize,
    pub x: Vec<f32>,
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature row of sample `i`.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Split into (train, test) by a shuffled index permutation.
    /// `train_frac` in (0, 1); the paper's random-dataset experiments use 0.8.
    pub fn split(&self, train_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&train_frac) && train_frac > 0.0);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_train = ((self.len() as f64) * train_frac).round() as usize;
        let take = |ids: &[usize], tag: &str| {
            let mut x = Vec::with_capacity(ids.len() * self.dim);
            let mut y = Vec::with_capacity(ids.len());
            for &i in ids {
                x.extend_from_slice(self.row(i));
                y.push(self.y[i]);
            }
            Dataset {
                name: format!("{}-{tag}", self.name),
                dim: self.dim,
                classes: self.classes,
                x,
                y,
            }
        };
        (take(&idx[..n_train], "train"), take(&idx[n_train..], "test"))
    }

    /// Contiguous shards for `w` workers (round-robin so class balance is
    /// preserved regardless of generation order).
    pub fn shard_indices(&self, w: usize) -> Vec<Vec<usize>> {
        let mut shards = vec![Vec::new(); w];
        for i in 0..self.len() {
            shards[i % w].push(i);
        }
        shards
    }

    /// Shards for `w` workers under a [`Partition`]. `Iid` delegates to
    /// [`Dataset::shard_indices`] (bitwise the historical sharding);
    /// `Dirichlet(α)` draws, per class, worker shares from Dirichlet(α·1
    /// `_W`) (seeded — same seed, same shards) and deals that class's
    /// shuffled samples out proportionally. Every sample lands in exactly
    /// one shard; a worker left with nothing steals one sample from the
    /// richest shard so the `Batcher`'s non-empty invariant holds.
    pub fn partition_indices(&self, w: usize, p: &Partition, seed: u64) -> Vec<Vec<usize>> {
        let alpha = match p {
            Partition::Iid => return self.shard_indices(w),
            Partition::Dirichlet(a) => *a,
        };
        let mut rng = Pcg64::new(seed, 0xD161);
        let mut shards = vec![Vec::new(); w];
        for class in 0..self.classes.max(1) {
            let mut members: Vec<usize> = (0..self.len())
                .filter(|&i| self.y[i] as usize == class)
                .collect();
            if members.is_empty() {
                continue;
            }
            rng.shuffle(&mut members);
            let weights: Vec<f64> = (0..w).map(|_| rng.gamma(alpha)).collect();
            let total: f64 = weights.iter().sum();
            // Deep-subnormal α can underflow every draw to zero; fall
            // back to even shares rather than divide by zero.
            let (weights, total) = if total.is_finite() && total > 0.0 {
                (weights, total)
            } else {
                (vec![1.0; w], w as f64)
            };
            let n = members.len() as f64;
            let mut start = 0usize;
            let mut cum = 0.0;
            for (j, wt) in weights.iter().enumerate() {
                cum += *wt;
                let end = if j + 1 == w {
                    members.len()
                } else {
                    (((cum / total) * n).round() as usize).clamp(start, members.len())
                };
                shards[j].extend_from_slice(&members[start..end]);
                start = end;
            }
        }
        for j in 0..w {
            if shards[j].is_empty() {
                let rich = (0..w).max_by_key(|&i| shards[i].len()).unwrap();
                if shards[rich].len() > 1 {
                    let taken = shards[rich].pop().unwrap();
                    shards[j].push(taken);
                }
            }
        }
        shards
    }

    /// Subsample `n` rows (seeded) — used for the fixed train-loss probe set.
    pub fn subsample(&self, n: usize, rng: &mut Pcg64) -> Dataset {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        idx.truncate(n.min(self.len()));
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in &idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset {
            name: format!("{}-sub{n}", self.name),
            dim: self.dim,
            classes: self.classes,
            x,
            y,
        }
    }
}

/// Mini-batch sampler over a worker's shard: reshuffles each epoch, yields
/// `(x, y)` buffers of exactly `batch` samples (wraps across epochs so every
/// draw is full-size, as PyTorch's `drop_last=False` + cycling would).
///
/// Owns an `Arc<Dataset>` so it can move into a worker thread.
pub struct Batcher {
    data: std::sync::Arc<Dataset>,
    shard: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Pcg64,
    /// Reused output buffers: the worker hot loop must not allocate.
    x_buf: Vec<f32>,
    y_buf: Vec<i32>,
}

impl Batcher {
    pub fn new(
        data: std::sync::Arc<Dataset>,
        shard: Vec<usize>,
        batch: usize,
        mut rng: Pcg64,
    ) -> Self {
        assert!(!shard.is_empty(), "empty shard");
        assert!(batch > 0);
        let mut shard = shard;
        rng.shuffle(&mut shard);
        Batcher {
            x_buf: vec![0.0; batch * data.dim],
            y_buf: vec![0; batch],
            data,
            shard,
            batch,
            cursor: 0,
            rng,
        }
    }

    /// Next mini-batch; returns borrowed buffers valid until the next call.
    pub fn next_batch(&mut self) -> (&[f32], &[i32]) {
        let dim = self.data.dim;
        for j in 0..self.batch {
            if self.cursor == self.shard.len() {
                self.rng.shuffle(&mut self.shard);
                self.cursor = 0;
            }
            let i = self.shard[self.cursor];
            self.cursor += 1;
            self.x_buf[j * dim..(j + 1) * dim].copy_from_slice(self.data.row(i));
            self.y_buf[j] = self.data.y[i];
        }
        (&self.x_buf, &self.y_buf)
    }
}

/// Per-class counts — used by generator tests to assert balance.
pub fn class_histogram(y: &[i32], classes: usize) -> Vec<usize> {
    let mut h = vec![0usize; classes];
    for &c in y {
        h[c as usize] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        Dataset {
            name: "toy".into(),
            dim: 2,
            classes: 2,
            x: (0..n * 2).map(|i| i as f32).collect(),
            y: (0..n).map(|i| (i % 2) as i32).collect(),
        }
    }

    #[test]
    fn split_preserves_samples() {
        let d = toy(100);
        let (tr, te) = d.split(0.8, &mut Pcg64::seeded(1));
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.dim, 2);
        // every row of tr/te exists in d
        let all_first: std::collections::BTreeSet<i64> =
            (0..d.len()).map(|i| d.row(i)[0] as i64).collect();
        for i in 0..tr.len() {
            assert!(all_first.contains(&(tr.row(i)[0] as i64)));
        }
    }

    #[test]
    fn shards_partition() {
        let d = toy(10);
        let shards = d.shard_indices(3);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 10);
        let mut seen = vec![false; 10];
        for s in &shards {
            for &i in s {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
    }

    #[test]
    fn batcher_wraps_epochs() {
        let d = toy(5);
        let mut b = Batcher::new(std::sync::Arc::new(d), (0..5).collect(), 3, Pcg64::seeded(2));
        for _ in 0..10 {
            let (x, y) = b.next_batch();
            assert_eq!(x.len(), 6);
            assert_eq!(y.len(), 3);
        }
    }

    #[test]
    fn batcher_covers_shard_within_epoch() {
        let d = toy(6);
        let mut b = Batcher::new(std::sync::Arc::new(d), (0..6).collect(), 2, Pcg64::seeded(3));
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..3 {
            let (x, _) = b.next_batch();
            seen.insert(x[0] as i64);
            seen.insert(x[2] as i64);
        }
        // 3 batches x 2 samples = one full epoch: all 6 distinct rows seen
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn histogram_counts() {
        let d = toy(10);
        assert_eq!(class_histogram(&d.y, 2), vec![5, 5]);
    }

    #[test]
    fn partition_parse_roundtrip() {
        for s in ["iid", "dirichlet:0.1", "dirichlet:5"] {
            let p = Partition::parse(s).unwrap();
            assert_eq!(p.to_string(), s);
        }
        assert!(Partition::parse("dirichlet:0").is_err());
        assert!(Partition::parse("dirichlet:-1").is_err());
        assert!(Partition::parse("dirichlet:nan").is_err());
        assert!(Partition::parse("zipf:2").is_err());
        assert!(Partition::Iid.is_iid());
        assert!(!Partition::Dirichlet(0.5).is_iid());
    }

    #[test]
    fn iid_partition_is_the_historical_sharding() {
        let d = toy(100);
        assert_eq!(
            d.partition_indices(3, &Partition::Iid, 42),
            d.shard_indices(3)
        );
    }

    #[test]
    fn dirichlet_partition_covers_and_skews() {
        let d = toy(400);
        let p = Partition::Dirichlet(0.05);
        let shards = d.partition_indices(4, &p, 7);
        // Same seed → same shards (the sim's replay depends on it).
        assert_eq!(shards, d.partition_indices(4, &p, 7));
        // Exact cover: every sample in exactly one shard, none empty.
        let mut seen = vec![false; d.len()];
        for s in &shards {
            assert!(!s.is_empty(), "a worker was starved of data");
            for &i in s {
                assert!(!seen[i], "sample {i} dealt twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "not every sample was dealt");
        // Label skew: with α = 0.05 over 4 workers, some worker holds far
        // more than the IID quarter of class 0.
        let max_share = shards
            .iter()
            .map(|s| s.iter().filter(|&&i| d.y[i] == 0).count())
            .max()
            .unwrap() as f64
            / 200.0;
        assert!(max_share > 0.4, "no label skew: max class-0 share {max_share}");
        // ... and a large α is close to balanced.
        let balanced = d.partition_indices(4, &Partition::Dirichlet(1000.0), 7);
        for s in &balanced {
            let share = s.iter().filter(|&&i| d.y[i] == 0).count() as f64 / 200.0;
            assert!((share - 0.25).abs() < 0.1, "α→∞ should be near-IID: {share}");
        }
    }
}
