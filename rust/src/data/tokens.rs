//! Synthetic token corpus for the end-to-end transformer driver.
//!
//! Generates a character-level corpus from a seeded second-order Markov
//! source with sparse transitions plus interleaved "quoted phrases" (exact
//! repeats of a handful of memorised strings). The source entropy is well
//! below `log(vocab)`, so a causal LM trained through the full PS stack shows
//! a genuine falling loss curve: from ~ln(V) at init toward the source's
//! conditional entropy.

use crate::util::rng::Pcg64;

/// A token corpus plus the sliding-window view used for LM training.
#[derive(Clone, Debug)]
pub struct TokenDataset {
    pub name: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub tokens: Vec<i32>,
    /// Window start offsets usable for (input, target) pairs.
    starts: Vec<usize>,
}

impl TokenDataset {
    pub fn num_windows(&self) -> usize {
        self.starts.len()
    }

    /// Copy window `w` into the caller's buffers: `input = tokens[s..s+L]`,
    /// `target = tokens[s+1..s+L+1]`.
    pub fn window(&self, w: usize, input: &mut [i32], target: &mut [i32]) {
        let s = self.starts[w];
        input.copy_from_slice(&self.tokens[s..s + self.seq_len]);
        target.copy_from_slice(&self.tokens[s + 1..s + 1 + self.seq_len]);
    }

    /// Split window indices into (train, test) shards.
    pub fn split_windows(&self, train_frac: f64, rng: &mut Pcg64) -> (Vec<usize>, Vec<usize>) {
        let mut idx: Vec<usize> = (0..self.num_windows()).collect();
        rng.shuffle(&mut idx);
        let n = ((idx.len() as f64) * train_frac) as usize;
        (idx[..n].to_vec(), idx[n..].to_vec())
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub length: usize,
    pub seq_len: usize,
    /// Each previous-token context allows this many successor tokens.
    pub branching: usize,
    /// Number of memorised phrases injected verbatim.
    pub phrases: usize,
    pub phrase_len: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            vocab: 64,
            length: 200_000,
            seq_len: 64,
            branching: 4,
            phrases: 12,
            phrase_len: 24,
        }
    }
}

/// Generate the corpus. Deterministic in (spec, seed).
pub fn generate(spec: &CorpusSpec, rng: &mut Pcg64) -> TokenDataset {
    assert!(spec.vocab >= 4 && spec.branching >= 1);
    // Sparse first-order transition table: successors[prev] is a list of
    // `branching` allowed next tokens (with a preferred first choice).
    // First-order keeps the bigram conditional entropy low (learnable by a
    // small LM); the injected phrases add longer-range structure on top.
    let v = spec.vocab;
    let mut successors = vec![0i32; v * spec.branching];
    for ctx in 0..v {
        for k in 0..spec.branching {
            successors[ctx * spec.branching + k] = rng.below(v as u64) as i32;
        }
    }
    // Memorised phrases.
    let phrases: Vec<Vec<i32>> = (0..spec.phrases)
        .map(|_| {
            (0..spec.phrase_len)
                .map(|_| rng.below(v as u64) as i32)
                .collect()
        })
        .collect();

    let mut tokens = Vec::with_capacity(spec.length);
    tokens.push(rng.below(v as u64) as i32);
    tokens.push(rng.below(v as u64) as i32);
    while tokens.len() < spec.length {
        if !phrases.is_empty() && rng.chance(0.02) {
            let p = &phrases[rng.below(phrases.len() as u64) as usize];
            tokens.extend_from_slice(p);
            continue;
        }
        let ctx = tokens[tokens.len() - 1] as usize;
        // Zipf-ish choice among the allowed successors: first is most likely.
        let r = rng.next_f64();
        let k = if r < 0.6 {
            0
        } else if r < 0.85 {
            1 % spec.branching
        } else {
            rng.below(spec.branching as u64) as usize
        };
        tokens.push(successors[ctx * spec.branching + k]);
    }
    tokens.truncate(spec.length);

    let stride = spec.seq_len / 2;
    let starts: Vec<usize> = (0..spec.length.saturating_sub(spec.seq_len + 1))
        .step_by(stride.max(1))
        .collect();
    TokenDataset {
        name: format!("markov-v{v}"),
        vocab: v,
        seq_len: spec.seq_len,
        tokens,
        starts,
    }
}

/// Mini-batch sampler over token windows (same reuse discipline as
/// `data::Batcher`).
pub struct TokenBatcher {
    data: std::sync::Arc<TokenDataset>,
    shard: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Pcg64,
    in_buf: Vec<i32>,
    tgt_buf: Vec<i32>,
}

impl TokenBatcher {
    pub fn new(
        data: std::sync::Arc<TokenDataset>,
        shard: Vec<usize>,
        batch: usize,
        mut rng: Pcg64,
    ) -> Self {
        assert!(!shard.is_empty());
        let mut shard = shard;
        rng.shuffle(&mut shard);
        TokenBatcher {
            in_buf: vec![0; batch * data.seq_len],
            tgt_buf: vec![0; batch * data.seq_len],
            data,
            shard,
            batch,
            cursor: 0,
            rng,
        }
    }

    pub fn next_batch(&mut self) -> (&[i32], &[i32]) {
        let l = self.data.seq_len;
        for j in 0..self.batch {
            if self.cursor == self.shard.len() {
                self.rng.shuffle(&mut self.shard);
                self.cursor = 0;
            }
            let w = self.shard[self.cursor];
            self.cursor += 1;
            let (i0, i1) = (j * l, (j + 1) * l);
            self.data
                .window(w, &mut self.in_buf[i0..i1], &mut self.tgt_buf[i0..i1]);
        }
        (&self.in_buf, &self.tgt_buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_shapes() {
        let spec = CorpusSpec {
            length: 5000,
            ..Default::default()
        };
        let d = generate(&spec, &mut Pcg64::seeded(1));
        assert_eq!(d.tokens.len(), 5000);
        assert!(d.tokens.iter().all(|&t| (0..64).contains(&t)));
        assert!(d.num_windows() > 100);
    }

    #[test]
    fn windows_are_shifted_pairs() {
        let spec = CorpusSpec {
            length: 2000,
            seq_len: 8,
            ..Default::default()
        };
        let d = generate(&spec, &mut Pcg64::seeded(2));
        let mut inp = vec![0; 8];
        let mut tgt = vec![0; 8];
        d.window(3, &mut inp, &mut tgt);
        assert_eq!(&inp[1..], &tgt[..7]);
    }

    #[test]
    fn low_entropy_source() {
        // Bigram conditional entropy must be well below log2(V): the corpus
        // must be learnable.
        let spec = CorpusSpec {
            length: 50_000,
            ..Default::default()
        };
        let d = generate(&spec, &mut Pcg64::seeded(3));
        let v = d.vocab;
        let mut counts = vec![0.0f64; v * v];
        for w in d.tokens.windows(2) {
            counts[w[0] as usize * v + w[1] as usize] += 1.0;
        }
        let mut h = 0.0;
        let total: f64 = counts.iter().sum();
        for row in counts.chunks(v) {
            let rs: f64 = row.iter().sum();
            if rs == 0.0 {
                continue;
            }
            for &c in row {
                if c > 0.0 {
                    let p_joint = c / total;
                    let p_cond = c / rs;
                    h -= p_joint * p_cond.log2();
                }
            }
        }
        let hmax = (v as f64).log2();
        assert!(h < hmax * 0.75, "conditional entropy {h:.2} vs max {hmax:.2}");
    }

    #[test]
    fn batcher_yields_full_batches() {
        let spec = CorpusSpec {
            length: 4000,
            seq_len: 16,
            ..Default::default()
        };
        let d = generate(&spec, &mut Pcg64::seeded(4));
        let shard: Vec<usize> = (0..d.num_windows()).collect();
        let mut b = TokenBatcher::new(std::sync::Arc::new(d), shard, 4, Pcg64::seeded(5));
        for _ in 0..20 {
            let (i, t) = b.next_batch();
            assert_eq!(i.len(), 64);
            assert_eq!(t.len(), 64);
        }
    }
}
