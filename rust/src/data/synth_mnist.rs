//! Procedural MNIST lookalike: 28×28 grayscale handwritten-style digits.
//!
//! No network access ⇒ no real MNIST. Each digit class is a set of stroke
//! segments (roughly the pen strokes of the glyph); every sample renders the
//! strokes through a random affine jitter (translate / scale / rotate /
//! shear), random stroke thickness, and additive pixel noise. This yields a
//! 10-class image problem with real intra-class variation that a small CNN
//! fits in minutes but not instantly — the role MNIST plays in the paper.

use super::Dataset;
use crate::util::rng::Pcg64;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;

/// Stroke endpoints in glyph-local unit coordinates (x right, y down).
type Seg = ((f32, f32), (f32, f32));

/// Pen strokes per digit. Hand-authored to mimic the topology of each glyph
/// (loops approximated by polylines).
fn strokes(digit: usize) -> &'static [Seg] {
    const O: &[Seg] = &[
        ((0.25, 0.15), (0.75, 0.15)),
        ((0.75, 0.15), (0.85, 0.5)),
        ((0.85, 0.5), (0.75, 0.85)),
        ((0.75, 0.85), (0.25, 0.85)),
        ((0.25, 0.85), (0.15, 0.5)),
        ((0.15, 0.5), (0.25, 0.15)),
    ];
    const I: &[Seg] = &[((0.35, 0.25), (0.5, 0.1)), ((0.5, 0.1), (0.5, 0.9)), ((0.3, 0.9), (0.7, 0.9))];
    const TWO: &[Seg] = &[
        ((0.2, 0.25), (0.4, 0.1)),
        ((0.4, 0.1), (0.7, 0.12)),
        ((0.7, 0.12), (0.8, 0.35)),
        ((0.8, 0.35), (0.2, 0.9)),
        ((0.2, 0.9), (0.85, 0.9)),
    ];
    const THREE: &[Seg] = &[
        ((0.2, 0.12), (0.75, 0.12)),
        ((0.75, 0.12), (0.5, 0.45)),
        ((0.5, 0.45), (0.8, 0.65)),
        ((0.8, 0.65), (0.7, 0.88)),
        ((0.7, 0.88), (0.2, 0.88)),
    ];
    const FOUR: &[Seg] = &[
        ((0.6, 0.1), (0.15, 0.6)),
        ((0.15, 0.6), (0.85, 0.6)),
        ((0.62, 0.35), (0.62, 0.9)),
    ];
    const FIVE: &[Seg] = &[
        ((0.8, 0.1), (0.25, 0.1)),
        ((0.25, 0.1), (0.22, 0.45)),
        ((0.22, 0.45), (0.7, 0.45)),
        ((0.7, 0.45), (0.8, 0.68)),
        ((0.8, 0.68), (0.65, 0.9)),
        ((0.65, 0.9), (0.2, 0.88)),
    ];
    const SIX: &[Seg] = &[
        ((0.7, 0.1), (0.35, 0.35)),
        ((0.35, 0.35), (0.2, 0.65)),
        ((0.2, 0.65), (0.35, 0.9)),
        ((0.35, 0.9), (0.7, 0.88)),
        ((0.7, 0.88), (0.78, 0.65)),
        ((0.78, 0.65), (0.6, 0.52)),
        ((0.6, 0.52), (0.25, 0.6)),
    ];
    const SEVEN: &[Seg] = &[
        ((0.15, 0.12), (0.85, 0.12)),
        ((0.85, 0.12), (0.45, 0.9)),
        ((0.3, 0.5), (0.7, 0.5)),
    ];
    const EIGHT: &[Seg] = &[
        ((0.5, 0.1), (0.75, 0.28)),
        ((0.75, 0.28), (0.5, 0.48)),
        ((0.5, 0.48), (0.25, 0.28)),
        ((0.25, 0.28), (0.5, 0.1)),
        ((0.5, 0.48), (0.8, 0.7)),
        ((0.8, 0.7), (0.5, 0.9)),
        ((0.5, 0.9), (0.2, 0.7)),
        ((0.2, 0.7), (0.5, 0.48)),
    ];
    const NINE: &[Seg] = &[
        ((0.75, 0.4), (0.55, 0.5)),
        ((0.55, 0.5), (0.25, 0.4)),
        ((0.25, 0.4), (0.3, 0.15)),
        ((0.3, 0.15), (0.65, 0.1)),
        ((0.65, 0.1), (0.75, 0.4)),
        ((0.75, 0.4), (0.6, 0.9)),
    ];
    match digit {
        0 => O,
        1 => I,
        2 => TWO,
        3 => THREE,
        4 => FOUR,
        5 => FIVE,
        6 => SIX,
        7 => SEVEN,
        8 => EIGHT,
        9 => NINE,
        _ => unreachable!("digit out of range"),
    }
}

/// Random per-sample affine transform in glyph space.
struct Jitter {
    sx: f32,
    sy: f32,
    rot: f32,
    shear: f32,
    dx: f32,
    dy: f32,
    thick: f32,
    gain: f32,
}

impl Jitter {
    fn sample(rng: &mut Pcg64) -> Jitter {
        Jitter {
            sx: rng.uniform(0.75, 1.05) as f32,
            sy: rng.uniform(0.75, 1.05) as f32,
            rot: rng.uniform(-0.18, 0.18) as f32,
            shear: rng.uniform(-0.15, 0.15) as f32,
            dx: rng.uniform(-0.08, 0.08) as f32,
            dy: rng.uniform(-0.08, 0.08) as f32,
            thick: rng.uniform(0.045, 0.085) as f32,
            gain: rng.uniform(0.75, 1.0) as f32,
        }
    }

    fn apply(&self, p: (f32, f32)) -> (f32, f32) {
        // centre, scale+shear+rotate, translate back
        let (mut x, mut y) = (p.0 - 0.5, p.1 - 0.5);
        x *= self.sx;
        y *= self.sy;
        x += self.shear * y;
        let (c, s) = (self.rot.cos(), self.rot.sin());
        let (xr, yr) = (c * x - s * y, s * x + c * y);
        (xr + 0.5 + self.dx, yr + 0.5 + self.dy)
    }
}

/// Render one digit into a DIM-length buffer (values in [0, 1]).
pub fn render_digit(digit: usize, rng: &mut Pcg64, out: &mut [f32]) {
    assert_eq!(out.len(), DIM);
    out.fill(0.0);
    let j = Jitter::sample(rng);
    for &(a, b) in strokes(digit) {
        let (ax, ay) = j.apply(a);
        let (bx, by) = j.apply(b);
        draw_segment(out, ax, ay, bx, by, j.thick, j.gain);
    }
    // Additive noise + clamp (sensor-style grain).
    for v in out.iter_mut() {
        let noise = rng.normal_ms(0.0, 0.03) as f32;
        *v = (*v + noise).clamp(0.0, 1.0);
    }
}

/// Splat a thick anti-aliased segment (unit coords) into the grid.
fn draw_segment(out: &mut [f32], ax: f32, ay: f32, bx: f32, by: f32, thick: f32, gain: f32) {
    let n = SIDE as f32;
    let (x0, y0) = (ax * n, ay * n);
    let (x1, y1) = (bx * n, by * n);
    let r = thick * n;
    let (dx, dy) = (x1 - x0, y1 - y0);
    let len2 = dx * dx + dy * dy;
    let min_x = (x0.min(x1) - r - 1.0).floor().max(0.0) as usize;
    let max_x = (x0.max(x1) + r + 1.0).ceil().min(n - 1.0) as usize;
    let min_y = (y0.min(y1) - r - 1.0).floor().max(0.0) as usize;
    let max_y = (y0.max(y1) + r + 1.0).ceil().min(n - 1.0) as usize;
    for py in min_y..=max_y {
        for px in min_x..=max_x {
            let (cx, cy) = (px as f32 + 0.5, py as f32 + 0.5);
            // distance from pixel centre to segment
            let t = if len2 > 0.0 {
                (((cx - x0) * dx + (cy - y0) * dy) / len2).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let (qx, qy) = (x0 + t * dx, y0 + t * dy);
            let d = ((cx - qx) * (cx - qx) + (cy - qy) * (cy - qy)).sqrt();
            // soft falloff at the stroke edge
            let a = (1.0 - (d - r * 0.5).max(0.0) / (r * 0.75)).clamp(0.0, 1.0);
            let idx = py * SIDE + px;
            out[idx] = out[idx].max(a * gain);
        }
    }
}

/// Generate a full dataset of `n` samples with balanced classes.
pub fn generate(n: usize, rng: &mut Pcg64) -> Dataset {
    let mut x = vec![0.0f32; n * DIM];
    let mut y = Vec::with_capacity(n);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for (k, &slot) in order.iter().enumerate() {
        let digit = k % 10;
        render_digit(digit, rng, &mut x[slot * DIM..(slot + 1) * DIM]);
        y.push(0); // placeholder; fill below by slot
    }
    // labels must line up with slots
    let mut labels = vec![0i32; n];
    for (k, &slot) in order.iter().enumerate() {
        labels[slot] = (k % 10) as i32;
    }
    y.clear();
    y.extend_from_slice(&labels);
    Dataset {
        name: "synth-mnist".into(),
        dim: DIM,
        classes: 10,
        x,
        y,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::class_histogram;

    #[test]
    fn shapes_and_range() {
        let d = generate(200, &mut Pcg64::seeded(1));
        assert_eq!(d.len(), 200);
        assert_eq!(d.dim, 784);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let h = class_histogram(&d.y, 10);
        assert!(h.iter().all(|&c| c == 20), "{h:?}");
    }

    #[test]
    fn digits_have_ink() {
        let mut rng = Pcg64::seeded(2);
        let mut buf = vec![0.0f32; DIM];
        for digit in 0..10 {
            render_digit(digit, &mut rng, &mut buf);
            let ink: f32 = buf.iter().sum();
            assert!(ink > 10.0, "digit {digit} nearly blank (ink={ink})");
            assert!(ink < DIM as f32 * 0.6, "digit {digit} saturated");
        }
    }

    #[test]
    fn intra_class_variation_exists() {
        let mut rng = Pcg64::seeded(3);
        let mut a = vec![0.0f32; DIM];
        let mut b = vec![0.0f32; DIM];
        render_digit(5, &mut rng, &mut a);
        render_digit(5, &mut rng, &mut b);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 5.0, "two renders of the same digit are identical");
    }

    #[test]
    fn classes_are_distinguishable_by_template() {
        // Mean image per class should differ meaningfully between classes.
        let mut rng = Pcg64::seeded(4);
        let mut means = vec![vec![0.0f32; DIM]; 10];
        let reps = 20;
        let mut buf = vec![0.0f32; DIM];
        for digit in 0..10 {
            for _ in 0..reps {
                render_digit(digit, &mut rng, &mut buf);
                for (m, &v) in means[digit].iter_mut().zip(&buf) {
                    *m += v / reps as f32;
                }
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f32 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                assert!(d > 8.0, "classes {a} and {b} too similar (L1={d})");
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(50, &mut Pcg64::seeded(9));
        let b = generate(50, &mut Pcg64::seeded(9));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
