//! Procedural CIFAR-10 lookalike: 32×32 RGB class-conditional scenes.
//!
//! Stand-in for CIFAR-10 (no downloads offline). Each class is a *generative
//! recipe* combining a colour palette, a background texture field and a
//! foreground shape; samples draw every recipe parameter from seeded
//! distributions, so classes overlap in colour space and require texture +
//! shape cues — a genuinely harder optimisation problem than the digit set,
//! mirroring the MNIST→CIFAR difficulty step the paper leans on (§7.1).

use super::Dataset;
use crate::util::rng::Pcg64;

pub const SIDE: usize = 32;
pub const CHANNELS: usize = 3;
pub const DIM: usize = SIDE * SIDE * CHANNELS;

/// Per-class recipe parameters.
struct Recipe {
    /// Base RGB palette (background, foreground).
    bg: [f32; 3],
    fg: [f32; 3],
    /// Background texture: 0 smooth gradient, 1 horizontal waves,
    /// 2 vertical waves, 3 checker, 4 diagonal stripes.
    texture: u8,
    /// Foreground shape: 0 disc, 1 square, 2 triangle, 3 ring, 4 cross.
    shape: u8,
    /// Texture spatial frequency.
    freq: f32,
}

fn recipe(class: usize) -> Recipe {
    // Hand-picked so that no single cue (colour alone, shape alone)
    // separates all classes.
    const TABLE: [([f32; 3], [f32; 3], u8, u8, f32); 10] = [
        ([0.55, 0.75, 0.95], [0.85, 0.85, 0.90], 0, 0, 2.0), // "plane": sky + light disc
        ([0.45, 0.45, 0.50], [0.80, 0.20, 0.15], 4, 1, 5.0), // "car": asphalt + red box
        ([0.40, 0.65, 0.95], [0.35, 0.30, 0.25], 1, 2, 3.0), // "bird": sky + dark triangle
        ([0.35, 0.55, 0.30], [0.85, 0.60, 0.25], 2, 0, 4.0), // "cat": grass + tan disc
        ([0.50, 0.60, 0.35], [0.55, 0.40, 0.25], 3, 1, 6.0), // "deer": field + brown box
        ([0.45, 0.50, 0.40], [0.30, 0.25, 0.20], 2, 4, 5.0), // "dog": yard + dark cross
        ([0.25, 0.45, 0.30], [0.45, 0.75, 0.35], 1, 3, 7.0), // "frog": pond + green ring
        ([0.50, 0.55, 0.35], [0.60, 0.45, 0.30], 4, 2, 4.0), // "horse": field + triangle
        ([0.20, 0.35, 0.60], [0.70, 0.70, 0.75], 0, 1, 3.0), // "ship": sea + grey box
        ([0.55, 0.55, 0.60], [0.35, 0.55, 0.35], 3, 4, 8.0), // "truck": road + cross
    ];
    let (bg, fg, texture, shape, freq) = TABLE[class];
    Recipe {
        bg,
        fg,
        texture,
        shape,
        freq,
    }
}

/// Render one sample of `class` into `out` (CHW planar layout, values [0,1]).
///
/// Planar (channel-major) layout matches the `(C, H, W)`-style reshape the
/// L2 model applies to the flat feature vector.
pub fn render_scene(class: usize, rng: &mut Pcg64, out: &mut [f32]) {
    assert_eq!(out.len(), DIM);
    let r = recipe(class);
    // Sample-level jitter.
    let hue_shift: [f32; 3] = [
        rng.normal_ms(0.0, 0.06) as f32,
        rng.normal_ms(0.0, 0.06) as f32,
        rng.normal_ms(0.0, 0.06) as f32,
    ];
    let cx = rng.uniform(0.3, 0.7) as f32;
    let cy = rng.uniform(0.3, 0.7) as f32;
    let size = rng.uniform(0.15, 0.30) as f32;
    let phase = rng.uniform(0.0, std::f64::consts::TAU) as f32;
    let freq = r.freq * rng.uniform(0.8, 1.25) as f32;
    let rot = rng.uniform(0.0, std::f64::consts::TAU) as f32;
    let (rc, rs) = (rot.cos(), rot.sin());

    for py in 0..SIDE {
        for px in 0..SIDE {
            let x = (px as f32 + 0.5) / SIDE as f32;
            let y = (py as f32 + 0.5) / SIDE as f32;
            // background intensity from the texture field
            let tex = match r.texture {
                0 => 0.5 + 0.5 * (y + 0.3 * x), // smooth gradient
                1 => 0.5 + 0.5 * (freq * std::f32::consts::TAU * y + phase).sin(),
                2 => 0.5 + 0.5 * (freq * std::f32::consts::TAU * x + phase).sin(),
                3 => {
                    let cxs = ((x * freq).floor() + (y * freq).floor()) as i64;
                    if cxs % 2 == 0 {
                        0.35
                    } else {
                        0.75
                    }
                }
                _ => 0.5 + 0.5 * (freq * std::f32::consts::TAU * (x + y) + phase).sin(),
            };
            // foreground mask from the shape
            let (ux, uy) = (x - cx, y - cy);
            let (sxr, syr) = (rc * ux - rs * uy, rs * ux + rc * uy);
            let inside = match r.shape {
                0 => (sxr * sxr + syr * syr).sqrt() < size,
                1 => sxr.abs() < size && syr.abs() < size,
                2 => syr > -size && syr < size && sxr.abs() < (size - syr) * 0.8,
                3 => {
                    let d = (sxr * sxr + syr * syr).sqrt();
                    d < size && d > size * 0.55
                }
                _ => (sxr.abs() < size * 0.3 && syr.abs() < size)
                    || (syr.abs() < size * 0.3 && sxr.abs() < size),
            };
            for c in 0..CHANNELS {
                let base = if inside { r.fg[c] } else { r.bg[c] * (0.6 + 0.8 * tex) };
                let noise = rng.normal_ms(0.0, 0.04) as f32;
                let v = (base + hue_shift[c] + noise).clamp(0.0, 1.0);
                out[c * SIDE * SIDE + py * SIDE + px] = v;
            }
        }
    }
}

/// Generate `n` samples with balanced classes (shuffled order).
pub fn generate(n: usize, rng: &mut Pcg64) -> Dataset {
    let mut x = vec![0.0f32; n * DIM];
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut labels = vec![0i32; n];
    for (k, &slot) in order.iter().enumerate() {
        let class = k % 10;
        render_scene(class, rng, &mut x[slot * DIM..(slot + 1) * DIM]);
        labels[slot] = class as i32;
    }
    Dataset {
        name: "synth-cifar".into(),
        dim: DIM,
        classes: 10,
        x,
        y: labels,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::class_histogram;

    #[test]
    fn shapes_and_range() {
        let d = generate(100, &mut Pcg64::seeded(1));
        assert_eq!(d.len(), 100);
        assert_eq!(d.dim, 3072);
        assert!(d.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert_eq!(class_histogram(&d.y, 10), vec![10; 10]);
    }

    #[test]
    fn classes_differ_in_mean_image() {
        let mut rng = Pcg64::seeded(2);
        let reps = 12;
        let mut means = vec![vec![0.0f32; DIM]; 10];
        let mut buf = vec![0.0f32; DIM];
        for class in 0..10 {
            for _ in 0..reps {
                render_scene(class, &mut rng, &mut buf);
                for (m, &v) in means[class].iter_mut().zip(&buf) {
                    *m += v / reps as f32;
                }
            }
        }
        for a in 0..10 {
            for b in (a + 1)..10 {
                let d: f32 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y).abs())
                    .sum::<f32>()
                    / DIM as f32;
                assert!(d > 0.01, "classes {a},{b} indistinguishable ({d})");
            }
        }
    }

    #[test]
    fn intra_class_variation() {
        let mut rng = Pcg64::seeded(3);
        let mut a = vec![0.0f32; DIM];
        let mut b = vec![0.0f32; DIM];
        render_scene(4, &mut rng, &mut a);
        render_scene(4, &mut rng, &mut b);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 30.0, "no intra-class variation (L1={diff})");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(30, &mut Pcg64::seeded(5));
        let b = generate(30, &mut Pcg64::seeded(5));
        assert_eq!(a.x, b.x);
    }
}
