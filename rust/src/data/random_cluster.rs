//! The paper's randomly generated dataset (§6): n-dimensional points drawn
//! from class-conditional Gaussian clusters.
//!
//! "we used randomly generated datasets with 20 dimensions and 10 classes
//! containing 10k samples with 80:20 train to test split. A newly sampled
//! dataset was used for each configuration."
//!
//! Class centroids are drawn uniformly in a hypercube with pairwise margin
//! enforced by rejection, then samples are centroid + N(0, σ²) noise. The
//! separation/σ ratio controls problem difficulty: defaults give a problem a
//! linear classifier reaches ~85–95 % on — optimisable but not instant, like
//! the paper's setup.

use super::Dataset;
use crate::util::rng::Pcg64;

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub n_samples: usize,
    pub dim: usize,
    pub classes: usize,
    /// Centroid coordinates drawn from U(-box_half, box_half).
    pub box_half: f64,
    /// Minimum pairwise centroid distance (rejection sampled).
    pub min_margin: f64,
    /// Per-coordinate sample noise σ.
    pub noise: f64,
}

impl Default for ClusterSpec {
    /// The paper's configuration: 10 k samples, 20-dim, 10 classes.
    fn default() -> Self {
        ClusterSpec {
            n_samples: 10_000,
            dim: 20,
            classes: 10,
            box_half: 2.0,
            min_margin: 2.0,
            noise: 1.0,
        }
    }
}

/// Generate a dataset from the spec. Classes are balanced (n/classes each,
/// remainder spread over the first classes) and rows are emitted shuffled.
pub fn generate(spec: &ClusterSpec, rng: &mut Pcg64) -> Dataset {
    let centroids = sample_centroids(spec, rng);
    let mut x = Vec::with_capacity(spec.n_samples * spec.dim);
    let mut y = Vec::with_capacity(spec.n_samples);
    for i in 0..spec.n_samples {
        let c = i % spec.classes;
        y.push(c as i32);
        let base = &centroids[c * spec.dim..(c + 1) * spec.dim];
        for &b in base {
            x.push((b + rng.normal_ms(0.0, spec.noise)) as f32);
        }
    }
    // Shuffle rows jointly.
    let mut idx: Vec<usize> = (0..spec.n_samples).collect();
    rng.shuffle(&mut idx);
    let mut xs = Vec::with_capacity(x.len());
    let mut ys = Vec::with_capacity(y.len());
    for &i in &idx {
        xs.extend_from_slice(&x[i * spec.dim..(i + 1) * spec.dim]);
        ys.push(y[i]);
    }
    Dataset {
        name: format!("random{}d{}c", spec.dim, spec.classes),
        dim: spec.dim,
        classes: spec.classes,
        x: xs,
        y: ys,
    }
}

fn sample_centroids(spec: &ClusterSpec, rng: &mut Pcg64) -> Vec<f64> {
    let mut centroids: Vec<f64> = Vec::with_capacity(spec.classes * spec.dim);
    let mut attempts = 0;
    while centroids.len() < spec.classes * spec.dim {
        let cand: Vec<f64> = (0..spec.dim)
            .map(|_| rng.uniform(-spec.box_half, spec.box_half))
            .collect();
        let ok = centroids.chunks(spec.dim).all(|c| {
            let d2: f64 = c
                .iter()
                .zip(&cand)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            d2.sqrt() >= spec.min_margin
        });
        attempts += 1;
        if ok || attempts > 10_000 {
            // In high dimension rejection almost never triggers; the attempt
            // cap guards degenerate specs (margin too large for the box).
            centroids.extend(cand);
            attempts = 0;
        }
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::class_histogram;

    #[test]
    fn paper_spec_shapes() {
        let spec = ClusterSpec {
            n_samples: 1000,
            ..Default::default()
        };
        let d = generate(&spec, &mut Pcg64::seeded(42));
        assert_eq!(d.len(), 1000);
        assert_eq!(d.dim, 20);
        assert_eq!(d.classes, 10);
        let h = class_histogram(&d.y, 10);
        assert!(h.iter().all(|&c| c == 100), "balanced classes: {h:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = ClusterSpec {
            n_samples: 100,
            ..Default::default()
        };
        let a = generate(&spec, &mut Pcg64::seeded(7));
        let b = generate(&spec, &mut Pcg64::seeded(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = generate(&spec, &mut Pcg64::seeded(8));
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn clusters_are_separable_by_centroid_distance() {
        // Nearest-centroid classification on held-out data should beat 60 %
        // by a wide margin if clusters are real.
        let spec = ClusterSpec {
            n_samples: 2000,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(3);
        let d = generate(&spec, &mut rng);
        // Estimate centroids from the first half, classify the second half.
        let half = d.len() / 2;
        let mut cent = vec![0.0f64; 10 * d.dim];
        let mut count = vec![0usize; 10];
        for i in 0..half {
            let c = d.y[i] as usize;
            count[c] += 1;
            for (k, &v) in d.row(i).iter().enumerate() {
                cent[c * d.dim + k] += v as f64;
            }
        }
        for c in 0..10 {
            for k in 0..d.dim {
                cent[c * d.dim + k] /= count[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in half..d.len() {
            let row = d.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..10 {
                let d2: f64 = row
                    .iter()
                    .zip(&cent[c * d.dim..(c + 1) * d.dim])
                    .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
                    .sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / half as f64;
        assert!(acc > 0.6, "nearest-centroid accuracy too low: {acc}");
    }
}
