//! `artifacts/manifest.json` parsing — the contract between the build-time
//! Python AOT pipeline and the Rust runtime.

use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// One parameter tensor in the flat layout (mirrors `model.LayerSpec`).
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String,
    pub fan_in: usize,
    pub fan_out: usize,
}

impl LayerSpec {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A model entry: dims + the flat parameter layout.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String,
    pub x_dim: usize,
    pub y_dim: usize,
    pub classes: usize,
    pub param_count: usize,
    pub layers: Vec<LayerSpec>,
    /// Transformer-only extras (0 otherwise).
    pub vocab: usize,
    pub seq_len: usize,
}

/// One lowered graph artifact.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub model: String,
    pub kind: String, // "grad" | "eval"
    pub batch: usize,
    pub variant: String, // "jnp" | "pallas"
    pub path: PathBuf,
    pub param_count: usize,
    pub x_dim: usize,
    pub y_dim: usize,
}

/// One parameter-server op artifact (fused update / buffer reduce).
#[derive(Clone, Debug)]
pub struct OpEntry {
    pub op: String,
    pub model: String,
    pub variant: String,
    pub path: PathBuf,
    pub param_count: usize,
    pub k: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    pub ops: Vec<OpEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {} (run `make artifacts`): {e}", path.display()))?;
        let root = parse(&text)?;
        anyhow::ensure!(
            root.usize_field("format_version")? == 1,
            "unsupported manifest format"
        );

        let mut models = Vec::new();
        for (name, m) in root.req("models")?.as_obj().unwrap() {
            let mut layers = Vec::new();
            for l in m.req("layers")?.as_arr().unwrap() {
                layers.push(LayerSpec {
                    name: l.str_field("name")?,
                    shape: l
                        .req("shape")?
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| v.as_usize().unwrap())
                        .collect(),
                    init: l.str_field("init")?,
                    fan_in: l.usize_field("fan_in")?,
                    fan_out: l.usize_field("fan_out")?,
                });
            }
            models.push(ModelEntry {
                name: name.clone(),
                kind: m.str_field("kind")?,
                x_dim: m.usize_field("x_dim")?,
                y_dim: m.usize_field("y_dim")?,
                classes: m.usize_field("classes")?,
                param_count: m.usize_field("param_count")?,
                layers,
                vocab: m.get("vocab").and_then(Json::as_usize).unwrap_or(0),
                seq_len: m.get("seq_len").and_then(Json::as_usize).unwrap_or(0),
            });
        }

        let mut artifacts = Vec::new();
        for a in root.req("artifacts")?.as_arr().unwrap() {
            artifacts.push(ArtifactEntry {
                model: a.str_field("model")?,
                kind: a.str_field("kind")?,
                batch: a.usize_field("batch")?,
                variant: a.str_field("variant")?,
                path: dir.join(a.str_field("path")?),
                param_count: a.usize_field("param_count")?,
                x_dim: a.usize_field("x_dim")?,
                y_dim: a.usize_field("y_dim")?,
            });
        }

        let mut ops = Vec::new();
        for o in root.req("ops")?.as_arr().unwrap() {
            ops.push(OpEntry {
                op: o.str_field("op")?,
                model: o.str_field("model")?,
                variant: o.str_field("variant")?,
                path: dir.join(o.str_field("path")?),
                param_count: o.usize_field("param_count")?,
                k: o.usize_field("k")?,
            });
        }

        Ok(Manifest {
            dir,
            models,
            artifacts,
            ops,
        })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| anyhow::anyhow!("model `{name}` not in manifest"))
    }

    /// Find a graph artifact.
    pub fn graph(
        &self,
        model: &str,
        kind: &str,
        batch: usize,
        variant: &str,
    ) -> anyhow::Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.kind == kind && a.batch == batch && a.variant == variant)
            .ok_or_else(|| {
                let avail: Vec<String> = self
                    .artifacts
                    .iter()
                    .filter(|a| a.model == model && a.kind == kind)
                    .map(|a| format!("b{} {}", a.batch, a.variant))
                    .collect();
                anyhow::anyhow!(
                    "no artifact {model}/{kind} batch={batch} variant={variant}; available: {avail:?}"
                )
            })
    }

    /// The eval artifact for a model (single per model, any batch).
    pub fn eval_graph(&self, model: &str) -> anyhow::Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.kind == "eval")
            .ok_or_else(|| anyhow::anyhow!("no eval artifact for `{model}`"))
    }

    pub fn op(&self, op: &str, model: &str, variant: &str) -> anyhow::Result<&OpEntry> {
        self.ops
            .iter()
            .find(|o| o.op == op && o.model == model && o.variant == variant)
            .ok_or_else(|| anyhow::anyhow!("no op artifact {op}/{model}/{variant}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
 "format_version": 1,
 "models": {
  "mlp": {"kind": "mlp", "x_dim": 20, "y_dim": 1, "classes": 10,
          "param_count": 14,
          "layers": [
            {"name": "w0", "shape": [2, 5], "init": "glorot_uniform", "fan_in": 2, "fan_out": 5},
            {"name": "b0", "shape": [4], "init": "zeros", "fan_in": 0, "fan_out": 0}
          ]}
 },
 "artifacts": [
  {"model": "mlp", "kind": "grad", "batch": 32, "variant": "jnp",
   "path": "mlp_grad_b32_jnp.hlo.txt", "param_count": 14, "x_dim": 20, "y_dim": 1},
  {"model": "mlp", "kind": "eval", "batch": 100, "variant": "jnp",
   "path": "mlp_eval_b100_jnp.hlo.txt", "param_count": 14, "x_dim": 20, "y_dim": 1}
 ],
 "ops": [
  {"op": "sgd_update", "model": "mlp", "variant": "pallas",
   "path": "sgd_update_mlp_pallas.hlo.txt", "param_count": 14, "k": 0}
 ]
}"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn parses_fixture() {
        let dir = std::env::temp_dir().join("hybrid_sgd_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.models.len(), 1);
        let mlp = m.model("mlp").unwrap();
        assert_eq!(mlp.param_count, 14);
        assert_eq!(mlp.layers[0].size(), 10);
        assert_eq!(mlp.layers[0].init, "glorot_uniform");
        let g = m.graph("mlp", "grad", 32, "jnp").unwrap();
        assert!(g.path.ends_with("mlp_grad_b32_jnp.hlo.txt"));
        assert!(m.graph("mlp", "grad", 7, "jnp").is_err());
        assert!(m.eval_graph("mlp").is_ok());
        assert!(m.op("sgd_update", "mlp", "pallas").is_ok());
        assert!(m.op("sgd_update", "mlp", "jnp").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load("/nonexistent/path").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
