//! Parameter initialisation from manifest layer specs.
//!
//! Replicates the init *distributions* the L2 models declare (the layout and
//! distribution matter for the experiments, not bit-equality with JAX):
//! `glorot_uniform` (U(±√(6/(fan_in+fan_out)))), `zeros`, `ones`,
//! `normal:<std>`.

use super::manifest::ModelEntry;
use crate::util::rng::Pcg64;

/// Build the flat initial parameter vector for a model.
pub fn init_params(model: &ModelEntry, rng: &mut Pcg64) -> anyhow::Result<Vec<f32>> {
    let mut out = Vec::with_capacity(model.param_count);
    for layer in &model.layers {
        let n = layer.size();
        let start = out.len();
        out.resize(start + n, 0.0);
        let slice = &mut out[start..];
        match layer.init.as_str() {
            "zeros" => {}
            "ones" => slice.fill(1.0),
            "glorot_uniform" => {
                anyhow::ensure!(
                    layer.fan_in + layer.fan_out > 0,
                    "glorot layer `{}` missing fan dims",
                    layer.name
                );
                let limit = (6.0 / (layer.fan_in + layer.fan_out) as f64).sqrt() as f32;
                rng.fill_uniform_sym(slice, limit);
            }
            other => {
                if let Some(std) = other.strip_prefix("normal:") {
                    let std: f32 = std
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad init `{other}`"))?;
                    rng.fill_normal(slice, std);
                } else {
                    anyhow::bail!("unknown init `{other}` for layer `{}`", layer.name);
                }
            }
        }
    }
    anyhow::ensure!(
        out.len() == model.param_count,
        "layer sizes sum to {} but param_count is {}",
        out.len(),
        model.param_count
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::LayerSpec;

    fn model() -> ModelEntry {
        ModelEntry {
            name: "t".into(),
            kind: "mlp".into(),
            x_dim: 2,
            y_dim: 1,
            classes: 2,
            param_count: 16,
            vocab: 0,
            seq_len: 0,
            layers: vec![
                LayerSpec {
                    name: "w".into(),
                    shape: vec![2, 4],
                    init: "glorot_uniform".into(),
                    fan_in: 2,
                    fan_out: 4,
                },
                LayerSpec {
                    name: "b".into(),
                    shape: vec![4],
                    init: "zeros".into(),
                    fan_in: 0,
                    fan_out: 0,
                },
                LayerSpec {
                    name: "g".into(),
                    shape: vec![2],
                    init: "ones".into(),
                    fan_in: 0,
                    fan_out: 0,
                },
                LayerSpec {
                    name: "e".into(),
                    shape: vec![2],
                    init: "normal:0.02".into(),
                    fan_in: 0,
                    fan_out: 0,
                },
            ],
        }
    }

    #[test]
    fn init_respects_distributions() {
        let m = model();
        let mut rng = Pcg64::seeded(1);
        let p = init_params(&m, &mut rng).unwrap();
        assert_eq!(p.len(), 16);
        let limit = (6.0f32 / 6.0).sqrt();
        for &v in &p[..8] {
            assert!(v.abs() <= limit);
        }
        assert!(p[..8].iter().any(|&v| v != 0.0));
        assert_eq!(&p[8..12], &[0.0; 4]);
        assert_eq!(&p[12..14], &[1.0; 2]);
        for &v in &p[14..16] {
            assert!(v.abs() < 0.2); // 10 sigma
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let m = model();
        let a = init_params(&m, &mut Pcg64::seeded(5)).unwrap();
        let b = init_params(&m, &mut Pcg64::seeded(5)).unwrap();
        let c = init_params(&m, &mut Pcg64::seeded(6)).unwrap();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn rejects_bad_counts() {
        let mut m = model();
        m.param_count = 99;
        assert!(init_params(&m, &mut Pcg64::seeded(1)).is_err());
        let mut m2 = model();
        m2.layers[0].init = "mystery".into();
        assert!(init_params(&m2, &mut Pcg64::seeded(1)).is_err());
    }
}
