//! Offline stand-in for the PJRT execution layer (`exec.rs`), compiled when
//! the `pjrt` feature is off. Presents the same public API; constructors
//! fail with a clear error so every caller's "skip when artifacts/PJRT are
//! unavailable" path engages. The inhabited-by-nothing `Infallible` field
//! makes the post-construction methods statically unreachable.

use crate::engine::GradEngine;
use std::convert::Infallible;

/// A [`GradEngine`] backed by AOT-compiled XLA executables (unavailable:
/// built without the `pjrt` feature).
pub struct XlaEngine {
    never: Infallible,
}

impl XlaEngine {
    /// Mirrors `exec::XlaEngine::new`; always errors in this build.
    pub fn new(
        _manifest: &super::manifest::Manifest,
        _model: &str,
        _grad_batch: Option<usize>,
        _variant: &str,
        _with_eval: bool,
    ) -> anyhow::Result<XlaEngine> {
        anyhow::bail!(
            "XlaEngine unavailable: built without the `pjrt` feature \
             (rebuild with `cargo build --features pjrt` and the real `xla` \
             crate in rust/Cargo.toml to run AOT artifacts)"
        )
    }
}

impl GradEngine for XlaEngine {
    fn param_count(&self) -> usize {
        match self.never {}
    }

    fn batch_size(&self) -> usize {
        match self.never {}
    }

    fn grad(
        &mut self,
        _params: &[f32],
        _x: &[f32],
        _y: &[i32],
        _grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        match self.never {}
    }

    fn eval(&mut self, _params: &[f32], _x: &[f32], _y: &[i32]) -> anyhow::Result<(f64, usize)> {
        match self.never {}
    }
}

/// A standalone parameter-server op (fused SGD update / buffer reduce) —
/// unavailable without the `pjrt` feature.
pub struct UpdateOp {
    pub param_count: usize,
    never: Infallible,
}

impl UpdateOp {
    /// Mirrors `exec::UpdateOp::new`; always errors in this build.
    pub fn new(
        _manifest: &super::manifest::Manifest,
        _model: &str,
        _variant: &str,
    ) -> anyhow::Result<UpdateOp> {
        anyhow::bail!(
            "UpdateOp unavailable: built without the `pjrt` feature \
             (rebuild with `cargo build --features pjrt`)"
        )
    }

    /// θ ← θ − scale · grad_sum, computed by the AOT kernel.
    pub fn apply(&mut self, _params: &mut [f32], _grad_sum: &[f32], _scale: f32) -> anyhow::Result<()> {
        match self.never {}
    }
}
