//! Runtime layer: PJRT loading/execution of the AOT artifacts, manifest
//! parsing, and parameter initialisation. Python never runs here — the
//! artifacts under `artifacts/` are the entire L1/L2 contribution at runtime.
//!
//! The PJRT execution path is feature-gated: without `--features pjrt` the
//! native backend builds and tests fully offline against an API-compatible
//! stub whose constructors explain how to enable the real path (DESIGN.md
//! §1.4).

#[cfg(feature = "pjrt")]
pub mod exec;
#[cfg(not(feature = "pjrt"))]
#[path = "exec_stub.rs"]
pub mod exec;
pub mod init;
pub mod manifest;

pub use exec::{UpdateOp, XlaEngine};
pub use init::init_params;
pub use manifest::Manifest;

use crate::engine::{factory, EngineFactory};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Default artifact directory: `$HYBRID_SGD_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("HYBRID_SGD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Engine factories for a model: (worker grad engine, evaluator engine).
///
/// Each call of a factory creates a fresh PJRT client + compiled executable
/// inside the calling thread (clients are not `Send`). Compilation is the
/// per-thread startup cost; the hot path only executes.
pub fn engine_factories(
    dir: impl AsRef<Path>,
    model: &str,
    grad_batch: usize,
    variant: &str,
) -> anyhow::Result<(EngineFactory, EngineFactory)> {
    let manifest = Arc::new(Manifest::load(dir)?);
    // Validate up front so errors surface before threads spawn.
    manifest.graph(model, "grad", grad_batch, variant)?;
    manifest.eval_graph(model)?;
    let m1 = Arc::clone(&manifest);
    let model1 = model.to_string();
    let variant1 = variant.to_string();
    let worker = factory(move || {
        Ok(Box::new(XlaEngine::new(&m1, &model1, Some(grad_batch), &variant1, false)?)
            as Box<dyn crate::engine::GradEngine>)
    });
    let model2 = model.to_string();
    let eval = factory(move || {
        Ok(Box::new(XlaEngine::new(&manifest, &model2, None, "jnp", true)?)
            as Box<dyn crate::engine::GradEngine>)
    });
    Ok((worker, eval))
}
