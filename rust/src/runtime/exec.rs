//! PJRT execution: load HLO-text artifacts, compile once, run from the hot
//! path.
//!
//! [`XlaEngine`] implements [`GradEngine`] over a grad and/or eval artifact.
//! Engines are **not** `Send` (the PJRT client wrapper is `Rc`-based) and are
//! constructed inside each worker thread via [`crate::engine::EngineFactory`].
//! Input literals are allocated once and refilled with `copy_raw_from` every
//! call — the steady-state hot path does no Rust-side allocation.

use crate::engine::GradEngine;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Compile an HLO-text artifact on a fresh-or-shared client.
pub fn compile(client: &PjRtClient, path: &std::path::Path) -> anyhow::Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
    )
    .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))
}

/// One compiled (grad or eval) graph plus its reusable input literals.
struct Graph {
    exe: PjRtLoadedExecutable,
    batch: usize,
    x_dim: usize,
    y_dim: usize,
    p_lit: Literal,
    x_lit: Literal,
    y_lit: Literal,
}

impl Graph {
    fn new(
        client: &PjRtClient,
        path: &std::path::Path,
        param_count: usize,
        batch: usize,
        x_dim: usize,
        y_dim: usize,
    ) -> anyhow::Result<Graph> {
        let exe = compile(client, path)?;
        Ok(Graph {
            exe,
            batch,
            x_dim,
            y_dim,
            p_lit: Literal::create_from_shape(xla::PrimitiveType::F32, &[param_count]),
            x_lit: Literal::create_from_shape(xla::PrimitiveType::F32, &[batch, x_dim]),
            y_lit: Literal::create_from_shape(xla::PrimitiveType::S32, &[batch, y_dim]),
        })
    }

    /// Fill inputs and execute; returns the decomposed 2-tuple output.
    fn run(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(Literal, Literal)> {
        anyhow::ensure!(
            x.len() == self.batch * self.x_dim,
            "x size {} != {}x{}",
            x.len(),
            self.batch,
            self.x_dim
        );
        anyhow::ensure!(y.len() == self.batch * self.y_dim, "y size mismatch");
        self.p_lit.copy_raw_from(params)?;
        self.x_lit.copy_raw_from(x)?;
        self.y_lit.copy_raw_from(y)?;
        let res = self
            .exe
            .execute(&[&self.p_lit, &self.x_lit, &self.y_lit])?;
        let out = res[0][0].to_literal_sync()?;
        let (a, b) = out.to_tuple2()?;
        Ok((a, b))
    }
}

/// A [`GradEngine`] backed by AOT-compiled XLA executables.
pub struct XlaEngine {
    param_count: usize,
    grad: Option<Graph>,
    eval: Option<Graph>,
    // Cold-path scratch for grad download.
    grad_host: Vec<f32>,
}

impl XlaEngine {
    /// Build from manifest entries. Either graph may be omitted.
    pub fn new(
        manifest: &super::manifest::Manifest,
        model: &str,
        grad_batch: Option<usize>,
        variant: &str,
        with_eval: bool,
    ) -> anyhow::Result<XlaEngine> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let entry = manifest.model(model)?;
        let grad = match grad_batch {
            Some(b) => {
                let a = manifest.graph(model, "grad", b, variant)?;
                Some(Graph::new(&client, &a.path, a.param_count, a.batch, a.x_dim, a.y_dim)?)
            }
            None => None,
        };
        let eval = if with_eval {
            let a = manifest.eval_graph(model)?;
            Some(Graph::new(&client, &a.path, a.param_count, a.batch, a.x_dim, a.y_dim)?)
        } else {
            None
        };
        Ok(XlaEngine {
            param_count: entry.param_count,
            grad,
            eval,
            grad_host: Vec::new(),
        })
    }
}

impl GradEngine for XlaEngine {
    fn param_count(&self) -> usize {
        self.param_count
    }

    fn batch_size(&self) -> usize {
        self.grad.as_ref().map(|g| g.batch).unwrap_or(0)
    }

    fn eval_batch_size(&self) -> usize {
        self.eval
            .as_ref()
            .or(self.grad.as_ref())
            .map(|g| g.batch)
            .unwrap_or(0)
    }

    fn grad(
        &mut self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let g = self
            .grad
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("engine has no grad graph"))?;
        let (loss, grads) = g.run(params, x, y)?;
        let _ = &mut self.grad_host;
        grads.copy_raw_to(grad_out)?;
        Ok(loss.get_first_element::<f32>()?)
    }

    fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(f64, usize)> {
        let g = self
            .eval
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("engine has no eval graph"))?;
        let (sum_loss, correct) = g.run(params, x, y)?;
        Ok((
            sum_loss.get_first_element::<f32>()? as f64,
            correct.get_first_element::<f32>()? as usize,
        ))
    }
}

/// A standalone parameter-server op (fused SGD update / buffer reduce) —
/// used by the runtime benches to compare the XLA aggregation path against
/// the native Rust one.
pub struct UpdateOp {
    exe: PjRtLoadedExecutable,
    p_lit: Literal,
    g_lit: Literal,
    s_lit: Literal,
    pub param_count: usize,
}

impl UpdateOp {
    pub fn new(manifest: &super::manifest::Manifest, model: &str, variant: &str) -> anyhow::Result<UpdateOp> {
        let client = PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let op = manifest.op("sgd_update", model, variant)?;
        Ok(UpdateOp {
            exe: compile(&client, &op.path)?,
            p_lit: Literal::create_from_shape(xla::PrimitiveType::F32, &[op.param_count]),
            g_lit: Literal::create_from_shape(xla::PrimitiveType::F32, &[op.param_count]),
            s_lit: Literal::create_from_shape(xla::PrimitiveType::F32, &[1]),
            param_count: op.param_count,
        })
    }

    /// θ ← θ − scale · grad_sum, computed by the AOT kernel.
    pub fn apply(&mut self, params: &mut [f32], grad_sum: &[f32], scale: f32) -> anyhow::Result<()> {
        self.p_lit.copy_raw_from(params)?;
        self.g_lit.copy_raw_from(grad_sum)?;
        self.s_lit.copy_raw_from(&[scale])?;
        let res = self.exe.execute(&[&self.p_lit, &self.g_lit, &self.s_lit])?;
        let out = res[0][0].to_literal_sync()?.to_tuple1()?;
        out.copy_raw_to(params)?;
        Ok(())
    }
}
