//! The gradient-engine interface between the coordinator (L3) and whatever
//! computes gradients.
//!
//! Two implementations exist:
//! - [`crate::runtime::XlaEngine`] — the production path: AOT-compiled
//!   JAX/Pallas executables run via PJRT.
//! - [`crate::native::NativeEngine`] — pure-Rust analytic models (softmax
//!   regression, MLP with manual backprop, quadratic bowl) used by tests,
//!   property checks and coordinator micro-benchmarks, and as a no-artifact
//!   fallback.
//!
//! PJRT clients are not `Send` (`Rc` internals), so engines are constructed
//! *inside* each worker thread from a `Send` factory.

/// Computes gradients and evaluation metrics for a fixed model architecture.
///
/// Parameters are a single flat `f32` vector (layout defined by the model's
/// manifest / spec); features are row-major `batch × dim`; labels are class
/// ids (for LM models, flattened target token ids).
pub trait GradEngine {
    /// Number of parameters (length of the flat vector).
    fn param_count(&self) -> usize;

    /// Mini-batch size this engine was compiled/configured for.
    fn batch_size(&self) -> usize;

    /// Compute mean loss over the batch and write `∂loss/∂θ` into
    /// `grad_out` (len == param_count). Returns the loss.
    fn grad(&mut self, params: &[f32], x: &[f32], y: &[i32], grad_out: &mut [f32])
        -> anyhow::Result<f32>;

    /// Evaluate on a batch: returns `(sum_loss, correct_count)` so callers
    /// can aggregate over chunks.
    fn eval(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> anyhow::Result<(f64, usize)>;

    /// Eval-batch size (may differ from the training batch).
    fn eval_batch_size(&self) -> usize {
        self.batch_size()
    }
}

/// Thread-safe constructor for per-thread engines.
pub type EngineFactory = std::sync::Arc<dyn Fn() -> anyhow::Result<Box<dyn GradEngine>> + Send + Sync>;

/// Convenience: wrap a closure as an [`EngineFactory`].
pub fn factory<F>(f: F) -> EngineFactory
where
    F: Fn() -> anyhow::Result<Box<dyn GradEngine>> + Send + Sync + 'static,
{
    std::sync::Arc::new(f)
}
