//! Aggregation policies: the decision core of the paper.
//!
//! [`Aggregator`] is a *pure* state machine (no threads, no channels) driven
//! by the parameter-server loop with one call per gradient arrival — which
//! makes the paper's algorithm directly unit- and property-testable. The
//! server layer (`server.rs`) only routes messages.
//!
//! Semantics (DESIGN.md §2):
//! - **Async** — apply every gradient on arrival (HOGWILD-style parameter
//!   server, the paper's asynchronous baseline).
//! - **Sync** — one gradient per worker per round; workers block at the
//!   barrier; flush when all `W` contributed (the synchronous baseline).
//! - **Hybrid smooth** (paper's Algorithm 1, default) — buffer every arrival;
//!   flush an averaged update when `len(buffer) ≥ K(n)`; submitters never
//!   block. Because θ is frozen between flushes, all buffered gradients
//!   share one base version: sync-quality aggregation at async throughput.
//! - **Hybrid strict** — same, but a worker that already contributed to the
//!   current epoch blocks until the flush; at `K = W` this *is* sync.

use super::adaptive::{AdaptiveConfig, AdaptiveController};
use super::buffer::GradientBuffer;
use super::compress::GradView;
use super::params::ParamStore;
use super::threshold::Schedule;

/// Which aggregation algorithm the parameter server runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    Async,
    Sync,
    Hybrid { schedule: Schedule, strict: bool },
    /// §9 future work: K driven by the observed-staleness controller
    /// instead of a fixed schedule (see [`super::adaptive`]).
    HybridAdaptive { cfg: AdaptiveConfig, strict: bool },
}

impl Policy {
    /// Parse CLI syntax: `async`, `sync`, `hybrid:<schedule>`,
    /// `hybrid-strict:<schedule>`.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        if s == "async" {
            return Ok(Policy::Async);
        }
        if s == "sync" {
            return Ok(Policy::Sync);
        }
        if let Some(rest) = s.strip_prefix("hybrid-strict:") {
            return Ok(Policy::Hybrid {
                schedule: Schedule::parse(rest)?,
                strict: true,
            });
        }
        if let Some(rest) = s.strip_prefix("hybrid:") {
            return Ok(Policy::Hybrid {
                schedule: Schedule::parse(rest)?,
                strict: false,
            });
        }
        if let Some(rest) = s.strip_prefix("adaptive") {
            // Accept exactly `adaptive`, `adaptive:<target>` and
            // `adaptive:<target>:strict` — anything else (e.g. a bare
            // `adaptivegarbage`) is an error, not a silent default.
            let mut cfg = AdaptiveConfig::default();
            let mut strict = false;
            if !rest.is_empty() {
                let spec = rest.strip_prefix(':').ok_or_else(|| {
                    anyhow::anyhow!("bad policy `{s}` (expected `adaptive` or `adaptive:<target>`)")
                })?;
                let target = match spec.strip_suffix(":strict") {
                    Some(t) => {
                        strict = true;
                        t
                    }
                    None => spec,
                };
                cfg.target_staleness = target.parse().map_err(|_| {
                    anyhow::anyhow!("bad adaptive target staleness `{target}`")
                })?;
            }
            return Ok(Policy::HybridAdaptive { cfg, strict });
        }
        anyhow::bail!(
            "unknown policy `{s}` (async | sync | hybrid:<sched> | hybrid-strict:<sched> | adaptive[:<target>[:strict]])"
        )
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Async => write!(f, "async"),
            Policy::Sync => write!(f, "sync"),
            Policy::Hybrid { schedule, strict } => {
                if *strict {
                    write!(f, "hybrid-strict:{schedule}")
                } else {
                    write!(f, "hybrid:{schedule}")
                }
            }
            Policy::HybridAdaptive { cfg, strict } => {
                write!(
                    f,
                    "adaptive:{}{}",
                    cfg.target_staleness,
                    if *strict { ":strict" } else { "" }
                )
            }
        }
    }
}

/// What the server should do after one gradient arrival.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Update applied immediately — reply to the submitter with fresh θ.
    AppliedNow,
    /// Gradient buffered — reply to the submitter with the *current* θ
    /// (a stale read in the paper's terms); it keeps working.
    Buffered,
    /// Gradient buffered — the submitter must block until the next flush.
    BufferedBlocked,
    /// This arrival triggered a flush: an averaged update of `count`
    /// gradients was applied. Reply to the submitter AND release everyone
    /// blocked in this epoch.
    Flushed {
        count: usize,
        distinct_workers: usize,
        mean_staleness: f64,
        k_at_flush: usize,
    },
}

/// Statistics the aggregator keeps for the metrics pipeline.
#[derive(Clone, Debug, Default)]
pub struct AggStats {
    pub arrivals: u64,
    pub applied_async: u64,
    pub flushes: u64,
    pub flushed_gradients: u64,
    pub staleness_sum: f64,
    pub blocked_total: u64,
}

/// The policy state machine.
pub struct Aggregator {
    policy: Policy,
    buffer: GradientBuffer,
    workers: usize,
    k_max: usize,
    adaptive: Option<AdaptiveController>,
    pub stats: AggStats,
}

impl Aggregator {
    pub fn new(policy: Policy, dim: usize, workers: usize) -> Self {
        let adaptive = match &policy {
            Policy::HybridAdaptive { cfg, .. } => {
                Some(AdaptiveController::new(cfg.clone()))
            }
            _ => None,
        };
        Aggregator {
            policy,
            buffer: GradientBuffer::new(dim, workers),
            workers,
            k_max: workers,
            adaptive,
            stats: AggStats::default(),
        }
    }

    /// Override the threshold cap (default = worker count).
    pub fn with_k_max(mut self, k_max: usize) -> Self {
        self.k_max = k_max.max(1);
        self
    }

    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// Current threshold value (1 for the baselines).
    pub fn current_k(&self) -> usize {
        match &self.policy {
            Policy::Async => 1,
            Policy::Sync => self.workers,
            Policy::Hybrid { schedule, .. } => schedule.k(self.stats.arrivals, self.k_max),
            Policy::HybridAdaptive { .. } => {
                self.adaptive.as_ref().map(|a| a.k()).unwrap_or(1)
            }
        }
    }

    /// Number of gradients currently buffered.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Feed one dense gradient; mutates `store` according to the policy.
    /// `loss` is the worker-reported mini-batch loss (used by the adaptive
    /// controller; pass anything for the fixed policies).
    pub fn on_gradient(
        &mut self,
        store: &mut ParamStore,
        grad: &[f32],
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        self.on_gradient_view(store, GradView::Dense(grad), worker, base_version, loss)
    }

    /// [`Aggregator::on_gradient`] for a gradient in any wire format. The
    /// dense arm takes exactly the code path `on_gradient` always took;
    /// sparse arms are applied/accumulated in O(nnz) without densifying,
    /// and int8 arms dequantize on the fly.
    pub fn on_gradient_view(
        &mut self,
        store: &mut ParamStore,
        grad: GradView<'_>,
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        self.stats.arrivals += 1;
        let stale = store.version().saturating_sub(base_version);
        self.stats.staleness_sum += stale as f64;
        if let Some(ctrl) = self.adaptive.as_mut() {
            ctrl.observe(stale, loss, self.k_max);
        }
        match &self.policy {
            Policy::Async => {
                store.apply_view(grad);
                self.stats.applied_async += 1;
                Outcome::AppliedNow
            }
            Policy::Sync => {
                self.buffer
                    .push_view(grad, worker, base_version, store.version());
                if self.buffer.distinct_workers() >= self.workers {
                    self.flush(store)
                } else {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                }
            }
            Policy::Hybrid { schedule, strict } => {
                let k = schedule.k(self.stats.arrivals - 1, self.k_max);
                self.buffer
                    .push_view(grad, worker, base_version, store.version());
                if self.buffer.len() >= k {
                    self.flush(store)
                } else if *strict {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                } else {
                    Outcome::Buffered
                }
            }
            Policy::HybridAdaptive { strict, .. } => {
                let k = self.adaptive.as_ref().map(|a| a.k()).unwrap_or(1);
                self.buffer
                    .push_view(grad, worker, base_version, store.version());
                if self.buffer.len() >= k {
                    self.flush(store)
                } else if *strict {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                } else {
                    Outcome::Buffered
                }
            }
        }
    }

    fn flush(&mut self, store: &mut ParamStore) -> Outcome {
        let count = self.buffer.len();
        let distinct = self.buffer.distinct_workers();
        let mean_staleness = self.buffer.mean_staleness();
        // apply_mean bumps the version, which publishes the new snapshot.
        store.apply_mean(self.buffer.sum(), count);
        self.buffer.clear();
        self.stats.flushes += 1;
        self.stats.flushed_gradients += count as u64;
        Outcome::Flushed {
            count,
            distinct_workers: distinct,
            mean_staleness,
            k_at_flush: self.current_k(),
        }
    }

    /// Force-flush whatever is buffered (shutdown path) so no gradient is
    /// silently dropped. Returns the flushed count.
    pub fn drain(&mut self, store: &mut ParamStore) -> usize {
        if self.buffer.is_empty() {
            return 0;
        }
        let n = self.buffer.len();
        self.flush(store);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(d: usize) -> ParamStore {
        ParamStore::new(vec![0.0; d], 0.1)
    }

    #[test]
    fn async_applies_every_gradient() {
        let mut agg = Aggregator::new(Policy::Async, 2, 4);
        let mut ps = store(2);
        for i in 0..10 {
            let v = ps.version();
            let out = agg.on_gradient(&mut ps, &[1.0, 1.0], i % 4, v, 1.0);
            assert_eq!(out, Outcome::AppliedNow);
        }
        assert_eq!(ps.version(), 10);
        // 10 updates of lr·1 = 0.1 each
        assert!((ps.theta()[0] + 1.0).abs() < 1e-5);
    }

    #[test]
    fn sync_waits_for_all_workers() {
        let w = 3;
        let mut agg = Aggregator::new(Policy::Sync, 1, w);
        let mut ps = store(1);
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 1, 0, 1.0),
            Outcome::BufferedBlocked
        );
        // duplicate from worker 0 does NOT complete the barrier
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        let out = agg.on_gradient(&mut ps, &[3.0], 2, 0, 1.0);
        match out {
            Outcome::Flushed {
                count,
                distinct_workers,
                ..
            } => {
                assert_eq!(count, 4);
                assert_eq!(distinct_workers, 3);
            }
            o => panic!("expected flush, got {o:?}"),
        }
        assert_eq!(ps.version(), 1);
        // mean of four gradients of 3.0 = 3.0; θ = -0.1·3
        assert!((ps.theta()[0] + 0.3).abs() < 1e-6);
    }

    #[test]
    fn hybrid_k1_equals_async_numerically() {
        let sched = Schedule::Constant { k: 1 };
        let mut hyb = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            2,
            4,
        );
        let mut asy = Aggregator::new(Policy::Async, 2, 4);
        let mut ps_h = store(2);
        let mut ps_a = store(2);
        let grads = [[1.0f32, -2.0], [0.5, 0.5], [-1.0, 3.0]];
        for (i, g) in grads.iter().enumerate() {
            let (vh, va) = (ps_h.version(), ps_a.version());
            hyb.on_gradient(&mut ps_h, g, i % 4, vh, 1.0);
            asy.on_gradient(&mut ps_a, g, i % 4, va, 1.0);
        }
        assert_eq!(ps_h.theta(), ps_a.theta());
        assert_eq!(ps_h.version(), ps_a.version());
    }

    #[test]
    fn hybrid_buffers_then_flushes_at_k() {
        // step so small that K jumps to 2 after 2 arrivals, 3 after 4 ...
        let sched = Schedule::Step { step: 2 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            8,
        );
        let mut ps = store(1);
        // arrival 1: K(0)=1 → immediate flush of 1 (async-like)
        match agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0) {
            Outcome::Flushed { count: 1, .. } => {}
            o => panic!("{o:?}"),
        }
        // arrival 2: K(1)=1 → flush of 1
        match agg.on_gradient(&mut ps, &[1.0], 1, 1, 1.0) {
            Outcome::Flushed { count: 1, .. } => {}
            o => panic!("{o:?}"),
        }
        // arrival 3: K(2)=2 → buffered
        assert_eq!(agg.on_gradient(&mut ps, &[1.0], 0, 2, 1.0), Outcome::Buffered);
        // arrival 4: K(3)=2 → flush of 2
        match agg.on_gradient(&mut ps, &[1.0], 1, 2, 1.0) {
            Outcome::Flushed { count: 2, .. } => {}
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn hybrid_buffered_gradients_share_base_version() {
        // Between flushes θ is frozen ⇒ staleness within a flush is 0 when
        // workers fetch after the last flush.
        let sched = Schedule::Constant { k: 3 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        );
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0);
        let out = agg.on_gradient(&mut ps, &[1.0], 2, 0, 1.0);
        match out {
            Outcome::Flushed {
                mean_staleness, ..
            } => assert_eq!(mean_staleness, 0.0),
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn strict_blocks_submitters() {
        let sched = Schedule::Constant { k: 2 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: true,
            },
            1,
            4,
        );
        let mut ps = store(1);
        assert_eq!(
            agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        match agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0) {
            Outcome::Flushed { count: 2, .. } => {}
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn drain_flushes_leftovers() {
        let sched = Schedule::Constant { k: 10 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        );
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[2.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[4.0], 1, 0, 1.0);
        assert_eq!(agg.drain(&mut ps), 2);
        assert_eq!(ps.version(), 1);
        assert!((ps.theta()[0] + 0.1 * 3.0).abs() < 1e-6); // mean(2,4)=3
        assert_eq!(agg.drain(&mut ps), 0);
    }

    #[test]
    fn sparse_view_matches_dense_reconstruction_bitwise() {
        use crate::coordinator::compress::{GradView, TopKCompressor};
        use crate::util::rng::Pcg64;
        // Feeding a top-k compressed gradient as a sparse view must produce
        // exactly what feeding its dense reconstruction produces — for the
        // buffering hybrid policy (scatter-add path) and async (apply path).
        for policy in [
            Policy::Async,
            Policy::Hybrid {
                schedule: Schedule::Constant { k: 3 },
                strict: false,
            },
        ] {
            let dim = 16;
            let mut a = Aggregator::new(policy.clone(), dim, 4);
            let mut b = Aggregator::new(policy, dim, 4);
            let mut ps_a = store(dim);
            let mut ps_b = store(dim);
            let mut comp = TopKCompressor::new(dim, 4);
            let mut rng = Pcg64::seeded(77);
            let mut g = vec![0.0f32; dim];
            for i in 0..24 {
                rng.fill_normal(&mut g, 1.0);
                let sg = comp.compress(&g);
                let dense = sg.to_dense();
                let (va, vb) = (ps_a.version(), ps_b.version());
                assert_eq!(va, vb);
                let out_a = a.on_gradient_view(
                    &mut ps_a,
                    GradView::Sparse {
                        idx: &sg.idx,
                        val: &sg.val,
                    },
                    i % 4,
                    va,
                    1.0,
                );
                let out_b = b.on_gradient(&mut ps_b, &dense, i % 4, vb, 1.0);
                assert_eq!(out_a, out_b, "arrival {i}");
            }
            a.drain(&mut ps_a);
            b.drain(&mut ps_b);
            assert_eq!(ps_a.theta(), ps_b.theta());
            assert_eq!(ps_a.version(), ps_b.version());
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for s in [
            "async",
            "sync",
            "hybrid:step:500",
            "hybrid-strict:const:4",
            "adaptive:3.5",
            "adaptive:1.5:strict",
        ] {
            let p = Policy::parse(s).unwrap();
            assert_eq!(Policy::parse(&p.to_string()).unwrap(), p);
        }
        assert!(Policy::parse("nope").is_err());
    }

    #[test]
    fn adaptive_parse_rejects_garbage() {
        // Bare `adaptive` is the documented default form …
        let p = Policy::parse("adaptive").unwrap();
        assert_eq!(
            p,
            Policy::HybridAdaptive {
                cfg: AdaptiveConfig::default(),
                strict: false
            }
        );
        // … but a non-`:` remainder must not silently parse as that default.
        assert!(Policy::parse("adaptivegarbage").is_err());
        assert!(Policy::parse("adaptive2").is_err());
        assert!(Policy::parse("adaptive:").is_err());
        assert!(Policy::parse("adaptive:notanumber").is_err());
        assert!(Policy::parse("adaptive:2:bogus").is_err());
    }
}
