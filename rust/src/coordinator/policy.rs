//! Aggregation policies: the decision core of the paper.
//!
//! [`Aggregator`] is a *pure* state machine (no threads, no channels) driven
//! by the parameter-server loop with one call per gradient arrival — which
//! makes the paper's algorithm directly unit- and property-testable. The
//! server layer (`server.rs`) only routes messages.
//!
//! Semantics (DESIGN.md §2):
//! - **Async** — apply every gradient on arrival (HOGWILD-style parameter
//!   server, the paper's asynchronous baseline).
//! - **Sync** — one gradient per worker per round; workers block at the
//!   barrier; flush when all `W` contributed (the synchronous baseline).
//! - **Hybrid smooth** (paper's Algorithm 1, default) — buffer every arrival;
//!   flush an averaged update when `len(buffer) ≥ K(n)`; submitters never
//!   block. Because θ is frozen between flushes, all buffered gradients
//!   share one base version: sync-quality aggregation at async throughput.
//! - **Hybrid strict** — same, but a worker that already contributed to the
//!   current epoch blocks until the flush; at `K = W` this *is* sync.

use super::adaptive::{AdaptiveConfig, AdaptiveController};
use super::buffer::{AggregateMode, GradientBuffer};
use super::compress::GradView;
use super::membership::Membership;
use super::params::ParamStore;
use super::threshold::Schedule;

/// Which aggregation algorithm the parameter server runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    Async,
    Sync,
    Hybrid { schedule: Schedule, strict: bool },
    /// §9 future work: K driven by the observed-staleness controller
    /// instead of a fixed schedule (see [`super::adaptive`]).
    HybridAdaptive { cfg: AdaptiveConfig, strict: bool },
}

impl Policy {
    /// Parse CLI syntax: `async`, `sync`, `hybrid:<schedule>`,
    /// `hybrid-strict:<schedule>`.
    pub fn parse(s: &str) -> anyhow::Result<Policy> {
        if s == "async" {
            return Ok(Policy::Async);
        }
        if s == "sync" {
            return Ok(Policy::Sync);
        }
        if let Some(rest) = s.strip_prefix("hybrid-strict:") {
            return Ok(Policy::Hybrid {
                schedule: Schedule::parse(rest)?,
                strict: true,
            });
        }
        if let Some(rest) = s.strip_prefix("hybrid:") {
            return Ok(Policy::Hybrid {
                schedule: Schedule::parse(rest)?,
                strict: false,
            });
        }
        if let Some(rest) = s.strip_prefix("adaptive") {
            // Accept exactly `adaptive`, `adaptive:<target>` and
            // `adaptive:<target>:strict` — anything else (e.g. a bare
            // `adaptivegarbage`) is an error, not a silent default.
            let mut cfg = AdaptiveConfig::default();
            let mut strict = false;
            if !rest.is_empty() {
                let spec = rest.strip_prefix(':').ok_or_else(|| {
                    anyhow::anyhow!("bad policy `{s}` (expected `adaptive` or `adaptive:<target>`)")
                })?;
                let target = match spec.strip_suffix(":strict") {
                    Some(t) => {
                        strict = true;
                        t
                    }
                    None => spec,
                };
                cfg.target_staleness = target.parse().map_err(|_| {
                    anyhow::anyhow!("bad adaptive target staleness `{target}`")
                })?;
            }
            return Ok(Policy::HybridAdaptive { cfg, strict });
        }
        anyhow::bail!(
            "unknown policy `{s}` (async | sync | hybrid:<sched> | hybrid-strict:<sched> | adaptive[:<target>[:strict]])"
        )
    }
}

impl std::fmt::Display for Policy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Policy::Async => write!(f, "async"),
            Policy::Sync => write!(f, "sync"),
            Policy::Hybrid { schedule, strict } => {
                if *strict {
                    write!(f, "hybrid-strict:{schedule}")
                } else {
                    write!(f, "hybrid:{schedule}")
                }
            }
            Policy::HybridAdaptive { cfg, strict } => {
                write!(
                    f,
                    "adaptive:{}{}",
                    cfg.target_staleness,
                    if *strict { ":strict" } else { "" }
                )
            }
        }
    }
}

/// What the server should do after one gradient arrival.
#[derive(Clone, Debug, PartialEq)]
pub enum Outcome {
    /// Update applied immediately — reply to the submitter with fresh θ.
    AppliedNow,
    /// Gradient buffered — reply to the submitter with the *current* θ
    /// (a stale read in the paper's terms); it keeps working.
    Buffered,
    /// Gradient buffered — the submitter must block until the next flush.
    BufferedBlocked,
    /// This arrival triggered a flush: an averaged update of `count`
    /// gradients was applied. Reply to the submitter AND release everyone
    /// blocked in this epoch.
    Flushed {
        count: usize,
        distinct_workers: usize,
        mean_staleness: f64,
        k_at_flush: usize,
    },
}

/// Statistics the aggregator keeps for the metrics pipeline.
#[derive(Clone, Debug, Default)]
pub struct AggStats {
    pub arrivals: u64,
    pub applied_async: u64,
    pub flushes: u64,
    pub flushed_gradients: u64,
    pub staleness_sum: f64,
    pub blocked_total: u64,
    /// Gradients whose norm exceeded the clip radius (`--aggregate clip`).
    pub clipped: u64,
}

/// The policy state machine.
pub struct Aggregator {
    policy: Policy,
    buffer: GradientBuffer,
    workers: usize,
    k_max: usize,
    /// Elastic membership (DESIGN.md §2.7): when present, the sync barrier
    /// denominator and the threshold cap track the *live* worker set
    /// instead of the launch-time slot count. `None` (the default) is the
    /// static path, bitwise-identical to the pre-elastic stack.
    elastic: Option<Membership>,
    /// Floor on the barrier denominator / threshold cap under elastic
    /// membership: the barrier never renormalizes below this many workers,
    /// so a near-empty run waits for joiners instead of degenerating to
    /// K = 1.
    min_quorum: usize,
    adaptive: Option<AdaptiveController>,
    /// How a flush turns the buffered gradients into one update
    /// (DESIGN.md §2.10). `Mean` is the bitwise-pinned default.
    aggregate: AggregateMode,
    pub stats: AggStats,
}

impl Aggregator {
    pub fn new(policy: Policy, dim: usize, workers: usize) -> Self {
        let adaptive = match &policy {
            Policy::HybridAdaptive { cfg, .. } => {
                Some(AdaptiveController::new(cfg.clone()))
            }
            _ => None,
        };
        Aggregator {
            policy,
            buffer: GradientBuffer::new(dim, workers),
            workers,
            k_max: workers,
            elastic: None,
            min_quorum: 1,
            adaptive,
            aggregate: AggregateMode::Mean,
            stats: AggStats::default(),
        }
    }

    /// Override the threshold cap (default = worker count).
    pub fn with_k_max(mut self, k_max: usize) -> Self {
        self.k_max = k_max.max(1);
        self
    }

    /// Select the flush-time aggregation mode (default [`AggregateMode::Mean`],
    /// which is bitwise-identical to the pre-defense flush). Trimmed/median
    /// modes switch the buffer to per-gradient row retention; `clip` scales
    /// contributions at accumulation time and retains nothing extra.
    pub fn with_aggregate(mut self, mode: AggregateMode) -> Self {
        if mode.retains_rows() && !self.aggregate.retains_rows() {
            let dim = self.buffer.sum().len();
            self.buffer = GradientBuffer::new(dim, self.workers).with_row_retention();
        }
        self.aggregate = mode;
        self
    }

    pub fn aggregate(&self) -> &AggregateMode {
        &self.aggregate
    }

    /// Enable elastic membership: `initial_live` of the `workers` slots
    /// start live (slots `initial_live..` are reserved for late joiners),
    /// and the barrier denominator never drops below `min_quorum`.
    pub fn with_elastic(mut self, initial_live: usize, min_quorum: usize) -> Self {
        self.elastic = Some(Membership::new(self.workers, initial_live));
        self.min_quorum = min_quorum.max(1);
        self
    }

    pub fn policy(&self) -> &Policy {
        &self.policy
    }

    /// Live worker count (the slot count on the static path).
    pub fn live(&self) -> usize {
        match &self.elastic {
            Some(m) => m.live(),
            None => self.workers,
        }
    }

    /// Membership transitions applied so far (0 on the static path).
    pub fn membership_epoch(&self) -> u64 {
        self.elastic.as_ref().map_or(0, |m| m.epoch())
    }

    /// The sync-barrier denominator: live membership (quorum-floored)
    /// under elastic mode, the launch-time worker count otherwise.
    fn quorum(&self) -> usize {
        match &self.elastic {
            Some(m) => m.live().max(self.min_quorum).max(1),
            None => self.workers,
        }
    }

    /// Effective threshold cap: `k_max` clamped to live membership
    /// (quorum-floored) under elastic mode, plain `k_max` otherwise.
    fn k_cap(&self) -> usize {
        match &self.elastic {
            Some(m) => self.k_max.min(m.live().max(self.min_quorum)).max(1),
            None => self.k_max,
        }
    }

    /// Current threshold value (1 for the baselines).
    pub fn current_k(&self) -> usize {
        match &self.policy {
            Policy::Async => 1,
            Policy::Sync => self.quorum(),
            Policy::Hybrid { schedule, .. } => schedule.k(self.stats.arrivals, self.k_cap()),
            // The controller clamps to the cap it saw at its last
            // observation; clamp again so a membership departure takes
            // effect immediately, not one arrival later (a no-op on the
            // static path, where k_cap() == the k_max it already obeys).
            Policy::HybridAdaptive { .. } => self
                .adaptive
                .as_ref()
                .map(|a| a.k())
                .unwrap_or(1)
                .min(self.k_cap()),
        }
    }

    /// Number of gradients currently buffered.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// Elastic membership join. Returns true when the live set actually
    /// changed (idempotent; always false on the static path). A join can
    /// only *raise* the barrier denominator, so it never triggers a flush.
    pub fn member_join(&mut self, worker: usize) -> bool {
        match self.elastic.as_mut() {
            Some(m) => m.join(worker),
            None => false,
        }
    }

    /// Elastic membership departure (clean leave, crash, or eviction).
    /// Returns `(changed, flush)`: `changed` is whether the live set moved
    /// (idempotent), and `flush` is `Some(Outcome::Flushed { .. })` when
    /// the shrunken barrier denominator is now satisfied by what is already
    /// buffered — the caller must release its barrier-blocked workers
    /// exactly as it does for an arrival-triggered flush. The departed
    /// worker's already-buffered gradients stay in the buffer (they were
    /// accepted; they flush with the epoch — no loss, no double-apply).
    pub fn member_leave(
        &mut self,
        store: &mut ParamStore,
        worker: usize,
    ) -> (bool, Option<Outcome>) {
        let changed = match self.elastic.as_mut() {
            Some(m) => m.leave(worker),
            None => false,
        };
        if !changed || self.buffer.is_empty() {
            return (changed, None);
        }
        let ready = match &self.policy {
            Policy::Async => false,
            Policy::Sync => self.buffer.distinct_workers() >= self.quorum(),
            Policy::Hybrid { .. } | Policy::HybridAdaptive { .. } => {
                self.buffer.len() >= self.current_k()
            }
        };
        if ready {
            let out = self.flush(store);
            (true, Some(out))
        } else {
            (true, None)
        }
    }

    /// Feed one dense gradient; mutates `store` according to the policy.
    /// `loss` is the worker-reported mini-batch loss (used by the adaptive
    /// controller; pass anything for the fixed policies).
    pub fn on_gradient(
        &mut self,
        store: &mut ParamStore,
        grad: &[f32],
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        self.on_gradient_view(store, GradView::Dense(grad), worker, base_version, loss)
    }

    /// [`Aggregator::on_gradient`] for a gradient in any wire format. The
    /// dense arm takes exactly the code path `on_gradient` always took;
    /// sparse arms are applied/accumulated in O(nnz) without densifying,
    /// and int8 arms dequantize on the fly.
    pub fn on_gradient_view(
        &mut self,
        store: &mut ParamStore,
        grad: GradView<'_>,
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        self.stats.arrivals += 1;
        let stale = store.version().saturating_sub(base_version);
        self.stats.staleness_sum += stale as f64;
        let cap = self.k_cap();
        if let Some(ctrl) = self.adaptive.as_mut() {
            ctrl.observe(stale, loss, cap);
        }
        // Norm clipping acts per contribution, at accumulation/apply time,
        // so it composes with every wire format without densifying. `None`
        // (the unclipped / non-clip-mode case) takes exactly the pre-clip
        // code path, keeping the default bitwise-pinned.
        let clip_factor = match self.aggregate {
            AggregateMode::Clip(c) => {
                let norm = grad.sq_norm().sqrt();
                if norm.is_finite() && norm > c as f64 {
                    self.stats.clipped += 1;
                    Some((c as f64 / norm) as f32)
                } else {
                    None
                }
            }
            _ => None,
        };
        match &self.policy {
            Policy::Async => {
                match clip_factor {
                    Some(f) => store.apply_view_scaled(grad, f),
                    None => store.apply_view(grad),
                }
                self.stats.applied_async += 1;
                Outcome::AppliedNow
            }
            Policy::Sync => {
                let quorum = self.quorum();
                match clip_factor {
                    Some(f) => self
                        .buffer
                        .push_view_scaled(grad, f, worker, base_version, store.version()),
                    None => self
                        .buffer
                        .push_view(grad, worker, base_version, store.version()),
                }
                if self.buffer.distinct_workers() >= quorum {
                    self.flush(store)
                } else {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                }
            }
            Policy::Hybrid { schedule, strict } => {
                let k = schedule.k(self.stats.arrivals - 1, cap);
                match clip_factor {
                    Some(f) => self
                        .buffer
                        .push_view_scaled(grad, f, worker, base_version, store.version()),
                    None => self
                        .buffer
                        .push_view(grad, worker, base_version, store.version()),
                }
                if self.buffer.len() >= k {
                    self.flush(store)
                } else if *strict {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                } else {
                    Outcome::Buffered
                }
            }
            Policy::HybridAdaptive { strict, .. } => {
                let k = self.adaptive.as_ref().map(|a| a.k()).unwrap_or(1).min(cap);
                match clip_factor {
                    Some(f) => self
                        .buffer
                        .push_view_scaled(grad, f, worker, base_version, store.version()),
                    None => self
                        .buffer
                        .push_view(grad, worker, base_version, store.version()),
                }
                if self.buffer.len() >= k {
                    self.flush(store)
                } else if *strict {
                    self.stats.blocked_total += 1;
                    Outcome::BufferedBlocked
                } else {
                    Outcome::Buffered
                }
            }
        }
    }

    fn flush(&mut self, store: &mut ParamStore) -> Outcome {
        let count = self.buffer.len();
        let distinct = self.buffer.distinct_workers();
        let mean_staleness = self.buffer.mean_staleness();
        // apply_mean bumps the version, which publishes the new snapshot.
        match self.aggregate {
            // Mean keeps the exact pre-defense flush (bitwise-pinned);
            // clip already scaled each contribution at accumulation time.
            AggregateMode::Mean | AggregateMode::Clip(_) => {
                store.apply_mean(self.buffer.sum(), count);
            }
            // Robust flushes apply the coordinate-wise estimate as a
            // single-gradient step: θ ← θ − lr · estimate, same version /
            // publish semantics as the mean flush.
            AggregateMode::Trimmed(f) => {
                let trim = (f * count as f64).floor() as usize;
                store.apply_mean(self.buffer.robust_estimate(trim), 1);
            }
            AggregateMode::Median => {
                let trim = (count - 1) / 2;
                store.apply_mean(self.buffer.robust_estimate(trim), 1);
            }
        }
        self.buffer.clear();
        self.stats.flushes += 1;
        self.stats.flushed_gradients += count as u64;
        Outcome::Flushed {
            count,
            distinct_workers: distinct,
            mean_staleness,
            k_at_flush: self.current_k(),
        }
    }

    /// Force-flush whatever is buffered (shutdown path) so no gradient is
    /// silently dropped. Returns the flushed count.
    pub fn drain(&mut self, store: &mut ParamStore) -> usize {
        if self.buffer.is_empty() {
            return 0;
        }
        let n = self.buffer.len();
        self.flush(store);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(d: usize) -> ParamStore {
        ParamStore::new(vec![0.0; d], 0.1)
    }

    #[test]
    fn async_applies_every_gradient() {
        let mut agg = Aggregator::new(Policy::Async, 2, 4);
        let mut ps = store(2);
        for i in 0..10 {
            let v = ps.version();
            let out = agg.on_gradient(&mut ps, &[1.0, 1.0], i % 4, v, 1.0);
            assert_eq!(out, Outcome::AppliedNow);
        }
        assert_eq!(ps.version(), 10);
        // 10 updates of lr·1 = 0.1 each
        assert!((ps.theta()[0] + 1.0).abs() < 1e-5);
    }

    #[test]
    fn sync_waits_for_all_workers() {
        let w = 3;
        let mut agg = Aggregator::new(Policy::Sync, 1, w);
        let mut ps = store(1);
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 1, 0, 1.0),
            Outcome::BufferedBlocked
        );
        // duplicate from worker 0 does NOT complete the barrier
        assert_eq!(
            agg.on_gradient(&mut ps, &[3.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        let out = agg.on_gradient(&mut ps, &[3.0], 2, 0, 1.0);
        match out {
            Outcome::Flushed {
                count,
                distinct_workers,
                ..
            } => {
                assert_eq!(count, 4);
                assert_eq!(distinct_workers, 3);
            }
            o => panic!("expected flush, got {o:?}"),
        }
        assert_eq!(ps.version(), 1);
        // mean of four gradients of 3.0 = 3.0; θ = -0.1·3
        assert!((ps.theta()[0] + 0.3).abs() < 1e-6);
    }

    #[test]
    fn hybrid_k1_equals_async_numerically() {
        let sched = Schedule::Constant { k: 1 };
        let mut hyb = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            2,
            4,
        );
        let mut asy = Aggregator::new(Policy::Async, 2, 4);
        let mut ps_h = store(2);
        let mut ps_a = store(2);
        let grads = [[1.0f32, -2.0], [0.5, 0.5], [-1.0, 3.0]];
        for (i, g) in grads.iter().enumerate() {
            let (vh, va) = (ps_h.version(), ps_a.version());
            hyb.on_gradient(&mut ps_h, g, i % 4, vh, 1.0);
            asy.on_gradient(&mut ps_a, g, i % 4, va, 1.0);
        }
        assert_eq!(ps_h.theta(), ps_a.theta());
        assert_eq!(ps_h.version(), ps_a.version());
    }

    #[test]
    fn hybrid_buffers_then_flushes_at_k() {
        // step so small that K jumps to 2 after 2 arrivals, 3 after 4 ...
        let sched = Schedule::Step { step: 2 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            8,
        );
        let mut ps = store(1);
        // arrival 1: K(0)=1 → immediate flush of 1 (async-like)
        match agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0) {
            Outcome::Flushed { count: 1, .. } => {}
            o => panic!("{o:?}"),
        }
        // arrival 2: K(1)=1 → flush of 1
        match agg.on_gradient(&mut ps, &[1.0], 1, 1, 1.0) {
            Outcome::Flushed { count: 1, .. } => {}
            o => panic!("{o:?}"),
        }
        // arrival 3: K(2)=2 → buffered
        assert_eq!(agg.on_gradient(&mut ps, &[1.0], 0, 2, 1.0), Outcome::Buffered);
        // arrival 4: K(3)=2 → flush of 2
        match agg.on_gradient(&mut ps, &[1.0], 1, 2, 1.0) {
            Outcome::Flushed { count: 2, .. } => {}
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn hybrid_buffered_gradients_share_base_version() {
        // Between flushes θ is frozen ⇒ staleness within a flush is 0 when
        // workers fetch after the last flush.
        let sched = Schedule::Constant { k: 3 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        );
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0);
        let out = agg.on_gradient(&mut ps, &[1.0], 2, 0, 1.0);
        match out {
            Outcome::Flushed {
                mean_staleness, ..
            } => assert_eq!(mean_staleness, 0.0),
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn strict_blocks_submitters() {
        let sched = Schedule::Constant { k: 2 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: true,
            },
            1,
            4,
        );
        let mut ps = store(1);
        assert_eq!(
            agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        match agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0) {
            Outcome::Flushed { count: 2, .. } => {}
            o => panic!("{o:?}"),
        }
    }

    #[test]
    fn drain_flushes_leftovers() {
        let sched = Schedule::Constant { k: 10 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        );
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[2.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[4.0], 1, 0, 1.0);
        assert_eq!(agg.drain(&mut ps), 2);
        assert_eq!(ps.version(), 1);
        assert!((ps.theta()[0] + 0.1 * 3.0).abs() < 1e-6); // mean(2,4)=3
        assert_eq!(agg.drain(&mut ps), 0);
    }

    #[test]
    fn sparse_view_matches_dense_reconstruction_bitwise() {
        use crate::coordinator::compress::{GradView, TopKCompressor};
        use crate::util::rng::Pcg64;
        // Feeding a top-k compressed gradient as a sparse view must produce
        // exactly what feeding its dense reconstruction produces — for the
        // buffering hybrid policy (scatter-add path) and async (apply path).
        for policy in [
            Policy::Async,
            Policy::Hybrid {
                schedule: Schedule::Constant { k: 3 },
                strict: false,
            },
        ] {
            let dim = 16;
            let mut a = Aggregator::new(policy.clone(), dim, 4);
            let mut b = Aggregator::new(policy, dim, 4);
            let mut ps_a = store(dim);
            let mut ps_b = store(dim);
            let mut comp = TopKCompressor::new(dim, 4);
            let mut rng = Pcg64::seeded(77);
            let mut g = vec![0.0f32; dim];
            for i in 0..24 {
                rng.fill_normal(&mut g, 1.0);
                let sg = comp.compress(&g);
                let dense = sg.to_dense();
                let (va, vb) = (ps_a.version(), ps_b.version());
                assert_eq!(va, vb);
                let out_a = a.on_gradient_view(
                    &mut ps_a,
                    GradView::Sparse {
                        idx: &sg.idx,
                        val: &sg.val,
                    },
                    i % 4,
                    va,
                    1.0,
                );
                let out_b = b.on_gradient(&mut ps_b, &dense, i % 4, vb, 1.0);
                assert_eq!(out_a, out_b, "arrival {i}");
            }
            a.drain(&mut ps_a);
            b.drain(&mut ps_b);
            assert_eq!(ps_a.theta(), ps_b.theta());
            assert_eq!(ps_a.version(), ps_b.version());
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for s in [
            "async",
            "sync",
            "hybrid:step:500",
            "hybrid-strict:const:4",
            "adaptive:3.5",
            "adaptive:1.5:strict",
        ] {
            let p = Policy::parse(s).unwrap();
            assert_eq!(Policy::parse(&p.to_string()).unwrap(), p);
        }
        assert!(Policy::parse("nope").is_err());
    }

    #[test]
    fn elastic_leave_renormalizes_sync_barrier_and_flushes() {
        let mut agg = Aggregator::new(Policy::Sync, 1, 3).with_elastic(3, 1);
        let mut ps = store(1);
        assert_eq!(agg.current_k(), 3);
        assert_eq!(
            agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0),
            Outcome::BufferedBlocked
        );
        assert_eq!(
            agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0),
            Outcome::BufferedBlocked
        );
        // Worker 2 is declared dead: the barrier denominator drops to 2,
        // which the two buffered contributions already satisfy — the
        // departure itself releases the barrier.
        let (changed, flushed) = agg.member_leave(&mut ps, 2);
        assert!(changed);
        match flushed {
            Some(Outcome::Flushed {
                count,
                distinct_workers,
                ..
            }) => {
                assert_eq!(count, 2);
                assert_eq!(distinct_workers, 2);
            }
            o => panic!("expected flush on departure, got {o:?}"),
        }
        assert_eq!(ps.version(), 1);
        assert_eq!(agg.live(), 2);
        assert_eq!(agg.current_k(), 2);
        assert_eq!(agg.membership_epoch(), 1);
        // Idempotent: a second leave of the same worker changes nothing.
        assert_eq!(agg.member_leave(&mut ps, 2), (false, None));
        assert_eq!(agg.membership_epoch(), 1);
    }

    #[test]
    fn elastic_leave_caps_hybrid_threshold_to_live_membership() {
        let sched = Schedule::Constant { k: 4 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        )
        .with_elastic(4, 1);
        let mut ps = store(1);
        assert_eq!(agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0), Outcome::Buffered);
        assert_eq!(agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0), Outcome::Buffered);
        // Two departures cap K at the live count (2): the buffer already
        // holds 2, so the second departure flushes.
        assert_eq!(agg.member_leave(&mut ps, 3), (true, None));
        let (changed, flushed) = agg.member_leave(&mut ps, 2);
        assert!(changed);
        assert!(matches!(flushed, Some(Outcome::Flushed { count: 2, .. })));
        assert_eq!(agg.current_k(), 2);
        // A rejoin restores the cap toward the schedule's K.
        assert!(agg.member_join(2));
        assert_eq!(agg.current_k(), 3);
        assert_eq!(agg.membership_epoch(), 3);
    }

    #[test]
    fn min_quorum_floors_the_renormalized_barrier() {
        let mut agg = Aggregator::new(Policy::Sync, 1, 3).with_elastic(3, 2);
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        // Two workers leave; live = 1 but the quorum floor keeps the
        // barrier at 2: the lone buffered gradient must wait for a joiner.
        assert_eq!(agg.member_leave(&mut ps, 2), (true, None));
        let (changed, flushed) = agg.member_leave(&mut ps, 1);
        assert!(changed);
        assert!(flushed.is_none(), "quorum floor must hold the barrier");
        assert_eq!(agg.current_k(), 2);
        assert_eq!(ps.version(), 0);
        // A joiner arrives and contributes: the floored barrier releases.
        assert!(agg.member_join(1));
        match agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0) {
            Outcome::Flushed { count: 2, .. } => {}
            o => panic!("expected flush at the quorum floor, got {o:?}"),
        }
    }

    #[test]
    fn elastic_departure_releases_a_strict_adaptive_barrier() {
        // The adaptive controller's K is clamped to live membership at the
        // departure itself — not one arrival later, which would never come
        // if every survivor is blocked (the stall elastic mode exists to
        // fix). A constant loss plateaus the controller, which drifts K to
        // k_max deterministically (one step per 2-arrival window).
        let cfg = AdaptiveConfig {
            window: 2,
            ..Default::default()
        };
        let mut agg = Aggregator::new(
            Policy::HybridAdaptive { cfg, strict: true },
            1,
            4,
        )
        .with_elastic(4, 1);
        let mut ps = store(1);
        let mut reached = false;
        for i in 0..100 {
            let v = ps.version();
            agg.on_gradient(&mut ps, &[1.0], i % 4, v, 1.0);
            if agg.current_k() == 4 && agg.buffered() == 3 {
                reached = true;
                break;
            }
        }
        assert!(reached, "controller never parked 3 workers at a K=4 barrier");
        // Worker 3 is declared dead: K clamps to the 3 live workers, which
        // the buffered contributions already satisfy — the departure
        // itself releases the barrier.
        let (changed, flushed) = agg.member_leave(&mut ps, 3);
        assert!(changed);
        assert!(
            matches!(flushed, Some(Outcome::Flushed { count: 3, .. })),
            "departure must release the adaptive barrier, got {flushed:?}"
        );
        assert!(agg.current_k() <= 3, "adaptive K must clamp to live membership");
    }

    #[test]
    fn static_aggregator_ignores_membership_events() {
        let mut agg = Aggregator::new(Policy::Sync, 1, 3);
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[1.0], 1, 0, 1.0);
        assert_eq!(agg.member_leave(&mut ps, 2), (false, None));
        assert!(!agg.member_join(2));
        assert_eq!(agg.live(), 3);
        assert_eq!(agg.membership_epoch(), 0);
        assert_eq!(agg.current_k(), 3, "static barrier must not renormalize");
        assert_eq!(ps.version(), 0);
    }

    #[test]
    fn mean_mode_is_bitwise_identical_to_default() {
        // `--aggregate mean` must take exactly the pre-defense code path.
        use crate::util::rng::Pcg64;
        let sched = Schedule::Step { step: 3 };
        let policy = Policy::Hybrid {
            schedule: sched,
            strict: false,
        };
        let mut plain = Aggregator::new(policy.clone(), 4, 4);
        let mut modal =
            Aggregator::new(policy, 4, 4).with_aggregate(AggregateMode::Mean);
        let mut ps_a = store(4);
        let mut ps_b = store(4);
        let mut rng = Pcg64::seeded(3);
        let mut g = vec![0.0f32; 4];
        for i in 0..40 {
            rng.fill_normal(&mut g, 1.0);
            let (va, vb) = (ps_a.version(), ps_b.version());
            let oa = plain.on_gradient(&mut ps_a, &g, i % 4, va, 1.0);
            let ob = modal.on_gradient(&mut ps_b, &g, i % 4, vb, 1.0);
            assert_eq!(oa, ob);
        }
        assert_eq!(ps_a.theta(), ps_b.theta());
        assert_eq!(ps_a.version(), ps_b.version());
    }

    #[test]
    fn trimmed_flush_survives_a_poisoned_contribution() {
        let sched = Schedule::Constant { k: 4 };
        let mut agg = Aggregator::new(
            Policy::Hybrid {
                schedule: sched,
                strict: false,
            },
            1,
            4,
        )
        .with_aggregate(AggregateMode::Trimmed(0.25));
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[1.2], 1, 0, 1.0);
        agg.on_gradient(&mut ps, &[0.8], 2, 0, 1.0);
        // worker 3 is Byzantine: a huge reversed gradient
        let out = agg.on_gradient(&mut ps, &[-1000.0], 3, 0, 1.0);
        assert!(matches!(out, Outcome::Flushed { count: 4, .. }));
        // trim ⌊0.25·4⌋ = 1 per end: mean(1.0, 1.2) over the survivors
        // θ = -0.1 · 1.1; a mean flush would have moved θ *up* by ~25.
        assert!((ps.theta()[0] + 0.11).abs() < 1e-6, "{:?}", ps.theta());
    }

    #[test]
    fn median_flush_takes_the_middle() {
        let mut agg = Aggregator::new(Policy::Sync, 1, 3)
            .with_aggregate(AggregateMode::Median);
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        agg.on_gradient(&mut ps, &[2.0], 1, 0, 1.0);
        let out = agg.on_gradient(&mut ps, &[900.0], 2, 0, 1.0);
        assert!(matches!(out, Outcome::Flushed { count: 3, .. }));
        // median(1, 2, 900) = 2 → θ = -0.1 · 2
        assert!((ps.theta()[0] + 0.2).abs() < 1e-6, "{:?}", ps.theta());
    }

    #[test]
    fn clip_scales_oversized_gradients_everywhere() {
        // Async: applied immediately, scaled to the radius.
        let mut agg =
            Aggregator::new(Policy::Async, 2, 2).with_aggregate(AggregateMode::Clip(1.0));
        let mut ps = store(2);
        agg.on_gradient(&mut ps, &[3.0, 4.0], 0, 0, 1.0); // ‖g‖ = 5 → ×0.2
        assert_eq!(agg.stats.clipped, 1);
        assert!((ps.theta()[0] + 0.1 * 0.6).abs() < 1e-6);
        assert!((ps.theta()[1] + 0.1 * 0.8).abs() < 1e-6);
        // within the radius: untouched, not counted
        agg.on_gradient(&mut ps, &[0.1, 0.0], 1, 1, 1.0);
        assert_eq!(agg.stats.clipped, 1);
        // Buffered policy: clipped at accumulation, mean flush over the
        // clipped contributions.
        let mut agg = Aggregator::new(Policy::Sync, 1, 2)
            .with_aggregate(AggregateMode::Clip(1.0));
        let mut ps = store(1);
        agg.on_gradient(&mut ps, &[1.0], 0, 0, 1.0);
        let out = agg.on_gradient(&mut ps, &[-100.0], 1, 0, 1.0);
        assert!(matches!(out, Outcome::Flushed { count: 2, .. }));
        assert_eq!(agg.stats.clipped, 1);
        // mean(1.0, -1.0) = 0 → θ unchanged by the attack
        assert!((ps.theta()[0]).abs() < 1e-6, "{:?}", ps.theta());
    }

    #[test]
    fn clip_sparse_view_matches_dense_clip() {
        use crate::coordinator::compress::GradView;
        let mut a =
            Aggregator::new(Policy::Async, 4, 1).with_aggregate(AggregateMode::Clip(1.0));
        let mut b =
            Aggregator::new(Policy::Async, 4, 1).with_aggregate(AggregateMode::Clip(1.0));
        let mut ps_a = store(4);
        let mut ps_b = store(4);
        let dense = [3.0f32, 0.0, -4.0, 0.0];
        a.on_gradient(&mut ps_a, &dense, 0, 0, 1.0);
        b.on_gradient_view(
            &mut ps_b,
            GradView::Sparse {
                idx: &[0, 2],
                val: &[3.0, -4.0],
            },
            0,
            0,
            1.0,
        );
        assert_eq!(ps_a.theta(), ps_b.theta());
        assert_eq!(a.stats.clipped, b.stats.clipped);
    }

    #[test]
    fn adaptive_parse_rejects_garbage() {
        // Bare `adaptive` is the documented default form …
        let p = Policy::parse("adaptive").unwrap();
        assert_eq!(
            p,
            Policy::HybridAdaptive {
                cfg: AdaptiveConfig::default(),
                strict: false
            }
        );
        // … but a non-`:` remainder must not silently parse as that default.
        assert!(Policy::parse("adaptivegarbage").is_err());
        assert!(Policy::parse("adaptive2").is_err());
        assert!(Policy::parse("adaptive:").is_err());
        assert!(Policy::parse("adaptive:notanumber").is_err());
        assert!(Policy::parse("adaptive:2:bogus").is_err());
    }
}
