//! The parameter store: versioned flat parameter vector (one per shard) with
//! in-place SGD application and zero-copy snapshot publication.
//!
//! Each shard-server thread owns one [`ParamStore`]. Readers (workers
//! refreshing their local copy, the evaluator) never receive O(dim) copies
//! over channels: the store publishes an immutable [`ParamSnapshot`] behind
//! an [`SnapshotCell`] and readers take an `Arc` clone — a pointer read
//! under a nanosecond-scale lock. The publisher pays one memcpy per update
//! into a recycled buffer (no steady-state allocation); readers copy out
//! only when the version actually changed.

use std::sync::{Arc, Mutex};

/// An immutable published view of one shard's parameters.
#[derive(Clone, Debug)]
pub struct ParamSnapshot {
    pub theta: Vec<f32>,
    pub version: u64,
}

/// Single-writer / multi-reader snapshot slot: the writer swaps in a fresh
/// `Arc<ParamSnapshot>`, readers clone the `Arc`. The mutex is held only for
/// the pointer swap/clone, never for the O(dim) copy, so readers cannot
/// stall the server and the server cannot stall readers.
pub struct SnapshotCell {
    slot: Mutex<Arc<ParamSnapshot>>,
}

impl SnapshotCell {
    /// A cell holding version 0 of the given parameters.
    pub fn new(init: Vec<f32>) -> SnapshotCell {
        SnapshotCell {
            slot: Mutex::new(Arc::new(ParamSnapshot {
                theta: init,
                version: 0,
            })),
        }
    }

    /// Current snapshot (cheap: one `Arc` clone under a short lock).
    pub fn load(&self) -> Arc<ParamSnapshot> {
        Arc::clone(&self.slot.lock().unwrap())
    }

    /// Published version without touching the payload.
    pub fn version(&self) -> u64 {
        self.slot.lock().unwrap().version
    }

    /// Swap in a new snapshot, returning the old one for buffer recycling.
    fn swap(&self, snap: Arc<ParamSnapshot>) -> Arc<ParamSnapshot> {
        std::mem::replace(&mut *self.slot.lock().unwrap(), snap)
    }

    /// Publish an explicit (θ, version) pair directly. Test/bench helper —
    /// production code publishes through [`ParamStore`] for recycling.
    pub(crate) fn publish_raw(&self, theta: Vec<f32>, version: u64) {
        self.swap(Arc::new(ParamSnapshot { theta, version }));
    }
}

/// Versioned parameters with in-place SGD updates (one shard's slice of θ).
pub struct ParamStore {
    theta: Vec<f32>,
    version: u64,
    lr: f32,
    /// Where snapshots are published for workers and the evaluator.
    cell: Arc<SnapshotCell>,
    /// Recycled buffer for the next publication (avoids re-allocating).
    spare: Option<Vec<f32>>,
}

impl ParamStore {
    pub fn new(init: Vec<f32>, lr: f32) -> Self {
        let cell = Arc::new(SnapshotCell::new(init.clone()));
        Self::with_cell(init, lr, cell)
    }

    /// Construct around an externally created cell (the trainer hands the
    /// same cell to the workers and the evaluator). The cell is reset to
    /// version 0 with `init`.
    pub fn with_cell(init: Vec<f32>, lr: f32, cell: Arc<SnapshotCell>) -> Self {
        cell.swap(Arc::new(ParamSnapshot {
            theta: init.clone(),
            version: 0,
        }));
        ParamStore {
            theta: init,
            version: 0,
            lr,
            cell,
            spare: None,
        }
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Handle readers use to follow this store's snapshots.
    pub fn cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.cell)
    }

    /// θ ← θ − lr · g  (single gradient; the asynchronous application).
    pub fn apply_single(&mut self, grad: &[f32]) {
        self.apply_view(super::compress::GradView::Dense(grad));
    }

    /// [`ParamStore::apply_single`] for a gradient in any wire format:
    /// dense runs the exact SGD loop as always; sparse views update only
    /// their nnz coordinates (O(nnz), not O(dim)); quantized views
    /// dequantize on the fly.
    pub fn apply_view(&mut self, grad: super::compress::GradView<'_>) {
        grad.apply_to(&mut self.theta, self.lr);
        self.bump();
    }

    /// [`ParamStore::apply_view`] with the gradient scaled by `factor`:
    /// θ ← θ − lr · factor · g. The norm-clipping application for the
    /// async policy (`factor = min(1, c/‖g‖)`, DESIGN.md §2.10); O(nnz)
    /// for sparse arms, never densifies.
    pub fn apply_view_scaled(&mut self, grad: super::compress::GradView<'_>, factor: f32) {
        grad.apply_to(&mut self.theta, self.lr * factor);
        self.bump();
    }

    /// θ ← θ − lr · (Σ grads) / count  (aggregated synchronous application).
    /// `sum` is the pre-summed gradient buffer.
    pub fn apply_mean(&mut self, sum: &[f32], count: usize) {
        debug_assert_eq!(sum.len(), self.theta.len());
        debug_assert!(count > 0);
        let scale = self.lr / count as f32;
        for (t, &s) in self.theta.iter_mut().zip(sum) {
            *t -= scale * s;
        }
        self.bump();
    }

    fn bump(&mut self) {
        self.version += 1;
        // Every version is published: replies carry only version numbers,
        // so the snapshot must always be current when its version says so.
        self.publish();
    }

    /// Push the current θ into the published snapshot. The buffer of the
    /// previous snapshot is recycled once the last reader drops it, so the
    /// steady state is one memcpy and zero allocations per update.
    pub fn publish(&mut self) {
        let mut buf = self
            .spare
            .take()
            .unwrap_or_else(|| Vec::with_capacity(self.theta.len()));
        buf.clear();
        buf.extend_from_slice(&self.theta);
        let old = self.cell.swap(Arc::new(ParamSnapshot {
            theta: buf,
            version: self.version,
        }));
        if let Ok(snap) = Arc::try_unwrap(old) {
            self.spare = Some(snap.theta);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_update_is_sgd() {
        let mut ps = ParamStore::new(vec![1.0, 2.0], 0.1);
        ps.apply_single(&[10.0, -10.0]);
        assert_eq!(ps.theta(), &[0.0, 3.0]);
        assert_eq!(ps.version(), 1);
    }

    #[test]
    fn sparse_view_updates_only_touched_coords() {
        use crate::coordinator::compress::GradView;
        let mut ps = ParamStore::new(vec![1.0, 2.0, 3.0], 0.1);
        ps.apply_view(GradView::Sparse {
            idx: &[0, 2],
            val: &[10.0, -10.0],
        });
        assert_eq!(ps.theta(), &[0.0, 2.0, 4.0]);
        assert_eq!(ps.version(), 1);
        // snapshot published, exactly as for dense applications
        assert_eq!(ps.cell().load().theta, vec![0.0, 2.0, 4.0]);
    }

    #[test]
    fn mean_update_averages() {
        let mut ps = ParamStore::new(vec![0.0, 0.0], 1.0);
        // sum of 4 gradients, each [1, 2] → mean [1, 2]
        ps.apply_mean(&[4.0, 8.0], 4);
        assert_eq!(ps.theta(), &[-1.0, -2.0]);
    }

    #[test]
    fn snapshot_publishes_every_update() {
        let mut ps = ParamStore::new(vec![5.0], 0.5);
        let cell = ps.cell();
        assert_eq!(cell.load().version, 0);
        ps.apply_single(&[2.0]);
        let snap = cell.load();
        assert_eq!(snap.theta, vec![4.0]);
        assert_eq!(snap.version, 1);
    }

    #[test]
    fn readers_keep_old_snapshots_alive() {
        let mut ps = ParamStore::new(vec![0.0], 1.0);
        let cell = ps.cell();
        let pinned = cell.load(); // a slow reader holding version 0
        ps.apply_single(&[1.0]);
        ps.apply_single(&[1.0]);
        assert_eq!(pinned.version, 0);
        assert_eq!(pinned.theta, vec![0.0]);
        assert_eq!(cell.load().version, 2);
        assert_eq!(cell.load().theta, vec![-2.0]);
    }

    #[test]
    fn publish_recycles_buffers() {
        let mut ps = ParamStore::new(vec![0.0; 64], 1.0);
        // No reader pins snapshots, so after a warm-up update every further
        // publish reuses the recycled buffer (observable via capacity).
        ps.apply_single(&[1.0; 64]);
        for _ in 0..100 {
            ps.apply_single(&[1.0; 64]);
        }
        assert_eq!(ps.cell().load().version, 101);
        assert!(ps.spare.is_some(), "publish should recycle the old buffer");
    }

    #[test]
    fn with_cell_resets_external_cell() {
        let cell = Arc::new(SnapshotCell::new(vec![9.0, 9.0]));
        {
            let mut ps = ParamStore::with_cell(vec![1.0, 2.0], 0.1, Arc::clone(&cell));
            ps.apply_single(&[0.0, 0.0]);
        }
        let snap = cell.load();
        assert_eq!(snap.theta, vec![1.0, 2.0]);
        assert_eq!(snap.version, 1);
        assert_eq!(cell.version(), 1);
    }
}
