//! The parameter store: versioned flat parameter vector + SGD application.
//!
//! Owned by the parameter-server thread; a read-only snapshot is shared with
//! the evaluator through a mutex (snapshots happen a few times per second,
//! updates thousands of times — the lock is uncontended by design: the PS
//! only takes it when publishing, see `publish_every`).

use std::sync::{Arc, Mutex};

/// Versioned parameters with in-place SGD updates.
pub struct ParamStore {
    theta: Vec<f32>,
    version: u64,
    lr: f32,
    /// Shared snapshot for the evaluator thread (param vector + version).
    snapshot: Arc<Mutex<(Vec<f32>, u64)>>,
    /// Publish the snapshot every this many updates (and on demand).
    publish_every: u64,
}

impl ParamStore {
    pub fn new(init: Vec<f32>, lr: f32) -> Self {
        let snapshot = Arc::new(Mutex::new((init.clone(), 0)));
        Self::with_shared(init, lr, snapshot)
    }

    /// Construct around an externally created snapshot cell (the trainer
    /// hands the same cell to the evaluator thread).
    pub fn with_shared(init: Vec<f32>, lr: f32, snapshot: Arc<Mutex<(Vec<f32>, u64)>>) -> Self {
        {
            let mut s = snapshot.lock().unwrap();
            s.0.clear();
            s.0.extend_from_slice(&init);
            s.1 = 0;
        }
        ParamStore {
            theta: init,
            version: 0,
            lr,
            snapshot,
            publish_every: 8,
        }
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Handle the evaluator uses to read snapshots.
    pub fn snapshot_handle(&self) -> Arc<Mutex<(Vec<f32>, u64)>> {
        Arc::clone(&self.snapshot)
    }

    /// θ ← θ − lr · g  (single gradient; the asynchronous application).
    pub fn apply_single(&mut self, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.theta.len());
        for (t, &g) in self.theta.iter_mut().zip(grad) {
            *t -= self.lr * g;
        }
        self.bump();
    }

    /// θ ← θ − lr · (Σ grads) / count  (aggregated synchronous application).
    /// `sum` is the pre-summed gradient buffer.
    pub fn apply_mean(&mut self, sum: &[f32], count: usize) {
        debug_assert_eq!(sum.len(), self.theta.len());
        debug_assert!(count > 0);
        let scale = self.lr / count as f32;
        for (t, &s) in self.theta.iter_mut().zip(sum) {
            *t -= scale * s;
        }
        self.bump();
    }

    fn bump(&mut self) {
        self.version += 1;
        if self.version % self.publish_every == 0 {
            self.publish();
        }
    }

    /// Push the current θ into the shared snapshot (called on flush
    /// boundaries and at shutdown so the evaluator never lags far).
    pub fn publish(&self) {
        let mut snap = self.snapshot.lock().unwrap();
        snap.0.copy_from_slice(&self.theta);
        snap.1 = self.version;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_update_is_sgd() {
        let mut ps = ParamStore::new(vec![1.0, 2.0], 0.1);
        ps.apply_single(&[10.0, -10.0]);
        assert_eq!(ps.theta(), &[0.0, 3.0]);
        assert_eq!(ps.version(), 1);
    }

    #[test]
    fn mean_update_averages() {
        let mut ps = ParamStore::new(vec![0.0, 0.0], 1.0);
        // sum of 4 gradients, each [1, 2] → mean [1, 2]
        ps.apply_mean(&[4.0, 8.0], 4);
        assert_eq!(ps.theta(), &[-1.0, -2.0]);
    }

    #[test]
    fn snapshot_publishes() {
        let mut ps = ParamStore::new(vec![5.0], 0.5);
        let handle = ps.snapshot_handle();
        ps.apply_single(&[2.0]);
        ps.publish();
        let snap = handle.lock().unwrap();
        assert_eq!(snap.0, vec![4.0]);
        assert_eq!(snap.1, 1);
    }

    #[test]
    fn snapshot_auto_publishes_every_n() {
        let mut ps = ParamStore::new(vec![0.0], 1.0);
        let handle = ps.snapshot_handle();
        for _ in 0..8 {
            ps.apply_single(&[1.0]);
        }
        let snap = handle.lock().unwrap();
        assert_eq!(snap.1, 8, "auto-publish at version 8");
    }
}
