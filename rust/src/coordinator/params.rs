//! The parameter store: versioned flat parameter vector (one per shard) with
//! in-place SGD application and zero-copy snapshot publication.
//!
//! Each shard-server thread owns one [`ParamStore`]. Readers (workers
//! refreshing their local copy, the evaluator) never receive O(dim) copies
//! over channels: the store publishes an immutable [`ParamSnapshot`] behind
//! an [`SnapshotCell`] and readers take an `Arc` clone — a pointer read
//! under a nanosecond-scale lock. The publisher pays one memcpy per update
//! into a recycled buffer (no steady-state allocation); readers copy out
//! only when the version actually changed.
//!
//! Big-model path (DESIGN.md §2.12): θ is tracked in fixed-size blocks of
//! [`BLOCK_ELEMS`] coordinates, each stamped with the version at which it
//! last changed. `publish()` copies only the blocks that moved since the
//! recycled buffer's content version (sparse updates touch O(nnz) blocks,
//! not O(dim)), and the published `block_versions` let the transport serve
//! delta refreshes: a reader at version `have` needs exactly the blocks
//! with `block_versions[b] > have`. Snapshots optionally store parameters
//! in half precision ([`ParamDtype::F16`]/[`ParamDtype::Bf16`]) — master
//! weights stay f32, only published copies and the wire shrink.

use std::ops::Range;
use std::sync::{Arc, Mutex};

/// Coordinates per dirty-tracking block (16 KiB of f32). Small enough that
/// a sparse top-k update dirties a sliver of a big shard, large enough that
/// per-block bookkeeping is noise (one u64 per 16 KiB).
pub const BLOCK_ELEMS: usize = 4096;

/// How many retired snapshot buffers `publish()` keeps for reuse. Two, not
/// one: with a single spare, one pinned reader (an evaluator holding the
/// previous snapshot) turns every publish into a fresh full-dim allocation.
pub const SPARE_POOL_CAP: usize = 2;

/// Number of [`BLOCK_ELEMS`]-sized blocks covering `len` coordinates.
pub fn block_count(len: usize) -> usize {
    (len + BLOCK_ELEMS - 1) / BLOCK_ELEMS
}

/// Coordinate range of block `b` within a vector of `len` coordinates.
pub fn block_range(b: usize, len: usize) -> Range<usize> {
    let start = b * BLOCK_ELEMS;
    start..((start + BLOCK_ELEMS).min(len))
}

/// Storage precision of *published* parameter snapshots (and therefore of
/// snapshot wire payloads). Master weights in the store are always f32.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ParamDtype {
    #[default]
    F32,
    F16,
    Bf16,
}

impl ParamDtype {
    pub fn parse(s: &str) -> Option<ParamDtype> {
        match s {
            "f32" => Some(ParamDtype::F32),
            "f16" => Some(ParamDtype::F16),
            "bf16" => Some(ParamDtype::Bf16),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ParamDtype::F32 => "f32",
            ParamDtype::F16 => "f16",
            ParamDtype::Bf16 => "bf16",
        }
    }

    /// Wire tag (one byte in `Msg::SnapshotDelta`).
    pub fn tag(&self) -> u8 {
        match self {
            ParamDtype::F32 => 0,
            ParamDtype::F16 => 1,
            ParamDtype::Bf16 => 2,
        }
    }

    pub fn from_tag(t: u8) -> Option<ParamDtype> {
        match t {
            0 => Some(ParamDtype::F32),
            1 => Some(ParamDtype::F16),
            2 => Some(ParamDtype::Bf16),
            _ => None,
        }
    }

    /// Bytes per stored coordinate.
    pub fn elem_bytes(&self) -> usize {
        match self {
            ParamDtype::F32 => 4,
            ParamDtype::F16 | ParamDtype::Bf16 => 2,
        }
    }
}

// ---------------------------------------------------------------------------
// Half-precision conversions (hand-rolled: std has no f16/bf16). Both are
// round-to-nearest-even, the IEEE default, so converting the same f32 twice
// always yields the same bits — unchanged blocks stay bitwise-stable across
// delta publishes.
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits, round-to-nearest-even. Overflow saturates to
/// ±Inf; NaN maps to a quiet NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    if (bits & 0x7fff_ffff) > 0x7f80_0000 {
        return sign | 0x7e00; // NaN
    }
    let exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let man = bits & 0x007f_ffff;
    if exp >= 0x1f {
        return sign | 0x7c00; // Inf or overflow
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflows past subnormal range: ±0
        }
        // Subnormal result: shift the full 24-bit significand into place.
        let man = man | 0x0080_0000;
        let shift = (14 - exp) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let up = rem > halfway || (rem == halfway && (half & 1) == 1);
        return sign | (half + up as u32) as u16;
    }
    let half = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    // A carry out of the mantissa bumps the exponent (possibly to Inf),
    // which is exactly correct rounding behaviour.
    sign | (half + up as u32) as u16
}

/// IEEE binary16 bits → f32 (exact: every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = (h >> 10) & 0x1f;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: normalize into the f32 exponent range.
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // Inf / NaN
    } else {
        sign | ((exp as u32 + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits (truncate the mantissa to 7 bits), round-to-nearest
/// -even. NaN keeps its sign and is forced quiet.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    (bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) >> 16) as u16
}

/// bfloat16 bits → f32 (exact).
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Decode `bytes` (little-endian coordinates of `dtype`) into `out`.
/// Panics if the byte count does not match `out.len() * elem_bytes` —
/// callers validate lengths at the wire boundary first.
pub fn decode_block_into(dtype: ParamDtype, bytes: &[u8], out: &mut [f32]) {
    assert_eq!(bytes.len(), out.len() * dtype.elem_bytes());
    match dtype {
        ParamDtype::F32 => {
            for (o, c) in out.iter_mut().zip(bytes.chunks_exact(4)) {
                *o = f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
        }
        ParamDtype::F16 => {
            for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
            }
        }
        ParamDtype::Bf16 => {
            for (o, c) in out.iter_mut().zip(bytes.chunks_exact(2)) {
                *o = bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
            }
        }
    }
}

/// Published parameter payload in its storage precision.
#[derive(Clone, Debug)]
pub enum SnapshotData {
    F32(Vec<f32>),
    F16(Vec<u16>),
    Bf16(Vec<u16>),
}

impl SnapshotData {
    fn with_len(dtype: ParamDtype, len: usize) -> SnapshotData {
        match dtype {
            ParamDtype::F32 => SnapshotData::F32(vec![0.0; len]),
            ParamDtype::F16 => SnapshotData::F16(vec![0; len]),
            ParamDtype::Bf16 => SnapshotData::Bf16(vec![0; len]),
        }
    }

    fn from_theta(dtype: ParamDtype, theta: &[f32]) -> SnapshotData {
        match dtype {
            ParamDtype::F32 => SnapshotData::F32(theta.to_vec()),
            ParamDtype::F16 => {
                SnapshotData::F16(theta.iter().map(|&x| f32_to_f16_bits(x)).collect())
            }
            ParamDtype::Bf16 => {
                SnapshotData::Bf16(theta.iter().map(|&x| f32_to_bf16_bits(x)).collect())
            }
        }
    }

    pub fn dtype(&self) -> ParamDtype {
        match self {
            SnapshotData::F32(_) => ParamDtype::F32,
            SnapshotData::F16(_) => ParamDtype::F16,
            SnapshotData::Bf16(_) => ParamDtype::Bf16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            SnapshotData::F32(v) => v.len(),
            SnapshotData::F16(v) | SnapshotData::Bf16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy coordinates `r` from master weights into this buffer,
    /// converting to the storage precision. Returns bytes written.
    fn copy_block_from(&mut self, theta: &[f32], r: Range<usize>) -> usize {
        let n = r.len();
        match self {
            SnapshotData::F32(v) => v[r.clone()].copy_from_slice(&theta[r]),
            SnapshotData::F16(v) => {
                for (d, &s) in v[r.clone()].iter_mut().zip(&theta[r]) {
                    *d = f32_to_f16_bits(s);
                }
            }
            SnapshotData::Bf16(v) => {
                for (d, &s) in v[r.clone()].iter_mut().zip(&theta[r]) {
                    *d = f32_to_bf16_bits(s);
                }
            }
        }
        n * self.dtype().elem_bytes()
    }

    /// Dequantize coordinates `r` into an f32 slice of the same length.
    pub fn copy_to_f32(&self, r: Range<usize>, out: &mut [f32]) {
        debug_assert_eq!(r.len(), out.len());
        match self {
            SnapshotData::F32(v) => out.copy_from_slice(&v[r]),
            SnapshotData::F16(v) => {
                for (o, &h) in out.iter_mut().zip(&v[r]) {
                    *o = f16_bits_to_f32(h);
                }
            }
            SnapshotData::Bf16(v) => {
                for (o, &h) in out.iter_mut().zip(&v[r]) {
                    *o = bf16_bits_to_f32(h);
                }
            }
        }
    }

    /// Append the little-endian wire bytes of coordinates `r`.
    pub fn extend_wire_bytes(&self, r: Range<usize>, out: &mut Vec<u8>) {
        match self {
            SnapshotData::F32(v) => {
                for &x in &v[r] {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            SnapshotData::F16(v) | SnapshotData::Bf16(v) => {
                for &h in &v[r] {
                    out.extend_from_slice(&h.to_le_bytes());
                }
            }
        }
    }
}

/// An immutable published view of one shard's parameters.
#[derive(Clone, Debug)]
pub struct ParamSnapshot {
    pub data: SnapshotData,
    pub version: u64,
    /// Version at which each [`BLOCK_ELEMS`]-sized block last changed.
    /// A reader at version `have` is brought current by exactly the blocks
    /// with `block_versions[b] > have`.
    pub block_versions: Vec<u64>,
}

impl ParamSnapshot {
    fn full(data: SnapshotData, version: u64) -> ParamSnapshot {
        let blocks = block_count(data.len());
        ParamSnapshot {
            data,
            version,
            block_versions: vec![version; blocks],
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn dtype(&self) -> ParamDtype {
        self.data.dtype()
    }

    /// The parameters as f32. Panics unless the snapshot stores f32 —
    /// half-precision readers go through [`ParamSnapshot::copy_to`].
    pub fn theta(&self) -> &[f32] {
        match &self.data {
            SnapshotData::F32(v) => v,
            other => panic!(
                "snapshot stores {}, not f32; use copy_to",
                other.dtype().as_str()
            ),
        }
    }

    /// Full dequantizing copy into a same-length f32 buffer.
    pub fn copy_to(&self, out: &mut [f32]) {
        self.data.copy_to_f32(0..self.len(), out);
    }

    /// Indices of the blocks a reader at version `have` is missing.
    pub fn blocks_newer_than(&self, have: u64) -> Vec<usize> {
        self.block_versions
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > have)
            .map(|(b, _)| b)
            .collect()
    }
}

/// Single-writer / multi-reader snapshot slot: the writer swaps in a fresh
/// `Arc<ParamSnapshot>`, readers clone the `Arc`. The mutex is held only for
/// the pointer swap/clone, never for the O(dim) copy, so readers cannot
/// stall the server and the server cannot stall readers.
pub struct SnapshotCell {
    slot: Mutex<Arc<ParamSnapshot>>,
}

impl SnapshotCell {
    /// A cell holding version 0 of the given parameters (f32 storage).
    pub fn new(init: Vec<f32>) -> SnapshotCell {
        SnapshotCell {
            slot: Mutex::new(Arc::new(ParamSnapshot::full(SnapshotData::F32(init), 0))),
        }
    }

    /// Current snapshot (cheap: one `Arc` clone under a short lock).
    pub fn load(&self) -> Arc<ParamSnapshot> {
        Arc::clone(&self.slot.lock().unwrap())
    }

    /// Published version without touching the payload.
    pub fn version(&self) -> u64 {
        self.slot.lock().unwrap().version
    }

    /// Swap in a new snapshot, returning the old one for buffer recycling.
    fn swap(&self, snap: Arc<ParamSnapshot>) -> Arc<ParamSnapshot> {
        std::mem::replace(&mut *self.slot.lock().unwrap(), snap)
    }

    /// Publish an explicit (θ, version) pair directly. Test/bench helper —
    /// production code publishes through [`ParamStore`] for recycling.
    pub(crate) fn publish_raw(&self, theta: Vec<f32>, version: u64) {
        self.swap(Arc::new(ParamSnapshot::full(
            SnapshotData::F32(theta),
            version,
        )));
    }
}

/// A retired snapshot buffer waiting for reuse: its contents are exactly
/// the published parameters at `version`, so the next publish only has to
/// re-copy blocks that changed after that.
struct SpareBuf {
    version: u64,
    data: SnapshotData,
    block_versions: Vec<u64>,
}

/// Versioned parameters with in-place SGD updates (one shard's slice of θ).
pub struct ParamStore {
    theta: Vec<f32>,
    version: u64,
    lr: f32,
    dtype: ParamDtype,
    /// Version at which each block of `theta` last changed (master-side
    /// mirror of the published `block_versions`).
    block_versions: Vec<u64>,
    /// Where snapshots are published for workers and the evaluator.
    cell: Arc<SnapshotCell>,
    /// Recycled buffers for upcoming publications (cap [`SPARE_POOL_CAP`]).
    pool: Vec<SpareBuf>,
    /// Lifetime publish count and bytes actually copied into snapshots
    /// (delta publishes copy only dirty blocks, so this is << dim·4·versions
    /// for sparse workloads).
    publishes: u64,
    bytes_published: u64,
}

impl ParamStore {
    pub fn new(init: Vec<f32>, lr: f32) -> Self {
        Self::with_dtype(init, lr, ParamDtype::F32)
    }

    pub fn with_dtype(init: Vec<f32>, lr: f32, dtype: ParamDtype) -> Self {
        let cell = Arc::new(SnapshotCell::new(init.clone()));
        Self::with_cell_dtype(init, lr, cell, dtype)
    }

    /// Construct around an externally created cell (the trainer hands the
    /// same cell to the workers and the evaluator). The cell is reset to
    /// version 0 with `init`.
    pub fn with_cell(init: Vec<f32>, lr: f32, cell: Arc<SnapshotCell>) -> Self {
        Self::with_cell_dtype(init, lr, cell, ParamDtype::F32)
    }

    pub fn with_cell_dtype(
        init: Vec<f32>,
        lr: f32,
        cell: Arc<SnapshotCell>,
        dtype: ParamDtype,
    ) -> Self {
        cell.swap(Arc::new(ParamSnapshot::full(
            SnapshotData::from_theta(dtype, &init),
            0,
        )));
        let blocks = block_count(init.len());
        ParamStore {
            block_versions: vec![0; blocks],
            theta: init,
            version: 0,
            lr,
            dtype,
            cell,
            pool: Vec::new(),
            publishes: 0,
            bytes_published: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.theta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.theta.is_empty()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    pub fn dtype(&self) -> ParamDtype {
        self.dtype
    }

    /// Master weights — always f32 regardless of snapshot dtype.
    pub fn theta(&self) -> &[f32] {
        &self.theta
    }

    /// Lifetime number of snapshot publications.
    pub fn publishes(&self) -> u64 {
        self.publishes
    }

    /// Lifetime bytes memcpy'd/converted into published snapshots.
    pub fn snapshot_bytes_published(&self) -> u64 {
        self.bytes_published
    }

    /// Handle readers use to follow this store's snapshots.
    pub fn cell(&self) -> Arc<SnapshotCell> {
        Arc::clone(&self.cell)
    }

    /// θ ← θ − lr · g  (single gradient; the asynchronous application).
    pub fn apply_single(&mut self, grad: &[f32]) {
        self.apply_view(super::compress::GradView::Dense(grad));
    }

    /// Stamp the blocks `grad` touches with the version the pending update
    /// will have. Dense and full-dim quantized views touch everything;
    /// sparse views dirty only the blocks holding their nnz coordinates.
    fn mark_dirty(&mut self, grad: &super::compress::GradView<'_>) {
        let next = self.version + 1;
        match grad {
            super::compress::GradView::Dense(_) | super::compress::GradView::Quant { .. } => {
                for v in &mut self.block_versions {
                    *v = next;
                }
            }
            super::compress::GradView::Sparse { idx, .. }
            | super::compress::GradView::SparseQuant { idx, .. } => {
                for &i in idx.iter() {
                    self.block_versions[i as usize / BLOCK_ELEMS] = next;
                }
            }
        }
    }

    /// [`ParamStore::apply_single`] for a gradient in any wire format:
    /// dense runs the exact SGD loop as always; sparse views update only
    /// their nnz coordinates (O(nnz), not O(dim)); quantized views
    /// dequantize on the fly.
    pub fn apply_view(&mut self, grad: super::compress::GradView<'_>) {
        self.mark_dirty(&grad);
        grad.apply_to(&mut self.theta, self.lr);
        self.bump();
    }

    /// [`ParamStore::apply_view`] with the gradient scaled by `factor`:
    /// θ ← θ − lr · factor · g. The norm-clipping application for the
    /// async policy (`factor = min(1, c/‖g‖)`, DESIGN.md §2.10); O(nnz)
    /// for sparse arms, never densifies.
    pub fn apply_view_scaled(&mut self, grad: super::compress::GradView<'_>, factor: f32) {
        self.mark_dirty(&grad);
        grad.apply_to(&mut self.theta, self.lr * factor);
        self.bump();
    }

    /// θ ← θ − lr · (Σ grads) / count  (aggregated synchronous application).
    /// `sum` is the pre-summed gradient buffer.
    pub fn apply_mean(&mut self, sum: &[f32], count: usize) {
        debug_assert_eq!(sum.len(), self.theta.len());
        debug_assert!(count > 0);
        let next = self.version + 1;
        for v in &mut self.block_versions {
            *v = next;
        }
        let scale = self.lr / count as f32;
        for (t, &s) in self.theta.iter_mut().zip(sum) {
            *t -= scale * s;
        }
        self.bump();
    }

    fn bump(&mut self) {
        self.version += 1;
        // Every version is published: replies carry only version numbers,
        // so the snapshot must always be current when its version says so.
        self.publish();
    }

    /// Push the current θ into the published snapshot. Retired snapshot
    /// buffers are recycled once the last reader drops them; because a
    /// recycled buffer still holds the exact published contents of its
    /// version, only blocks dirtied after that version are re-copied — a
    /// sparse update on a 1e8-coordinate shard publishes in O(nnz), and the
    /// steady state allocates nothing.
    pub fn publish(&mut self) {
        // Freshest recycled buffer first: fewest stale blocks to re-copy.
        let spare = if self.pool.is_empty() {
            None
        } else {
            let mut best = 0;
            for i in 1..self.pool.len() {
                if self.pool[i].version > self.pool[best].version {
                    best = i;
                }
            }
            Some(self.pool.swap_remove(best))
        };
        let (data, block_versions) = match spare {
            Some(mut s) => {
                debug_assert_eq!(s.data.len(), self.theta.len());
                for (b, &v) in self.block_versions.iter().enumerate() {
                    if v > s.version {
                        let r = block_range(b, self.theta.len());
                        self.bytes_published += s.data.copy_block_from(&self.theta, r) as u64;
                    }
                }
                s.block_versions.copy_from_slice(&self.block_versions);
                (s.data, s.block_versions)
            }
            None => {
                let mut data = SnapshotData::with_len(self.dtype, self.theta.len());
                for b in 0..self.block_versions.len() {
                    let r = block_range(b, self.theta.len());
                    self.bytes_published += data.copy_block_from(&self.theta, r) as u64;
                }
                (data, self.block_versions.clone())
            }
        };
        self.publishes += 1;
        let old = self.cell.swap(Arc::new(ParamSnapshot {
            data,
            version: self.version,
            block_versions,
        }));
        if let Ok(snap) = Arc::try_unwrap(old) {
            if self.pool.len() < SPARE_POOL_CAP {
                self.pool.push(SpareBuf {
                    version: snap.version,
                    data: snap.data,
                    block_versions: snap.block_versions,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_update_is_sgd() {
        let mut ps = ParamStore::new(vec![1.0, 2.0], 0.1);
        ps.apply_single(&[10.0, -10.0]);
        assert_eq!(ps.theta(), &[0.0, 3.0]);
        assert_eq!(ps.version(), 1);
    }

    #[test]
    fn sparse_view_updates_only_touched_coords() {
        use crate::coordinator::compress::GradView;
        let mut ps = ParamStore::new(vec![1.0, 2.0, 3.0], 0.1);
        ps.apply_view(GradView::Sparse {
            idx: &[0, 2],
            val: &[10.0, -10.0],
        });
        assert_eq!(ps.theta(), &[0.0, 2.0, 4.0]);
        assert_eq!(ps.version(), 1);
        // snapshot published, exactly as for dense applications
        assert_eq!(ps.cell().load().theta(), &[0.0, 2.0, 4.0]);
    }

    #[test]
    fn mean_update_averages() {
        let mut ps = ParamStore::new(vec![0.0, 0.0], 1.0);
        // sum of 4 gradients, each [1, 2] → mean [1, 2]
        ps.apply_mean(&[4.0, 8.0], 4);
        assert_eq!(ps.theta(), &[-1.0, -2.0]);
    }

    #[test]
    fn snapshot_publishes_every_update() {
        let mut ps = ParamStore::new(vec![5.0], 0.5);
        let cell = ps.cell();
        assert_eq!(cell.load().version, 0);
        ps.apply_single(&[2.0]);
        let snap = cell.load();
        assert_eq!(snap.theta(), &[4.0]);
        assert_eq!(snap.version, 1);
    }

    #[test]
    fn readers_keep_old_snapshots_alive() {
        let mut ps = ParamStore::new(vec![0.0], 1.0);
        let cell = ps.cell();
        let pinned = cell.load(); // a slow reader holding version 0
        ps.apply_single(&[1.0]);
        ps.apply_single(&[1.0]);
        assert_eq!(pinned.version, 0);
        assert_eq!(pinned.theta(), &[0.0]);
        assert_eq!(cell.load().version, 2);
        assert_eq!(cell.load().theta(), &[-2.0]);
    }

    #[test]
    fn publish_recycles_buffers() {
        let mut ps = ParamStore::new(vec![0.0; 64], 1.0);
        // No reader pins snapshots, so after a warm-up update every further
        // publish reuses a recycled buffer (observable via the pool).
        ps.apply_single(&[1.0; 64]);
        for _ in 0..100 {
            ps.apply_single(&[1.0; 64]);
        }
        assert_eq!(ps.cell().load().version, 101);
        assert!(!ps.pool.is_empty(), "publish should recycle the old buffer");
    }

    #[test]
    fn publish_recycles_buffers_under_reader_pin() {
        // One pinned reader must not force an allocation per publish: the
        // pool (cap 2) keeps a second buffer in rotation. Steady state is
        // detectable as the pool staying non-empty across publishes while
        // the pin is held.
        let mut ps = ParamStore::new(vec![0.0; 64], 1.0);
        let cell = ps.cell();
        ps.apply_single(&[1.0; 64]);
        ps.apply_single(&[1.0; 64]); // warm the pool
        let _pinned = cell.load(); // evaluator parks on the current snapshot
        for i in 0..100 {
            ps.apply_single(&[1.0; 64]);
            if i > 0 {
                // After the first pinned publish the free snapshot and the
                // pool rotate: every further publish finds a spare.
                assert!(
                    !ps.pool.is_empty(),
                    "pinned reader degraded publish to allocation-per-update (i={i})"
                );
            }
        }
        assert_eq!(ps.cell().load().version, 102);
        assert_eq!(_pinned.version, 2);
    }

    #[test]
    fn with_cell_resets_external_cell() {
        let cell = Arc::new(SnapshotCell::new(vec![9.0, 9.0]));
        {
            let mut ps = ParamStore::with_cell(vec![1.0, 2.0], 0.1, Arc::clone(&cell));
            ps.apply_single(&[0.0, 0.0]);
        }
        let snap = cell.load();
        assert_eq!(snap.theta(), &[1.0, 2.0]);
        assert_eq!(snap.version, 1);
        assert_eq!(cell.version(), 1);
    }

    // -- block versioning ---------------------------------------------------

    #[test]
    fn block_geometry() {
        assert_eq!(block_count(0), 0);
        assert_eq!(block_count(1), 1);
        assert_eq!(block_count(BLOCK_ELEMS), 1);
        assert_eq!(block_count(BLOCK_ELEMS + 1), 2);
        assert_eq!(block_range(0, 10), 0..10);
        assert_eq!(block_range(1, BLOCK_ELEMS + 10), BLOCK_ELEMS..BLOCK_ELEMS + 10);
    }

    #[test]
    fn sparse_update_dirties_only_its_blocks() {
        use crate::coordinator::compress::GradView;
        let dim = 3 * BLOCK_ELEMS;
        let mut ps = ParamStore::new(vec![0.0; dim], 1.0);
        // touch one coordinate in block 2 only
        let idx = [2 * BLOCK_ELEMS as u32 + 7];
        ps.apply_view(GradView::Sparse {
            idx: &idx,
            val: &[1.0],
        });
        let snap = ps.cell().load();
        assert_eq!(snap.block_versions, vec![0, 0, 1]);
        assert_eq!(snap.blocks_newer_than(0), vec![2]);
        assert!(snap.blocks_newer_than(1).is_empty());
        // The first publish finds no recycled buffer (one-time warm-up full
        // copy); the second recycles the v0 buffer and copies only the
        // dirty block.
        assert_eq!(ps.snapshot_bytes_published(), (dim * 4) as u64);
        drop(snap);
        ps.apply_view(GradView::Sparse {
            idx: &idx,
            val: &[1.0],
        });
        assert_eq!(
            ps.snapshot_bytes_published(),
            (dim * 4 + BLOCK_ELEMS * 4) as u64,
            "delta publish must copy only the dirty block"
        );
        let snap = ps.cell().load();
        assert_eq!(snap.block_versions, vec![0, 0, 2]);
    }

    #[test]
    fn dense_update_dirties_everything() {
        let dim = 2 * BLOCK_ELEMS;
        let mut ps = ParamStore::new(vec![0.0; dim], 1.0);
        ps.apply_single(&vec![1.0; dim]);
        let snap = ps.cell().load();
        assert_eq!(snap.block_versions, vec![1, 1]);
        assert_eq!(snap.blocks_newer_than(0), vec![0, 1]);
    }

    #[test]
    fn delta_publish_matches_master_bitwise() {
        use crate::coordinator::compress::GradView;
        // Interleave sparse and dense updates; every published snapshot
        // must equal the master weights exactly.
        let dim = 2 * BLOCK_ELEMS + 17;
        let mut ps = ParamStore::new((0..dim).map(|i| i as f32 * 0.25).collect(), 0.01);
        let cell = ps.cell();
        let mut rng: u64 = 42;
        for step in 0..50 {
            if step % 7 == 3 {
                ps.apply_single(&vec![0.125; dim]);
            } else {
                let mut idx = Vec::new();
                let mut val = Vec::new();
                for _ in 0..5 {
                    rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    idx.push((rng % dim as u64) as u32);
                    val.push(((rng >> 32) as i32 as f32) * 1e-9);
                }
                idx.sort_unstable();
                idx.dedup();
                val.truncate(idx.len());
                ps.apply_view(GradView::Sparse {
                    idx: &idx,
                    val: &val,
                });
            }
            let snap = cell.load();
            assert_eq!(snap.version, ps.version());
            assert_eq!(snap.theta(), ps.theta(), "step {step}");
        }
    }

    // -- half-precision conversions -----------------------------------------

    #[test]
    fn f16_roundtrip_exact_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 65504.0, -65504.0, 6.1035156e-5] {
            let h = f32_to_f16_bits(x);
            assert_eq!(f16_bits_to_f32(h), x, "x={x}");
        }
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(
            f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)),
            f32::NEG_INFINITY
        );
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow saturates to Inf
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e6)), f32::INFINITY);
        // subnormal range survives
        let tiny = 5.9604645e-8; // smallest f16 subnormal
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
    }

    #[test]
    fn f16_round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; the
        // even mantissa (1.0) wins.
        let halfway = f32::from_bits(0x3f80_1000);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(halfway)), 1.0);
        // 1 + 3·2^-11 is halfway with an odd low bit: rounds up.
        let halfway_odd = f32::from_bits(0x3f80_3000);
        assert_eq!(
            f32_to_f16_bits(halfway_odd),
            f32_to_f16_bits(f32::from_bits(0x3f80_4000))
        );
    }

    #[test]
    fn bf16_roundtrip_and_rounding() {
        for &x in &[0.0f32, -0.0, 1.0, -2.5, 3.0e38, 1.18e-38] {
            let b = f32_to_bf16_bits(x);
            let y = bf16_bits_to_f32(b);
            if x == 0.0 {
                assert_eq!(y, x);
            } else {
                assert!((y - x).abs() / x.abs() < 1.0 / 128.0, "x={x} y={y}");
            }
        }
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::INFINITY)), f32::INFINITY);
        // round-to-nearest-even at the 8-bit mantissa boundary
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::from_bits(0x3f80_8000))), 1.0);
        // near-max f32 overflows to Inf rather than wrapping
        assert_eq!(bf16_bits_to_f32(f32_to_bf16_bits(f32::MAX)), f32::INFINITY);
    }

    #[test]
    fn half_precision_relative_error_bound() {
        // Property: for normal-range values the conversion error is bounded
        // by the precision of the target mantissa — 2^-11 for f16 (10+1
        // bits), 2^-8 for bf16 (7+1 bits). This is the documented eval-
        // divergence bound from DESIGN.md §2.12.
        let mut rng: u64 = 0x9e3779b97f4a7c15;
        for _ in 0..10_000 {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // uniform in ±[2^-10, 2^10): comfortably inside both formats'
            // normal ranges
            let mant = ((rng >> 40) as f32 / (1u64 << 24) as f32) + 1.0; // [1,2)
            let e = ((rng >> 8) % 21) as i32 - 10;
            let sign = if rng & 1 == 0 { 1.0 } else { -1.0 };
            let x = sign * mant * (e as f32).exp2();
            let f16_err = (f16_bits_to_f32(f32_to_f16_bits(x)) - x).abs() / x.abs();
            assert!(f16_err <= 1.0 / 2048.0, "f16 err {f16_err} at {x}");
            let bf_err = (bf16_bits_to_f32(f32_to_bf16_bits(x)) - x).abs() / x.abs();
            assert!(bf_err <= 1.0 / 256.0, "bf16 err {bf_err} at {x}");
        }
    }

    #[test]
    fn f16_store_publishes_half_precision_deltas() {
        use crate::coordinator::compress::GradView;
        let dim = BLOCK_ELEMS + 5;
        let init: Vec<f32> = (0..dim).map(|i| (i as f32).sin()).collect();
        let mut ps = ParamStore::with_dtype(init.clone(), 0.1, ParamDtype::F16);
        let cell = ps.cell();
        // version 0 snapshot is already f16
        let snap0 = cell.load();
        assert_eq!(snap0.dtype(), ParamDtype::F16);
        let mut got = vec![0.0f32; dim];
        snap0.copy_to(&mut got);
        for (g, x) in got.iter().zip(&init) {
            assert_eq!(*g, f16_bits_to_f32(f32_to_f16_bits(*x)));
        }
        // sparse update republishes only one block, and the snapshot equals
        // a from-scratch conversion of the master weights (unchanged blocks
        // are bitwise-stable because the conversion is deterministic)
        ps.apply_view(GradView::Sparse {
            idx: &[3],
            val: &[1.0],
        });
        let snap1 = cell.load();
        snap1.copy_to(&mut got);
        for (i, (g, x)) in got.iter().zip(ps.theta()).enumerate() {
            assert_eq!(*g, f16_bits_to_f32(f32_to_f16_bits(*x)), "coord {i}");
        }
        assert_eq!(snap1.block_versions, vec![1, 0]);
        // bytes: one-time warm-up full copy at 2 B/coord...
        assert_eq!(ps.snapshot_bytes_published(), (dim * 2) as u64);
        drop(snap0);
        drop(snap1);
        // ...then deltas copy one block at 2 B/coord
        ps.apply_view(GradView::Sparse {
            idx: &[7],
            val: &[1.0],
        });
        assert_eq!(
            ps.snapshot_bytes_published(),
            (dim * 2 + BLOCK_ELEMS * 2) as u64
        );
    }

    #[test]
    fn decode_block_roundtrips_wire_bytes() {
        let theta: Vec<f32> = (0..100).map(|i| (i as f32) * 0.37 - 18.0).collect();
        for dtype in [ParamDtype::F32, ParamDtype::F16, ParamDtype::Bf16] {
            let data = SnapshotData::from_theta(dtype, &theta);
            let mut wire = Vec::new();
            data.extend_wire_bytes(20..60, &mut wire);
            assert_eq!(wire.len(), 40 * dtype.elem_bytes());
            let mut out = vec![0.0f32; 40];
            decode_block_into(dtype, &wire, &mut out);
            let mut want = vec![0.0f32; 40];
            data.copy_to_f32(20..60, &mut want);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn dtype_parse_and_tags() {
        assert_eq!(ParamDtype::parse("f32"), Some(ParamDtype::F32));
        assert_eq!(ParamDtype::parse("f16"), Some(ParamDtype::F16));
        assert_eq!(ParamDtype::parse("bf16"), Some(ParamDtype::Bf16));
        assert_eq!(ParamDtype::parse("f64"), None);
        for d in [ParamDtype::F32, ParamDtype::F16, ParamDtype::Bf16] {
            assert_eq!(ParamDtype::from_tag(d.tag()), Some(d));
            assert_eq!(ParamDtype::parse(d.as_str()), Some(d));
        }
        assert_eq!(ParamDtype::from_tag(9), None);
    }
}
