//! Gradient compression codecs.
//!
//! The paper's related-work survey credits Horovod's gradient compression as
//! a scalability lever for synchronous training; this module provides the
//! two standard codecs as an optional worker-side transform so the framework
//! covers that axis too:
//!
//! - **Top-k sparsification** with error feedback: only the k
//!   largest-magnitude coordinates are transmitted; the residual is
//!   accumulated locally and added to the next gradient (the standard
//!   convergence-preserving trick).
//! - **Int8 linear quantization**: per-tensor scale, 4× smaller payloads.
//!
//! Codecs operate on the flat gradient vector and are exercised by the
//! ablation bench; the default pipeline sends raw f32 (the channel transport
//! is in-process, so compression is about *fidelity semantics*, not
//! bandwidth, in this reproduction — the codec math is what the tests pin).

/// A sparse gradient: sorted coordinate/value pairs.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGrad {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseGrad {
    /// Dense reconstruction (zeros elsewhere).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// Payload size in bytes (index + value per entry).
    pub fn payload_bytes(&self) -> usize {
        self.idx.len() * (4 + 4)
    }

    /// Split into per-shard sparse gradients with indices rebased to each
    /// shard's local coordinate space — what a compressed submission to the
    /// sharded parameter server fans out as. Indices are sorted, so this is
    /// a single linear scan. Like the codecs themselves (see module docs),
    /// this is exercised by tests/ablations, not the default dense
    /// `Arc`-fan-out pipeline.
    pub fn split_shards(&self, layout: &crate::coordinator::shard::ShardLayout) -> Vec<SparseGrad> {
        assert_eq!(self.dim, layout.dim());
        let mut out: Vec<SparseGrad> = layout
            .ranges()
            .map(|r| SparseGrad {
                dim: r.len(),
                idx: Vec::new(),
                val: Vec::new(),
            })
            .collect();
        let mut shard = 0usize;
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            while !layout.range(shard).contains(&(i as usize)) {
                shard += 1;
            }
            out[shard].idx.push(i - layout.range(shard).start as u32);
            out[shard].val.push(v);
        }
        out
    }
}

/// Top-k sparsifier with error feedback. One instance per worker.
pub struct TopKCompressor {
    k: usize,
    /// Accumulated residual (error feedback). Public for diagnostics/tests.
    pub residual: Vec<f32>,
    /// Scratch for selection.
    scratch: Vec<(f32, u32)>,
}

impl TopKCompressor {
    pub fn new(dim: usize, k: usize) -> Self {
        assert!(k >= 1);
        TopKCompressor {
            k: k.min(dim),
            residual: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
        }
    }

    /// Compress `grad + residual`, keeping the top-k magnitudes; the rest
    /// feeds back into the residual.
    pub fn compress(&mut self, grad: &[f32]) -> SparseGrad {
        assert_eq!(grad.len(), self.residual.len());
        self.scratch.clear();
        for (i, (&g, r)) in grad.iter().zip(self.residual.iter()).enumerate() {
            self.scratch.push((g + r, i as u32));
        }
        // partial selection by |value|
        let k = self.k;
        self.scratch
            .select_nth_unstable_by(k - 1, |a, b| b.0.abs().partial_cmp(&a.0.abs()).unwrap());
        let mut idx: Vec<u32> = self.scratch[..k].iter().map(|&(_, i)| i).collect();
        let mut pairs: Vec<(u32, f32)> = self.scratch[..k]
            .iter()
            .map(|&(v, i)| (i, v))
            .collect();
        pairs.sort_unstable_by_key(|&(i, _)| i);
        idx.sort_unstable();
        let val: Vec<f32> = pairs.iter().map(|&(_, v)| v).collect();
        // update residual: transmitted coords reset, others accumulate
        let mut transmitted = vec![false; self.residual.len()];
        for &i in &idx {
            transmitted[i as usize] = true;
        }
        for (i, r) in self.residual.iter_mut().enumerate() {
            if transmitted[i] {
                *r = 0.0;
            } else {
                *r += grad[i];
            }
        }
        SparseGrad {
            dim: grad.len(),
            idx,
            val,
        }
    }

    /// Residual L1 mass (diagnostics).
    pub fn residual_l1(&self) -> f64 {
        self.residual.iter().map(|&r| r.abs() as f64).sum()
    }
}

/// Int8 linearly-quantized gradient.
#[derive(Clone, Debug)]
pub struct QuantGrad {
    pub scale: f32,
    pub data: Vec<i8>,
}

impl QuantGrad {
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + 4
    }
}

/// Quantize to int8 with a per-tensor max-abs scale.
pub fn quantize_i8(grad: &[f32]) -> QuantGrad {
    let maxabs = grad.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if maxabs == 0.0 { 1.0 } else { maxabs / 127.0 };
    let data = grad
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    QuantGrad { scale, data }
}

/// Dequantize back to f32.
pub fn dequantize_i8(q: &QuantGrad) -> Vec<f32> {
    q.data.iter().map(|&b| b as f32 * q.scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn topk_keeps_largest() {
        let mut c = TopKCompressor::new(6, 2);
        let g = [0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let s = c.compress(&g);
        assert_eq!(s.idx, vec![1, 3]);
        assert_eq!(s.val, vec![-5.0, 3.0]);
        let dense = s.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[0], 0.0);
    }

    #[test]
    fn error_feedback_preserves_mass() {
        // Repeatedly compressing the same gradient must eventually transmit
        // every coordinate's accumulated value: sum of transmissions ≈ sum
        // of inputs per coordinate.
        let dim = 8;
        let mut c = TopKCompressor::new(dim, 2);
        let g: Vec<f32> = (0..dim).map(|i| (i as f32 + 1.0) * 0.1).collect();
        let rounds = 40;
        let mut transmitted = vec![0.0f64; dim];
        for _ in 0..rounds {
            let s = c.compress(&g);
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                transmitted[i as usize] += v as f64;
            }
        }
        // exact conservation: transmitted + residual == injected, per coord
        for (i, &t) in transmitted.iter().enumerate() {
            let want = g[i] as f64 * rounds as f64;
            let got = t + c.residual[i] as f64;
            assert!(
                (got - want).abs() < 1e-3 * want.max(1.0),
                "coord {i}: transmitted+residual {got:.3} vs injected {want:.3}"
            );
        }
    }

    #[test]
    fn topk_residual_bounded_on_random_stream() {
        let mut rng = Pcg64::seeded(4);
        let dim = 100;
        let mut c = TopKCompressor::new(dim, 10);
        for _ in 0..200 {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            let _ = c.compress(&g);
        }
        // residual should not blow up (error feedback drains it)
        assert!(c.residual_l1() < dim as f64 * 5.0, "residual {}", c.residual_l1());
    }

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Pcg64::seeded(5);
        let mut g = vec![0.0f32; 1000];
        rng.fill_normal(&mut g, 2.0);
        let q = quantize_i8(&g);
        let back = dequantize_i8(&q);
        let maxabs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let step = maxabs / 127.0;
        for (a, b) in g.iter().zip(&back) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
        assert_eq!(q.payload_bytes(), 1004);
    }

    #[test]
    fn quant_handles_zeros_and_extremes() {
        let q = quantize_i8(&[0.0, 0.0]);
        assert_eq!(dequantize_i8(&q), vec![0.0, 0.0]);
        let q = quantize_i8(&[127.0, -127.0, 1.0]);
        let b = dequantize_i8(&q);
        assert!((b[0] - 127.0).abs() < 1.0);
        assert!((b[1] + 127.0).abs() < 1.0);
    }

    #[test]
    fn split_shards_partitions_and_rebases() {
        use crate::coordinator::shard::ShardLayout;
        let s = SparseGrad {
            dim: 10,
            idx: vec![0, 3, 4, 7, 9],
            val: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        let layout = ShardLayout::new(10, 3); // ranges 0..4, 4..7, 7..10
        let parts = s.split_shards(&layout);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].idx, vec![0, 3]);
        assert_eq!(parts[0].val, vec![1.0, 2.0]);
        assert_eq!(parts[1].idx, vec![0]);
        assert_eq!(parts[1].val, vec![3.0]);
        assert_eq!(parts[2].idx, vec![0, 2]);
        assert_eq!(parts[2].val, vec![4.0, 5.0]);
        // Dense reconstruction of the parts matches slicing the dense grad.
        let dense = s.to_dense();
        for (p, r) in parts.iter().zip(layout.ranges()) {
            assert_eq!(p.to_dense(), dense[r]);
        }
    }

    #[test]
    fn sparse_payload_smaller_than_dense() {
        let mut c = TopKCompressor::new(10_000, 100);
        let mut rng = Pcg64::seeded(6);
        let mut g = vec![0.0f32; 10_000];
        rng.fill_normal(&mut g, 1.0);
        let s = c.compress(&g);
        assert_eq!(s.payload_bytes(), 100 * 8);
        assert!(s.payload_bytes() < 10_000 * 4 / 10);
    }
}
