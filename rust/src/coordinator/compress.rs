//! Gradient compression codecs and the selectable **wire format** layer.
//!
//! The paper's related-work survey credits gradient compression as a
//! scalability lever: in any real deployment of the hybrid scheme the
//! dominant cost is gradient *communication*, not the SGD apply. This
//! module makes compression a first-class wire format threaded through the
//! whole pipeline (workers encode, shard servers consume encoded views,
//! the simulator accounts bytes-on-wire):
//!
//! - **`dense`** — raw f32, the default; bitwise-identical to the
//!   uncompressed pipeline.
//! - **`topk:<k|frac>`** — top-k sparsification with error feedback: only
//!   the k largest-magnitude coordinates are transmitted; the residual is
//!   accumulated locally and added to the next gradient (the standard
//!   convergence-preserving trick). `topk:100` keeps 100 coordinates,
//!   `topk:0.01` keeps 1% of the dimension.
//! - **`int8`** — per-tensor max-abs linear quantization, 4× smaller
//!   payloads.
//! - **`topk+int8:<k|frac>`** — both: sparse indices with int8 values
//!   (5 bytes per coordinate instead of 8).
//!
//! Hot-path contract: [`TopKCompressor::compress_into`] and
//! [`GradEncoder::encode`] are **allocation-free in steady state** — every
//! buffer (selection scratch, sparse index/value vectors, per-shard payload
//! splits) is owned by the compressor/encoder and recycled round-trip, the
//! same discipline as [`super::params::ParamStore::publish`]. Selection
//! uses a *total order* (|value| descending, index ascending on ties), so
//! compressed runs are deterministic across platforms and never panic on
//! NaN gradients.

use super::shard::ShardLayout;
use std::cmp::Ordering;
use std::ops::Range;
use std::sync::Arc;

/// How worker→server gradient traffic is encoded on the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum WireFormat {
    /// Raw f32 (4 bytes/coordinate). The default; golden-trace identical
    /// to the pre-wire-format pipeline.
    Dense,
    /// Top-k sparsification with error feedback (8 bytes/kept coordinate).
    TopK(KSpec),
    /// Int8 linear quantization (1 byte/coordinate + 4-byte scale).
    Int8,
    /// Top-k then int8 values (5 bytes/kept coordinate + 4-byte scale).
    TopKInt8(KSpec),
}

/// Top-k size: an absolute coordinate count or a fraction of the dimension.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KSpec {
    Count(usize),
    Frac(f64),
}

impl KSpec {
    fn parse(s: &str) -> anyhow::Result<KSpec> {
        if s.contains('.') {
            let f: f64 = s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad top-k fraction `{s}`"))?;
            anyhow::ensure!(
                f > 0.0 && f < 1.0 && f.is_finite(),
                "top-k fraction `{s}` must be in (0, 1)"
            );
            Ok(KSpec::Frac(f))
        } else {
            let n: usize = s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad top-k count `{s}`"))?;
            Ok(KSpec::Count(n))
        }
    }

    /// Concrete k for a gradient of `dim` coordinates, clamped to
    /// `[1, dim]` so degenerate specs (`topk:0`, `topk:10_000_000`) never
    /// underflow or overrun the selection.
    pub fn resolve(&self, dim: usize) -> usize {
        let k = match *self {
            KSpec::Count(n) => n,
            KSpec::Frac(f) => (f * dim as f64).round() as usize,
        };
        k.clamp(1, dim.max(1))
    }
}

impl std::fmt::Display for KSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KSpec::Count(n) => write!(f, "{n}"),
            KSpec::Frac(fr) => write!(f, "{fr}"),
        }
    }
}

impl WireFormat {
    /// Parse CLI/DSL syntax: `dense | topk:<k|frac> | int8 | topk+int8:<k|frac>`.
    pub fn parse(s: &str) -> anyhow::Result<WireFormat> {
        if s == "dense" {
            return Ok(WireFormat::Dense);
        }
        if s == "int8" {
            return Ok(WireFormat::Int8);
        }
        if let Some(rest) = s.strip_prefix("topk+int8:") {
            return Ok(WireFormat::TopKInt8(KSpec::parse(rest)?));
        }
        if let Some(rest) = s.strip_prefix("topk:") {
            return Ok(WireFormat::TopK(KSpec::parse(rest)?));
        }
        anyhow::bail!(
            "unknown wire format `{s}` (dense | topk:<k|frac> | int8 | topk+int8:<k|frac>)"
        )
    }

    pub fn is_dense(&self) -> bool {
        *self == WireFormat::Dense
    }
}

impl std::fmt::Display for WireFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireFormat::Dense => write!(f, "dense"),
            WireFormat::TopK(k) => write!(f, "topk:{k}"),
            WireFormat::Int8 => write!(f, "int8"),
            WireFormat::TopKInt8(k) => write!(f, "topk+int8:{k}"),
        }
    }
}

/// A sparse gradient: sorted coordinate/value pairs.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGrad {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseGrad {
    /// An empty sparse gradient of the given dimension.
    pub fn with_dim(dim: usize) -> SparseGrad {
        SparseGrad {
            dim,
            idx: Vec::new(),
            val: Vec::new(),
        }
    }

    /// Dense reconstruction (zeros elsewhere).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// Payload size in bytes (index + value per entry).
    pub fn payload_bytes(&self) -> usize {
        self.idx.len() * (4 + 4)
    }

    /// Split into per-shard sparse gradients with indices rebased to each
    /// shard's local coordinate space — what a compressed submission to the
    /// sharded parameter server fans out as. Indices are sorted, so this is
    /// a single linear scan.
    pub fn split_shards(&self, layout: &ShardLayout) -> Vec<SparseGrad> {
        let mut out: Vec<SparseGrad> = (0..layout.shards())
            .map(|_| SparseGrad::with_dim(0))
            .collect();
        self.split_shards_into(layout, &mut out);
        out
    }

    /// [`SparseGrad::split_shards`] into caller-owned buffers (index/value
    /// vectors are cleared and refilled, never reallocated in steady state).
    pub fn split_shards_into(&self, layout: &ShardLayout, out: &mut [SparseGrad]) {
        assert_eq!(self.dim, layout.dim());
        assert_eq!(out.len(), layout.shards());
        for (part, r) in out.iter_mut().zip(layout.ranges()) {
            part.dim = r.len();
            part.idx.clear();
            part.val.clear();
        }
        let mut shard = 0usize;
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            while !layout.range(shard).contains(&(i as usize)) {
                shard += 1;
            }
            out[shard].idx.push(i - layout.range(shard).start as u32);
            out[shard].val.push(v);
        }
    }
}

/// Int8 linearly-quantized gradient.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantGrad {
    pub scale: f32,
    pub data: Vec<i8>,
}

impl QuantGrad {
    /// An empty quantized gradient (fill with [`quantize_i8_into`]).
    pub fn empty() -> QuantGrad {
        QuantGrad {
            scale: 1.0,
            data: Vec::new(),
        }
    }

    pub fn payload_bytes(&self) -> usize {
        self.data.len() + 4
    }
}

/// Top-k sparse gradient with int8-quantized values (shard-local indices
/// when produced by the encoder's per-shard split).
#[derive(Clone, Debug, PartialEq)]
pub struct SparseQuantGrad {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub scale: f32,
    pub data: Vec<i8>,
}

impl SparseQuantGrad {
    pub fn with_dim(dim: usize) -> SparseQuantGrad {
        SparseQuantGrad {
            dim,
            idx: Vec::new(),
            scale: 1.0,
            data: Vec::new(),
        }
    }

    /// Dense f32 reconstruction (zeros elsewhere).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        for (&i, &b) in self.idx.iter().zip(&self.data) {
            out[i as usize] = b as f32 * self.scale;
        }
        out
    }

    /// Payload size in bytes (u32 index + i8 value per entry + scale).
    pub fn payload_bytes(&self) -> usize {
        self.idx.len() * (4 + 1) + 4
    }
}

/// Top-k sparsifier with error feedback. One instance per worker.
pub struct TopKCompressor {
    k: usize,
    /// Accumulated residual (error feedback). Public for diagnostics/tests.
    pub residual: Vec<f32>,
    /// Scratch for selection (recycled; never reallocated in steady state).
    scratch: Vec<(f32, u32)>,
}

impl TopKCompressor {
    /// `k` is clamped to `[1, dim]` — `k = 0` and `k ≥ dim` are valid
    /// inputs (the latter degenerates to a dense-as-sparse transmission).
    pub fn new(dim: usize, k: usize) -> Self {
        TopKCompressor {
            k: k.clamp(1, dim.max(1)),
            residual: vec![0.0; dim],
            scratch: Vec::with_capacity(dim),
        }
    }

    /// Effective (clamped) k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Total-order ranking: |value| descending, index ascending on ties.
    /// `total_cmp` makes NaN gradients rank deterministically (largest)
    /// instead of panicking, and the index tie-break keeps compressed runs
    /// bitwise-reproducible across platforms and sort implementations.
    fn rank(a: &(f32, u32), b: &(f32, u32)) -> Ordering {
        b.0.abs()
            .total_cmp(&a.0.abs())
            .then_with(|| a.1.cmp(&b.1))
    }

    /// Compress `grad + residual`, keeping the top-k magnitudes; the rest
    /// feeds back into the residual. Writes into `out`, reusing its
    /// buffers — zero allocations once capacities are warm.
    pub fn compress_into(&mut self, grad: &[f32], out: &mut SparseGrad) {
        assert_eq!(grad.len(), self.residual.len());
        let dim = grad.len();
        out.dim = dim;
        out.idx.clear();
        out.val.clear();
        if dim == 0 {
            return;
        }
        let k = self.k.min(dim);
        self.scratch.clear();
        for (i, (&g, &r)) in grad.iter().zip(self.residual.iter()).enumerate() {
            self.scratch.push((g + r, i as u32));
        }
        // Partial selection by the total-order rank; skip when everything
        // is transmitted (k = dim would index one past the partition).
        if k < dim {
            self.scratch.select_nth_unstable_by(k - 1, Self::rank);
        }
        self.scratch[..k].sort_unstable_by_key(|&(_, i)| i);
        out.idx.extend(self.scratch[..k].iter().map(|&(_, i)| i));
        out.val.extend(self.scratch[..k].iter().map(|&(v, _)| v));
        // Error feedback: accumulate the whole gradient, then zero the
        // transmitted coordinates — identical to the mask formulation
        // (transmitted → 0, rest → r + g) without the O(dim) mask buffer.
        for (r, &g) in self.residual.iter_mut().zip(grad) {
            *r += g;
        }
        for &i in &out.idx {
            self.residual[i as usize] = 0.0;
        }
    }

    /// Allocating convenience wrapper around [`TopKCompressor::compress_into`].
    pub fn compress(&mut self, grad: &[f32]) -> SparseGrad {
        let mut out = SparseGrad::with_dim(grad.len());
        self.compress_into(grad, &mut out);
        out
    }

    /// Residual L1 mass (diagnostics).
    pub fn residual_l1(&self) -> f64 {
        self.residual.iter().map(|&r| r.abs() as f64).sum()
    }
}

/// Per-tensor quantization scale for a max-abs of `maxabs`.
fn i8_scale(maxabs: f32) -> f32 {
    if maxabs == 0.0 {
        1.0
    } else {
        maxabs / 127.0
    }
}

/// One value through the int8 quantizer (shared by every int8 format).
fn quantize_val(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Quantize to int8 with a per-tensor max-abs scale, reusing `out`'s buffer.
pub fn quantize_i8_into(grad: &[f32], out: &mut QuantGrad) {
    let maxabs = grad.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = i8_scale(maxabs);
    out.scale = scale;
    out.data.clear();
    out.data.extend(grad.iter().map(|&v| quantize_val(v, scale)));
}

/// Allocating convenience wrapper around [`quantize_i8_into`].
pub fn quantize_i8(grad: &[f32]) -> QuantGrad {
    let mut out = QuantGrad::empty();
    quantize_i8_into(grad, &mut out);
    out
}

/// Dequantize back to f32.
pub fn dequantize_i8(q: &QuantGrad) -> Vec<f32> {
    q.data.iter().map(|&b| b as f32 * q.scale).collect()
}

/// One shard's portion of an encoded gradient submission — what travels on
/// a shard channel (or through a simulator delivery event). Full-dimension
/// formats (dense, int8) ship one shared buffer and every shard reads its
/// slice; sparse formats are pre-split per shard with local indices.
#[derive(Clone, Debug)]
pub enum ShardGrad {
    /// Full-dim dense buffer shared across all shard messages of one
    /// submission (`Arc` fan-out, as the uncompressed pipeline always did).
    Dense(Arc<Vec<f32>>),
    /// Shard-local sparse coordinates (rebased by `split_shards`).
    Sparse(Arc<SparseGrad>),
    /// Full-dim int8 buffer shared across shards + per-tensor scale.
    Quant(Arc<QuantGrad>),
    /// Shard-local sparse coordinates with int8 values.
    SparseQuant(Arc<SparseQuantGrad>),
    /// Shard-local dense slice (already cut to one shard's coordinates).
    /// Produced by the network transport's decoder — a remote worker sends
    /// each shard only its slice, so there is no full-dim buffer to share.
    DenseLocal(Arc<Vec<f32>>),
    /// Shard-local int8 slice + per-tensor scale (transport decode path).
    QuantLocal(Arc<QuantGrad>),
}

impl ShardGrad {
    /// Borrow this payload as the shard's [`GradView`]. `range` is the
    /// shard's slice of the flat θ; shared full-dim payloads are sliced by
    /// it, pre-split sparse payloads already live in shard coordinates.
    pub fn view(&self, range: Range<usize>) -> GradView<'_> {
        match self {
            ShardGrad::Dense(g) => GradView::Dense(&g[range]),
            ShardGrad::Sparse(s) => {
                debug_assert_eq!(s.dim, range.len());
                GradView::Sparse {
                    idx: &s.idx,
                    val: &s.val,
                }
            }
            ShardGrad::Quant(q) => GradView::Quant {
                scale: q.scale,
                data: &q.data[range],
            },
            ShardGrad::SparseQuant(s) => {
                debug_assert_eq!(s.dim, range.len());
                GradView::SparseQuant {
                    idx: &s.idx,
                    scale: s.scale,
                    data: &s.data,
                }
            }
            ShardGrad::DenseLocal(g) => {
                debug_assert_eq!(g.len(), range.len());
                GradView::Dense(&g[..])
            }
            ShardGrad::QuantLocal(q) => {
                debug_assert_eq!(q.data.len(), range.len());
                GradView::Quant {
                    scale: q.scale,
                    data: &q.data[..],
                }
            }
        }
    }

    /// Whether **every** f32 the payload carries is finite. Checked over
    /// the *whole* payload (not one shard's slice), so under a shared
    /// full-dim buffer all shards reach the same verdict — rejecting a
    /// poisoned submission everywhere or nowhere, which preserves the
    /// lockstep invariant for count-triggered policies. Int8 data is
    /// finite by construction; only the dequantization scale can be NaN
    /// or ±Inf.
    pub fn is_finite(&self) -> bool {
        match self {
            ShardGrad::Dense(g) | ShardGrad::DenseLocal(g) => {
                g.iter().all(|v| v.is_finite())
            }
            ShardGrad::Sparse(s) => s.val.iter().all(|v| v.is_finite()),
            ShardGrad::Quant(q) | ShardGrad::QuantLocal(q) => q.scale.is_finite(),
            ShardGrad::SparseQuant(s) => s.scale.is_finite(),
        }
    }

    /// Bytes-on-wire attributable to one shard delivery of this payload.
    /// Shared full-dim payloads charge the shard its slice (`shard_len`
    /// coordinates); pre-split payloads charge their own entries.
    pub fn wire_bytes(&self, shard_len: usize) -> usize {
        match self {
            ShardGrad::Dense(_) => shard_len * 4,
            ShardGrad::Sparse(s) => s.idx.len() * (4 + 4),
            ShardGrad::Quant(_) => shard_len + 4,
            ShardGrad::SparseQuant(s) => s.idx.len() * (4 + 1) + 4,
            ShardGrad::DenseLocal(g) => g.len() * 4,
            ShardGrad::QuantLocal(q) => q.data.len() + 4,
        }
    }
}

/// Total bytes-on-wire of one submission's per-shard payloads.
pub fn submission_bytes(payloads: &[ShardGrad], layout: &ShardLayout) -> u64 {
    debug_assert_eq!(payloads.len(), layout.shards());
    payloads
        .iter()
        .enumerate()
        .map(|(s, p)| p.wire_bytes(layout.range(s).len()) as u64)
        .sum()
}

/// Worker-side wire encoder: owns the error-feedback state and **every**
/// buffer the encode path touches, so steady-state encoding performs zero
/// allocations. Payload buffers are recycled round-trip: each `encode`
/// first reclaims the previous round's buffers via `Arc::try_unwrap` (the
/// shard protocol guarantees consumers drop their clones before the worker
/// encodes again; a lost race just falls back to a fresh allocation, as in
/// the dense pipeline's spare-buffer recycling).
pub struct GradEncoder {
    wire: WireFormat,
    topk: Option<TopKCompressor>,
    /// Resolved k (0 for formats without sparsification); every per-shard
    /// sparse buffer is pre-reserved to this capacity so round-to-round
    /// nnz variation per shard never triggers a regrow.
    k: usize,
    /// Full-dim compressed gradient, scratch between compress and split.
    full_sparse: SparseGrad,
    /// Per-shard split scratch (drained into payload `Arc`s each round).
    parts: Vec<SparseGrad>,
    /// Payload `Arc`s retained from the previous round for recycling.
    inflight: Vec<ShardGrad>,
    spare_dense: Option<Vec<f32>>,
    spare_quant: Option<QuantGrad>,
    spare_sparse: Vec<SparseGrad>,
    spare_sq: Vec<SparseQuantGrad>,
}

impl GradEncoder {
    pub fn new(wire: WireFormat, dim: usize, shards: usize) -> GradEncoder {
        let (topk, k) = match &wire {
            WireFormat::TopK(spec) | WireFormat::TopKInt8(spec) => {
                let k = spec.resolve(dim);
                (Some(TopKCompressor::new(dim, k)), k)
            }
            _ => (None, 0),
        };
        let mut full_sparse = SparseGrad::with_dim(dim);
        full_sparse.idx.reserve(k);
        full_sparse.val.reserve(k);
        GradEncoder {
            wire,
            topk,
            k,
            full_sparse,
            parts: Vec::with_capacity(shards),
            inflight: Vec::with_capacity(shards),
            spare_dense: None,
            spare_quant: None,
            spare_sparse: Vec::with_capacity(shards),
            spare_sq: Vec::with_capacity(shards),
        }
    }

    /// A fresh pool entry sized so no later round can regrow it.
    fn fresh_sparse(&self) -> SparseGrad {
        let mut sg = SparseGrad::with_dim(0);
        sg.idx.reserve(self.k);
        sg.val.reserve(self.k);
        sg
    }

    fn fresh_sq(&self) -> SparseQuantGrad {
        let mut sq = SparseQuantGrad::with_dim(0);
        sq.idx.reserve(self.k);
        sq.data.reserve(self.k);
        sq
    }

    pub fn wire(&self) -> &WireFormat {
        &self.wire
    }

    /// Error-feedback residual L1 mass (None for formats without feedback).
    pub fn residual_l1(&self) -> Option<f64> {
        self.topk.as_ref().map(|c| c.residual_l1())
    }

    /// Reclaim last round's payload buffers whose consumers are done.
    fn reclaim(&mut self) {
        for p in self.inflight.drain(..) {
            match p {
                ShardGrad::Dense(a) => {
                    if let Ok(v) = Arc::try_unwrap(a) {
                        self.spare_dense = Some(v);
                    }
                }
                ShardGrad::Sparse(a) => {
                    if let Ok(sg) = Arc::try_unwrap(a) {
                        self.spare_sparse.push(sg);
                    }
                }
                ShardGrad::Quant(a) => {
                    if let Ok(q) = Arc::try_unwrap(a) {
                        self.spare_quant = Some(q);
                    }
                }
                ShardGrad::SparseQuant(a) => {
                    if let Ok(sq) = Arc::try_unwrap(a) {
                        self.spare_sq.push(sq);
                    }
                }
                // Never produced by this encoder (transport decode path),
                // but recycle them anyway if one is handed back.
                ShardGrad::DenseLocal(a) => {
                    if let Ok(v) = Arc::try_unwrap(a) {
                        self.spare_dense = Some(v);
                    }
                }
                ShardGrad::QuantLocal(a) => {
                    if let Ok(q) = Arc::try_unwrap(a) {
                        self.spare_quant = Some(q);
                    }
                }
            }
        }
    }

    /// Encode one full-dim gradient into per-shard payloads (one entry per
    /// shard, in shard order, replacing `out`'s contents). Clears `out`
    /// *before* reclaiming so the caller's clones from the previous round
    /// don't defeat buffer recycling.
    pub fn encode(&mut self, grad: &[f32], layout: &ShardLayout, out: &mut Vec<ShardGrad>) {
        out.clear();
        self.reclaim();
        let shards = layout.shards();
        match self.wire {
            WireFormat::Dense => {
                let mut buf = self.spare_dense.take().unwrap_or_default();
                buf.clear();
                buf.extend_from_slice(grad);
                let arc = Arc::new(buf);
                for _ in 0..shards {
                    out.push(ShardGrad::Dense(Arc::clone(&arc)));
                }
                self.inflight.push(ShardGrad::Dense(arc));
            }
            WireFormat::Int8 => {
                let mut q = self.spare_quant.take().unwrap_or_else(QuantGrad::empty);
                quantize_i8_into(grad, &mut q);
                let arc = Arc::new(q);
                for _ in 0..shards {
                    out.push(ShardGrad::Quant(Arc::clone(&arc)));
                }
                self.inflight.push(ShardGrad::Quant(arc));
            }
            WireFormat::TopK(_) => {
                let comp = self.topk.as_mut().expect("top-k state");
                comp.compress_into(grad, &mut self.full_sparse);
                self.parts.clear();
                for _ in 0..shards {
                    let sg = match self.spare_sparse.pop() {
                        Some(sg) => sg,
                        None => self.fresh_sparse(),
                    };
                    self.parts.push(sg);
                }
                self.full_sparse.split_shards_into(layout, &mut self.parts);
                for part in self.parts.drain(..) {
                    let arc = Arc::new(part);
                    out.push(ShardGrad::Sparse(Arc::clone(&arc)));
                    self.inflight.push(ShardGrad::Sparse(arc));
                }
            }
            WireFormat::TopKInt8(_) => {
                let comp = self.topk.as_mut().expect("top-k state");
                comp.compress_into(grad, &mut self.full_sparse);
                // One scale over the transmitted values (per-tensor scale,
                // shared by every shard's payload).
                let maxabs = self
                    .full_sparse
                    .val
                    .iter()
                    .fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = i8_scale(maxabs);
                self.parts.clear();
                for _ in 0..shards {
                    let sg = match self.spare_sparse.pop() {
                        Some(sg) => sg,
                        None => self.fresh_sparse(),
                    };
                    self.parts.push(sg);
                }
                self.full_sparse.split_shards_into(layout, &mut self.parts);
                for part in self.parts.iter() {
                    let mut sq = match self.spare_sq.pop() {
                        Some(sq) => sq,
                        None => self.fresh_sq(),
                    };
                    sq.dim = part.dim;
                    sq.scale = scale;
                    sq.idx.clear();
                    sq.idx.extend_from_slice(&part.idx);
                    sq.data.clear();
                    sq.data
                        .extend(part.val.iter().map(|&v| quantize_val(v, scale)));
                    let arc = Arc::new(sq);
                    out.push(ShardGrad::SparseQuant(Arc::clone(&arc)));
                    self.inflight.push(ShardGrad::SparseQuant(arc));
                }
                // The f32 split parts were only scratch: straight back to
                // the pool.
                self.spare_sparse.append(&mut self.parts);
            }
        }
    }
}

/// A borrowed view of one shard's slice of a gradient submission, in
/// whatever wire format it arrived. The pure aggregation state machines
/// ([`super::policy::Aggregator`], [`super::buffer::GradientBuffer`],
/// [`super::params::ParamStore`]) consume views, so sparse submissions are
/// scatter-added in O(nnz) and int8 ones dequantized on the fly — nothing
/// densifies a payload before the flush.
#[derive(Clone, Copy, Debug)]
pub enum GradView<'a> {
    Dense(&'a [f32]),
    Sparse {
        idx: &'a [u32],
        val: &'a [f32],
    },
    Quant {
        scale: f32,
        data: &'a [i8],
    },
    SparseQuant {
        idx: &'a [u32],
        scale: f32,
        data: &'a [i8],
    },
}

impl GradView<'_> {
    /// Coordinates carried (dense length or nnz).
    pub fn nnz(&self) -> usize {
        match self {
            GradView::Dense(g) => g.len(),
            GradView::Sparse { idx, .. } => idx.len(),
            GradView::Quant { data, .. } => data.len(),
            GradView::SparseQuant { idx, .. } => idx.len(),
        }
    }

    /// Scatter-add into a dense accumulator of the shard dimension. The
    /// dense arm is the exact summing loop the buffer always ran (bitwise
    /// identity for `compress=dense`); sparse arms touch only their nnz.
    pub fn add_to(&self, sum: &mut [f32]) {
        match *self {
            GradView::Dense(g) => {
                debug_assert_eq!(g.len(), sum.len());
                for (s, &g) in sum.iter_mut().zip(g) {
                    *s += g;
                }
            }
            GradView::Sparse { idx, val } => {
                for (&i, &v) in idx.iter().zip(val) {
                    sum[i as usize] += v;
                }
            }
            GradView::Quant { scale, data } => {
                debug_assert_eq!(data.len(), sum.len());
                for (s, &b) in sum.iter_mut().zip(data) {
                    *s += b as f32 * scale;
                }
            }
            GradView::SparseQuant { idx, scale, data } => {
                for (&i, &b) in idx.iter().zip(data) {
                    sum[i as usize] += b as f32 * scale;
                }
            }
        }
    }

    /// [`GradView::add_to`] with every accumulated value scaled by
    /// `factor` — the norm-clipping accumulation (DESIGN.md §2.10). Works
    /// per carried entry, so sparse/int8 payloads stay undensified.
    pub fn add_scaled_to(&self, sum: &mut [f32], factor: f32) {
        match *self {
            GradView::Dense(g) => {
                debug_assert_eq!(g.len(), sum.len());
                for (s, &g) in sum.iter_mut().zip(g) {
                    *s += factor * g;
                }
            }
            GradView::Sparse { idx, val } => {
                for (&i, &v) in idx.iter().zip(val) {
                    sum[i as usize] += factor * v;
                }
            }
            GradView::Quant { scale, data } => {
                debug_assert_eq!(data.len(), sum.len());
                for (s, &b) in sum.iter_mut().zip(data) {
                    *s += factor * (b as f32 * scale);
                }
            }
            GradView::SparseQuant { idx, scale, data } => {
                for (&i, &b) in idx.iter().zip(data) {
                    sum[i as usize] += factor * (b as f32 * scale);
                }
            }
        }
    }

    /// Squared L2 norm of the carried values (f64 accumulation; O(nnz) for
    /// sparse arms, dequantizing on the fly for int8 arms). For a shared
    /// full-dim payload this is the *shard slice's* norm — each shard clips
    /// its slice independently, which every shard computes identically
    /// (lockstep-safe) and bounds the full-vector norm by `c·√S`.
    pub fn sq_norm(&self) -> f64 {
        match *self {
            GradView::Dense(g) => g.iter().map(|&v| v as f64 * v as f64).sum(),
            GradView::Sparse { val, .. } => {
                val.iter().map(|&v| v as f64 * v as f64).sum()
            }
            GradView::Quant { scale, data } => data
                .iter()
                .map(|&b| {
                    let v = b as f32 * scale;
                    v as f64 * v as f64
                })
                .sum(),
            GradView::SparseQuant { scale, data, .. } => data
                .iter()
                .map(|&b| {
                    let v = b as f32 * scale;
                    v as f64 * v as f64
                })
                .sum(),
        }
    }

    /// Apply as a single SGD step: θ[i] ← θ[i] − lr · g[i] (the
    /// asynchronous application; O(nnz) for sparse arms).
    pub fn apply_to(&self, theta: &mut [f32], lr: f32) {
        match *self {
            GradView::Dense(g) => {
                debug_assert_eq!(g.len(), theta.len());
                for (t, &g) in theta.iter_mut().zip(g) {
                    *t -= lr * g;
                }
            }
            GradView::Sparse { idx, val } => {
                for (&i, &v) in idx.iter().zip(val) {
                    theta[i as usize] -= lr * v;
                }
            }
            GradView::Quant { scale, data } => {
                debug_assert_eq!(data.len(), theta.len());
                for (t, &b) in theta.iter_mut().zip(data) {
                    *t -= lr * (b as f32 * scale);
                }
            }
            GradView::SparseQuant { idx, scale, data } => {
                for (&i, &b) in idx.iter().zip(data) {
                    theta[i as usize] -= lr * (b as f32 * scale);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn topk_keeps_largest() {
        let mut c = TopKCompressor::new(6, 2);
        let g = [0.1, -5.0, 0.2, 3.0, -0.05, 0.0];
        let s = c.compress(&g);
        assert_eq!(s.idx, vec![1, 3]);
        assert_eq!(s.val, vec![-5.0, 3.0]);
        let dense = s.to_dense();
        assert_eq!(dense[1], -5.0);
        assert_eq!(dense[0], 0.0);
    }

    #[test]
    fn error_feedback_preserves_mass() {
        // Repeatedly compressing the same gradient must eventually transmit
        // every coordinate's accumulated value: sum of transmissions ≈ sum
        // of inputs per coordinate.
        let dim = 8;
        let mut c = TopKCompressor::new(dim, 2);
        let g: Vec<f32> = (0..dim).map(|i| (i as f32 + 1.0) * 0.1).collect();
        let rounds = 40;
        let mut transmitted = vec![0.0f64; dim];
        for _ in 0..rounds {
            let s = c.compress(&g);
            for (&i, &v) in s.idx.iter().zip(&s.val) {
                transmitted[i as usize] += v as f64;
            }
        }
        // exact conservation: transmitted + residual == injected, per coord
        for (i, &t) in transmitted.iter().enumerate() {
            let want = g[i] as f64 * rounds as f64;
            let got = t + c.residual[i] as f64;
            assert!(
                (got - want).abs() < 1e-3 * want.max(1.0),
                "coord {i}: transmitted+residual {got:.3} vs injected {want:.3}"
            );
        }
    }

    #[test]
    fn topk_residual_bounded_on_random_stream() {
        let mut rng = Pcg64::seeded(4);
        let dim = 100;
        let mut c = TopKCompressor::new(dim, 10);
        for _ in 0..200 {
            let mut g = vec![0.0f32; dim];
            rng.fill_normal(&mut g, 1.0);
            let _ = c.compress(&g);
        }
        // residual should not blow up (error feedback drains it)
        assert!(c.residual_l1() < dim as f64 * 5.0, "residual {}", c.residual_l1());
    }

    #[test]
    fn topk_k_clamped_to_valid_range() {
        // k = 0 used to underflow (`k - 1`); now clamps to 1.
        let mut c = TopKCompressor::new(4, 0);
        assert_eq!(c.k(), 1);
        let s = c.compress(&[1.0, -2.0, 0.5, 0.0]);
        assert_eq!(s.idx, vec![1]);
        assert_eq!(s.val, vec![-2.0]);
        // k ≥ dim used to panic in select_nth_unstable_by; now transmits
        // everything (and the residual stays empty).
        let mut c = TopKCompressor::new(3, 99);
        assert_eq!(c.k(), 3);
        let s = c.compress(&[1.0, 2.0, 3.0]);
        assert_eq!(s.idx, vec![0, 1, 2]);
        assert_eq!(s.val, vec![1.0, 2.0, 3.0]);
        assert_eq!(c.residual_l1(), 0.0);
    }

    #[test]
    fn topk_nan_gradient_does_not_panic() {
        // `partial_cmp().unwrap()` used to panic on NaN; the total-order
        // comparator ranks NaN largest, deterministically.
        let mut c = TopKCompressor::new(4, 2);
        let s = c.compress(&[1.0, f32::NAN, 3.0, 0.5]);
        assert_eq!(s.idx.len(), 2);
        assert!(s.idx.contains(&1), "NaN coordinate ranks largest: {:?}", s.idx);
        // subsequent compressions keep working
        let s2 = c.compress(&[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(s2.idx.len(), 2);
    }

    #[test]
    fn topk_ties_break_by_lowest_index() {
        // All-equal magnitudes: selection must deterministically keep the
        // lowest indices (the bitwise-reproducibility contract).
        let mut c = TopKCompressor::new(6, 3);
        let s = c.compress(&[1.0, -1.0, 1.0, -1.0, 1.0, 1.0]);
        assert_eq!(s.idx, vec![0, 1, 2]);
        assert_eq!(s.val, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn compress_into_is_allocation_free_in_steady_state() {
        // The reuse contract: after a warm-up round, repeated compressions
        // never regrow the output or scratch buffers (same discipline as
        // `publish_recycles_buffers` in params.rs).
        let dim = 512;
        let mut rng = Pcg64::seeded(9);
        let mut g = vec![0.0f32; dim];
        let mut c = TopKCompressor::new(dim, 32);
        let mut out = SparseGrad::with_dim(dim);
        rng.fill_normal(&mut g, 1.0);
        c.compress_into(&g, &mut out);
        let idx_ptr = out.idx.as_ptr() as usize;
        let val_ptr = out.val.as_ptr() as usize;
        let (idx_cap, val_cap) = (out.idx.capacity(), out.val.capacity());
        for _ in 0..100 {
            rng.fill_normal(&mut g, 1.0);
            c.compress_into(&g, &mut out);
        }
        assert_eq!(out.idx.as_ptr() as usize, idx_ptr, "idx buffer reallocated");
        assert_eq!(out.val.as_ptr() as usize, val_ptr, "val buffer reallocated");
        assert_eq!(out.idx.capacity(), idx_cap);
        assert_eq!(out.val.capacity(), val_cap);
    }

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Pcg64::seeded(5);
        let mut g = vec![0.0f32; 1000];
        rng.fill_normal(&mut g, 2.0);
        let q = quantize_i8(&g);
        let back = dequantize_i8(&q);
        let maxabs = g.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let step = maxabs / 127.0;
        for (a, b) in g.iter().zip(&back) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6);
        }
        assert_eq!(q.payload_bytes(), 1004);
    }

    #[test]
    fn quant_handles_zeros_and_extremes() {
        let q = quantize_i8(&[0.0, 0.0]);
        assert_eq!(dequantize_i8(&q), vec![0.0, 0.0]);
        let q = quantize_i8(&[127.0, -127.0, 1.0]);
        let b = dequantize_i8(&q);
        assert!((b[0] - 127.0).abs() < 1.0);
        assert!((b[1] + 127.0).abs() < 1.0);
    }

    #[test]
    fn split_shards_partitions_and_rebases() {
        let s = SparseGrad {
            dim: 10,
            idx: vec![0, 3, 4, 7, 9],
            val: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        let layout = ShardLayout::new(10, 3); // ranges 0..4, 4..7, 7..10
        let parts = s.split_shards(&layout);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].idx, vec![0, 3]);
        assert_eq!(parts[0].val, vec![1.0, 2.0]);
        assert_eq!(parts[1].idx, vec![0]);
        assert_eq!(parts[1].val, vec![3.0]);
        assert_eq!(parts[2].idx, vec![0, 2]);
        assert_eq!(parts[2].val, vec![4.0, 5.0]);
        // Dense reconstruction of the parts matches slicing the dense grad.
        let dense = s.to_dense();
        for (p, r) in parts.iter().zip(layout.ranges()) {
            assert_eq!(p.to_dense(), dense[r]);
        }
        // The `_into` variant reuses buffers and produces the same split.
        let mut reused = vec![SparseGrad::with_dim(0); 3];
        s.split_shards_into(&layout, &mut reused);
        assert_eq!(reused, parts);
    }

    #[test]
    fn sparse_payload_smaller_than_dense() {
        let mut c = TopKCompressor::new(10_000, 100);
        let mut rng = Pcg64::seeded(6);
        let mut g = vec![0.0f32; 10_000];
        rng.fill_normal(&mut g, 1.0);
        let s = c.compress(&g);
        assert_eq!(s.payload_bytes(), 100 * 8);
        assert!(s.payload_bytes() < 10_000 * 4 / 10);
    }

    #[test]
    fn wire_format_parse_display_roundtrip() {
        for s in ["dense", "topk:100", "topk:0.01", "int8", "topk+int8:0.05", "topk+int8:64"] {
            let w = WireFormat::parse(s).unwrap();
            assert_eq!(WireFormat::parse(&w.to_string()).unwrap(), w, "`{s}`");
        }
        for bad in ["", "nope", "topk:", "topk:0.0", "topk:1.5", "topk:x", "int8:4", "topk+int8:"] {
            assert!(WireFormat::parse(bad).is_err(), "`{bad}` should not parse");
        }
        assert!(WireFormat::Dense.is_dense());
        assert!(!WireFormat::Int8.is_dense());
    }

    #[test]
    fn kspec_resolves_and_clamps() {
        assert_eq!(KSpec::Count(10).resolve(100), 10);
        assert_eq!(KSpec::Count(0).resolve(100), 1);
        assert_eq!(KSpec::Count(500).resolve(100), 100);
        assert_eq!(KSpec::Frac(0.01).resolve(1000), 10);
        assert_eq!(KSpec::Frac(0.01).resolve(10), 1);
    }

    #[test]
    fn views_accumulate_and_apply_consistently() {
        let dense = vec![1.0f32, 0.0, -2.0, 0.5];
        let sparse = SparseGrad {
            dim: 4,
            idx: vec![0, 2, 3],
            val: vec![1.0, -2.0, 0.5],
        };
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        GradView::Dense(&dense).add_to(&mut a);
        GradView::Sparse {
            idx: &sparse.idx,
            val: &sparse.val,
        }
        .add_to(&mut b);
        assert_eq!(a, b);
        let mut ta = vec![1.0f32; 4];
        let mut tb = vec![1.0f32; 4];
        GradView::Dense(&dense).apply_to(&mut ta, 0.1);
        GradView::Sparse {
            idx: &sparse.idx,
            val: &sparse.val,
        }
        .apply_to(&mut tb, 0.1);
        assert_eq!(ta, tb);
        // int8 views dequantize on the fly within quantization tolerance
        let q = quantize_i8(&dense);
        let mut c = vec![0.0f32; 4];
        GradView::Quant {
            scale: q.scale,
            data: &q.data,
        }
        .add_to(&mut c);
        let step = q.scale;
        for (x, y) in a.iter().zip(&c) {
            assert!((x - y).abs() <= step * 0.5 + 1e-6);
        }
        assert_eq!(GradView::Dense(&dense).nnz(), 4);
        assert_eq!(
            GradView::Sparse {
                idx: &sparse.idx,
                val: &sparse.val
            }
            .nnz(),
            3
        );
    }

    #[test]
    fn encoder_splits_per_shard_and_counts_bytes() {
        let dim = 12;
        let layout = ShardLayout::new(dim, 3);
        let mut g = vec![0.0f32; dim];
        for (i, v) in g.iter_mut().enumerate() {
            *v = (i as f32 + 1.0) * if i % 2 == 0 { 1.0 } else { -1.0 };
        }
        let mut enc = GradEncoder::new(WireFormat::TopK(KSpec::Count(4)), dim, 3);
        let mut out = Vec::new();
        enc.encode(&g, &layout, &mut out);
        assert_eq!(out.len(), 3);
        // top-4 of |g| are coords 8..12; shards are 0..4, 4..8, 8..12
        let total: usize = out
            .iter()
            .map(|p| match p {
                ShardGrad::Sparse(s) => s.idx.len(),
                _ => panic!("expected sparse payload"),
            })
            .sum();
        assert_eq!(total, 4);
        assert_eq!(submission_bytes(&out, &layout), 4 * 8);
        // dense-equivalent bytes for comparison
        assert_eq!(dim * 4, 48);
        // reconstructing the parts matches the whole-vector compression
        let mut reference = TopKCompressor::new(dim, 4);
        let full = reference.compress(&g);
        let mut dense = vec![0.0f32; dim];
        for (p, r) in out.iter().zip(layout.ranges()) {
            p.view(r.clone()).add_to(&mut dense[r]);
        }
        assert_eq!(dense, full.to_dense());
    }

    #[test]
    fn encoder_recycles_payload_buffers() {
        // The steady-state zero-allocation contract at the encoder level:
        // once consumers drop their payload clones, the next encode reuses
        // the same heap buffers (observable via stable Vec pointers).
        let dim = 256;
        let layout = ShardLayout::new(dim, 2);
        let mut rng = Pcg64::seeded(12);
        let mut g = vec![0.0f32; dim];
        rng.fill_normal(&mut g, 1.0);
        for wire in [
            WireFormat::Dense,
            WireFormat::TopK(KSpec::Count(16)),
            WireFormat::Int8,
            WireFormat::TopKInt8(KSpec::Count(16)),
        ] {
            let mut enc = GradEncoder::new(wire.clone(), dim, 2);
            let mut out = Vec::new();
            // Warm-up round; consumers (the shard servers) drop their
            // clones — here that is simply `out` being cleared by encode.
            enc.encode(&g, &layout, &mut out);
            let ptrs: Vec<usize> = out
                .iter()
                .map(|p| match p {
                    ShardGrad::Dense(a) => a.as_ptr() as usize,
                    ShardGrad::Sparse(a) => a.idx.as_ptr() as usize,
                    ShardGrad::Quant(a) => a.data.as_ptr() as usize,
                    ShardGrad::SparseQuant(a) => a.data.as_ptr() as usize,
                    other => panic!("encoder never emits {other:?}"),
                })
                .collect();
            for round in 0..20 {
                rng.fill_normal(&mut g, 1.0);
                enc.encode(&g, &layout, &mut out);
                let mut now: Vec<usize> = out
                    .iter()
                    .map(|p| match p {
                        ShardGrad::Dense(a) => a.as_ptr() as usize,
                        ShardGrad::Sparse(a) => a.idx.as_ptr() as usize,
                        ShardGrad::Quant(a) => a.data.as_ptr() as usize,
                        ShardGrad::SparseQuant(a) => a.data.as_ptr() as usize,
                        other => panic!("encoder never emits {other:?}"),
                    })
                    .collect();
                // Pool order may rotate; compare as sets.
                let mut want = ptrs.clone();
                now.sort_unstable();
                want.sort_unstable();
                assert_eq!(now, want, "{wire}: payload buffers reallocated at round {round}");
            }
        }
    }

    #[test]
    fn view_sq_norm_and_scaled_add_agree_across_formats() {
        let dense = vec![3.0f32, 0.0, -4.0, 0.0];
        let dv = GradView::Dense(&dense);
        assert!((dv.sq_norm() - 25.0).abs() < 1e-9);
        let sv = GradView::Sparse {
            idx: &[0, 2],
            val: &[3.0, -4.0],
        };
        assert!((sv.sq_norm() - 25.0).abs() < 1e-9);
        // clip to norm 1: factor 1/5
        let f = (1.0 / dv.sq_norm().sqrt()) as f32;
        let mut a = vec![0.0f32; 4];
        let mut b = vec![0.0f32; 4];
        dv.add_scaled_to(&mut a, f);
        sv.add_scaled_to(&mut b, f);
        assert_eq!(a, b);
        assert!((a[0] - 0.6).abs() < 1e-6);
        assert!((a[2] + 0.8).abs() < 1e-6);
        // int8 views: sq_norm over dequantized values
        let q = quantize_i8(&dense);
        let qv = GradView::Quant {
            scale: q.scale,
            data: &q.data,
        };
        assert!((qv.sq_norm().sqrt() - 5.0).abs() < 0.1);
        let mut c = vec![0.0f32; 4];
        qv.add_scaled_to(&mut c, 0.5);
        let mut d = vec![0.0f32; 4];
        qv.add_to(&mut d);
        for (x, y) in c.iter().zip(&d) {
            assert!((x * 2.0 - y).abs() < 1e-6);
        }
    }

    #[test]
    fn shard_grad_finiteness_checks_whole_payload() {
        let ok = ShardGrad::Dense(Arc::new(vec![1.0, -2.0, 0.0]));
        assert!(ok.is_finite());
        // The poison sits outside shard 0's slice, but the verdict is
        // payload-wide — every shard must agree (lockstep invariant).
        let bad = ShardGrad::Dense(Arc::new(vec![1.0, f32::NAN, 0.0]));
        assert!(!bad.is_finite());
        let inf = ShardGrad::DenseLocal(Arc::new(vec![f32::INFINITY]));
        assert!(!inf.is_finite());
        let sp = ShardGrad::Sparse(Arc::new(SparseGrad {
            dim: 4,
            idx: vec![1],
            val: vec![f32::NEG_INFINITY],
        }));
        assert!(!sp.is_finite());
        // int8 data is always finite; only the scale can poison
        let q = ShardGrad::Quant(Arc::new(QuantGrad {
            scale: f32::NAN,
            data: vec![1, 2],
        }));
        assert!(!q.is_finite());
        let q_ok = ShardGrad::QuantLocal(Arc::new(QuantGrad {
            scale: 0.5,
            data: vec![1, 2],
        }));
        assert!(q_ok.is_finite());
        let sq = ShardGrad::SparseQuant(Arc::new(SparseQuantGrad {
            dim: 4,
            idx: vec![0],
            scale: f32::INFINITY,
            data: vec![7],
        }));
        assert!(!sq.is_finite());
    }

    #[test]
    fn topk_int8_payload_decodes_within_tolerance() {
        let dim = 64;
        let layout = ShardLayout::new(dim, 2);
        let mut rng = Pcg64::seeded(21);
        let mut g = vec![0.0f32; dim];
        rng.fill_normal(&mut g, 1.0);
        let mut enc = GradEncoder::new(WireFormat::TopKInt8(KSpec::Count(8)), dim, 2);
        let mut out = Vec::new();
        enc.encode(&g, &layout, &mut out);
        // Compare against the f32 top-k of the same stream.
        let mut reference = TopKCompressor::new(dim, 8);
        let full = reference.compress(&g);
        let mut decoded = vec![0.0f32; dim];
        for (p, r) in out.iter().zip(layout.ranges()) {
            p.view(r.clone()).add_to(&mut decoded[r]);
        }
        let maxabs = full.val.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let step = maxabs / 127.0;
        for (a, b) in full.to_dense().iter().zip(&decoded) {
            assert!((a - b).abs() <= step * 0.5 + 1e-6, "{a} vs {b}");
        }
        // 5 bytes per kept coordinate + one scale per shard payload
        let bytes = submission_bytes(&out, &layout);
        assert_eq!(bytes, 8 * 5 + 2 * 4);
    }
}
