//! Worker heterogeneity / communication-delay injection.
//!
//! The paper (§6): "to simulate the communication delays and faster/slower
//! workers, we randomly introduced execution delays in 50% gradient workers.
//! The execution delays were sampled randomly from a normal distribution with
//! a mean of 0 and a standard deviation of 0.25 during each gradient
//! calculated by the worker." Negative draws are clamped to zero (a delay
//! cannot be negative), matching the only sane reading.
//!
//! Determinism: the model owns no randomness and no timing. Which workers
//! are affected and every per-gradient draw come from the *injected*
//! `Pcg64` stream (the trainer derives it from `TrainConfig::seed`; seed
//! derivations are documented in EXPERIMENTS.md), and the *wait* itself is
//! served by the injected [`super::clock::Clock`] — wall sleep under the
//! real clock, pure time advancement under the virtual one — so a §6-style
//! delay experiment replays identically from its seed.

use crate::util::rng::Pcg64;
use std::time::Duration;

/// Delay model for one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct DelayModel {
    /// Fraction of workers subject to delays (paper: 0.5).
    pub affected_fraction: f64,
    /// Normal(mean, std) in seconds, clamped at 0 (paper: mean 0, σ 0.25).
    pub mean: f64,
    pub std: f64,
}

impl DelayModel {
    /// The paper's default configuration.
    pub fn paper_default() -> Self {
        DelayModel {
            affected_fraction: 0.5,
            mean: 0.0,
            std: 0.25,
        }
    }

    /// No delays at all.
    pub fn none() -> Self {
        DelayModel {
            affected_fraction: 0.0,
            mean: 0.0,
            std: 0.0,
        }
    }

    /// Same parameters with a different σ (Table 5 sweeps σ).
    pub fn with_std(mut self, std: f64) -> Self {
        self.std = std;
        self
    }

    /// Decide (deterministically, from the run RNG) which workers are slow.
    pub fn assign(&self, workers: usize, rng: &mut Pcg64) -> Vec<bool> {
        let n_affected = (workers as f64 * self.affected_fraction).round() as usize;
        let mut flags = vec![false; workers];
        for f in flags.iter_mut().take(n_affected) {
            *f = true;
        }
        rng.shuffle(&mut flags);
        flags
    }

    /// Sample the delay for one gradient computation of an affected worker.
    pub fn sample(&self, rng: &mut Pcg64) -> Duration {
        Duration::from_secs_f64(self.sample_secs(rng))
    }

    /// Same draw in raw seconds — the virtual-time simulator composes the
    /// value into event timestamps instead of sleeping it.
    pub fn sample_secs(&self, rng: &mut Pcg64) -> f64 {
        if self.std == 0.0 && self.mean <= 0.0 {
            return 0.0;
        }
        rng.normal_ms(self.mean, self.std).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_affects_half() {
        let m = DelayModel::paper_default();
        let flags = m.assign(26, &mut Pcg64::seeded(1));
        assert_eq!(flags.iter().filter(|&&f| f).count(), 13);
    }

    #[test]
    fn none_is_zero() {
        let m = DelayModel::none();
        let mut rng = Pcg64::seeded(2);
        assert_eq!(m.sample(&mut rng), Duration::ZERO);
        assert!(m.assign(8, &mut rng).iter().all(|&f| !f));
    }

    #[test]
    fn samples_clamped_nonnegative_with_correct_tail() {
        let m = DelayModel::paper_default();
        let mut rng = Pcg64::seeded(3);
        let n = 10_000;
        let mut zeros = 0;
        let mut sum = 0.0;
        for _ in 0..n {
            let d = m.sample(&mut rng).as_secs_f64();
            assert!(d >= 0.0);
            if d == 0.0 {
                zeros += 1;
            }
            sum += d;
        }
        // N(0, .25) clamped at 0: ~half the mass at 0, mean = σ/√(2π) ≈ 0.0997
        let frac0 = zeros as f64 / n as f64;
        assert!((frac0 - 0.5).abs() < 0.03, "zero fraction {frac0}");
        let mean = sum / n as f64;
        assert!((mean - 0.0997).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_and_sample_secs_agree() {
        let m = DelayModel::paper_default();
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(m.sample(&mut a), Duration::from_secs_f64(m.sample_secs(&mut b)));
        }
    }

    #[test]
    fn with_std_overrides() {
        let m = DelayModel::paper_default().with_std(1.25);
        assert_eq!(m.std, 1.25);
        assert_eq!(m.affected_fraction, 0.5);
    }
}
