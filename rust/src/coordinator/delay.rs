//! Worker heterogeneity / communication-delay injection.
//!
//! The paper (§6): "to simulate the communication delays and faster/slower
//! workers, we randomly introduced execution delays in 50% gradient workers.
//! The execution delays were sampled randomly from a normal distribution with
//! a mean of 0 and a standard deviation of 0.25 during each gradient
//! calculated by the worker." Negative draws are clamped to zero (a delay
//! cannot be negative), matching the only sane reading.
//!
//! Determinism: the model owns no randomness and no timing. Which workers
//! are affected and every per-gradient draw come from the *injected*
//! `Pcg64` stream (the trainer derives it from `TrainConfig::seed`; seed
//! derivations are documented in EXPERIMENTS.md), and the *wait* itself is
//! served by the injected [`super::clock::Clock`] — wall sleep under the
//! real clock, pure time advancement under the virtual one — so a §6-style
//! delay experiment replays identically from its seed.

use crate::util::rng::Pcg64;
use std::time::Duration;

/// Per-gradient delay distribution family (`delay-dist=` in the scenario
/// DSL).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DelayDist {
    /// Normal(mean, std) clamped at 0 — the paper's §6 model, the default.
    Normal,
    /// `exp(Normal(mean, std))` with `mean`/`std` read in log-space — the
    /// heavy-tailed WAN-RTT shape (most draws near `exp(mean)`, rare large
    /// stragglers).
    LogNormal,
}

impl DelayDist {
    pub fn parse(s: &str) -> anyhow::Result<DelayDist> {
        match s {
            "normal" => Ok(DelayDist::Normal),
            "lognormal" => Ok(DelayDist::LogNormal),
            other => anyhow::bail!("unknown delay dist `{other}` (expected `normal` or `lognormal`)"),
        }
    }
}

impl std::fmt::Display for DelayDist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DelayDist::Normal => write!(f, "normal"),
            DelayDist::LogNormal => write!(f, "lognormal"),
        }
    }
}

/// Delay model for one training run.
#[derive(Clone, Debug, PartialEq)]
pub struct DelayModel {
    /// Fraction of workers subject to delays (paper: 0.5).
    pub affected_fraction: f64,
    /// Normal(mean, std) in seconds, clamped at 0 (paper: mean 0, σ 0.25).
    /// Under [`DelayDist::LogNormal`] the pair is read in log-space.
    pub mean: f64,
    pub std: f64,
    /// Distribution family of the per-gradient draw. [`DelayDist::Normal`]
    /// (the default) reproduces the historical sampling bitwise.
    pub dist: DelayDist,
    /// WAN regional correlation groups: workers map round-robin onto this
    /// many regions, and all members of a region share one fixed
    /// multiplier on their delay draws — co-located workers are slow
    /// together, the signature of cross-region links. `0` (the default)
    /// disables the multiplier and reproduces the historical model
    /// bitwise.
    pub regions: usize,
}

impl DelayModel {
    /// The paper's default configuration.
    pub fn paper_default() -> Self {
        DelayModel {
            affected_fraction: 0.5,
            mean: 0.0,
            std: 0.25,
            dist: DelayDist::Normal,
            regions: 0,
        }
    }

    /// No delays at all.
    pub fn none() -> Self {
        DelayModel {
            affected_fraction: 0.0,
            mean: 0.0,
            std: 0.0,
            dist: DelayDist::Normal,
            regions: 0,
        }
    }

    /// Same parameters with a different σ (Table 5 sweeps σ).
    pub fn with_std(mut self, std: f64) -> Self {
        self.std = std;
        self
    }

    /// Same parameters under a different distribution family.
    pub fn with_dist(mut self, dist: DelayDist) -> Self {
        self.dist = dist;
        self
    }

    /// Same parameters with WAN regional correlation groups.
    pub fn with_regions(mut self, regions: usize) -> Self {
        self.regions = regions;
        self
    }

    /// Decide (deterministically, from the run RNG) which workers are slow.
    pub fn assign(&self, workers: usize, rng: &mut Pcg64) -> Vec<bool> {
        let n_affected = (workers as f64 * self.affected_fraction).round() as usize;
        let mut flags = vec![false; workers];
        for f in flags.iter_mut().take(n_affected) {
            *f = true;
        }
        rng.shuffle(&mut flags);
        flags
    }

    /// Sample the delay for one gradient computation of an affected worker.
    pub fn sample(&self, rng: &mut Pcg64) -> Duration {
        Duration::from_secs_f64(self.sample_secs(rng))
    }

    /// Same draw in raw seconds — the virtual-time simulator composes the
    /// value into event timestamps instead of sleeping it.
    pub fn sample_secs(&self, rng: &mut Pcg64) -> f64 {
        match self.dist {
            DelayDist::Normal => {
                if self.std == 0.0 && self.mean <= 0.0 {
                    return 0.0;
                }
                rng.normal_ms(self.mean, self.std).max(0.0)
            }
            DelayDist::LogNormal => rng.normal_ms(self.mean, self.std).exp(),
        }
    }

    /// [`DelayModel::sample`] with the worker's regional multiplier
    /// applied. Identical to `sample` when `regions` is off — the factor
    /// is exactly 1.0, so existing runs replay bitwise.
    pub fn sample_for(&self, worker: usize, rng: &mut Pcg64) -> Duration {
        Duration::from_secs_f64(self.sample_secs_for(worker, rng))
    }

    /// [`DelayModel::sample_secs`] scaled by [`DelayModel::region_factor`].
    pub fn sample_secs_for(&self, worker: usize, rng: &mut Pcg64) -> f64 {
        self.sample_secs(rng) * self.region_factor(worker)
    }

    /// The fixed multiplier of `worker`'s region: a lognormal draw
    /// (`exp N(0, 0.5)`, median 1) seeded purely by the region index, so a
    /// scenario string fully determines every factor — no extra state to
    /// replay. Workers map round-robin (`worker % regions`); `regions <= 1`
    /// returns exactly 1.0.
    pub fn region_factor(&self, worker: usize) -> f64 {
        if self.regions <= 1 {
            return 1.0;
        }
        let region = (worker % self.regions) as u64;
        let mut rng = Pcg64::new(0x57A4_D31A ^ region, region.wrapping_add(29));
        rng.normal_ms(0.0, 0.5).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_affects_half() {
        let m = DelayModel::paper_default();
        let flags = m.assign(26, &mut Pcg64::seeded(1));
        assert_eq!(flags.iter().filter(|&&f| f).count(), 13);
    }

    #[test]
    fn none_is_zero() {
        let m = DelayModel::none();
        let mut rng = Pcg64::seeded(2);
        assert_eq!(m.sample(&mut rng), Duration::ZERO);
        assert!(m.assign(8, &mut rng).iter().all(|&f| !f));
    }

    #[test]
    fn samples_clamped_nonnegative_with_correct_tail() {
        let m = DelayModel::paper_default();
        let mut rng = Pcg64::seeded(3);
        let n = 10_000;
        let mut zeros = 0;
        let mut sum = 0.0;
        for _ in 0..n {
            let d = m.sample(&mut rng).as_secs_f64();
            assert!(d >= 0.0);
            if d == 0.0 {
                zeros += 1;
            }
            sum += d;
        }
        // N(0, .25) clamped at 0: ~half the mass at 0, mean = σ/√(2π) ≈ 0.0997
        let frac0 = zeros as f64 / n as f64;
        assert!((frac0 - 0.5).abs() < 0.03, "zero fraction {frac0}");
        let mean = sum / n as f64;
        assert!((mean - 0.0997).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn sample_and_sample_secs_agree() {
        let m = DelayModel::paper_default();
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for _ in 0..100 {
            assert_eq!(m.sample(&mut a), Duration::from_secs_f64(m.sample_secs(&mut b)));
        }
    }

    #[test]
    fn with_std_overrides() {
        let m = DelayModel::paper_default().with_std(1.25);
        assert_eq!(m.std, 1.25);
        assert_eq!(m.affected_fraction, 0.5);
    }

    #[test]
    fn lognormal_is_positive_and_heavy_tailed() {
        // ln-space N(-2, 0.8): median exp(-2) ≈ 0.135 s, strictly positive.
        let mut m = DelayModel::paper_default().with_dist(DelayDist::LogNormal);
        m.mean = -2.0;
        m.std = 0.8;
        let mut rng = Pcg64::seeded(5);
        let n = 20_000;
        let mut draws: Vec<f64> = (0..n).map(|_| m.sample_secs(&mut rng)).collect();
        assert!(draws.iter().all(|&d| d > 0.0), "lognormal draws are positive");
        draws.sort_unstable_by(f64::total_cmp);
        let median = draws[n / 2];
        assert!((median - (-2.0f64).exp()).abs() < 0.02, "median {median}");
        // Heavy tail: the mean sits well above the median.
        let mean = draws.iter().sum::<f64>() / n as f64;
        assert!(mean > median * 1.2, "mean {mean} vs median {median}");
    }

    #[test]
    fn region_factors_are_deterministic_and_off_by_default() {
        let m = DelayModel::paper_default();
        // regions off: the factor is exactly 1, so sampling via the
        // per-worker entry point is bitwise the historical draw.
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        for w in 0..8 {
            assert_eq!(m.region_factor(w), 1.0);
            assert_eq!(
                m.sample_secs_for(w, &mut a).to_bits(),
                m.sample_secs(&mut b).to_bits()
            );
        }
        let wan = m.clone().with_regions(3);
        // Same region → same factor; factors differ across regions.
        assert_eq!(wan.region_factor(0), wan.region_factor(3));
        assert_eq!(wan.region_factor(1), wan.region_factor(4));
        assert_ne!(wan.region_factor(0), wan.region_factor(1));
        assert!(wan.region_factor(0) > 0.0);
        // Replays: the factor depends only on the scenario, not run state.
        assert_eq!(
            wan.region_factor(2).to_bits(),
            DelayModel::paper_default().with_regions(3).region_factor(2).to_bits()
        );
    }

    #[test]
    fn dist_parse_roundtrip() {
        for s in ["normal", "lognormal"] {
            assert_eq!(DelayDist::parse(s).unwrap().to_string(), s);
        }
        assert!(DelayDist::parse("pareto").is_err());
    }
}
