//! Threshold functions `K(n)` controlling the async→sync transition.
//!
//! The paper's Algorithm 1 grows a threshold K with the number of gradient
//! updates; its experiments use a **step function** whose step size is a
//! multiple of `1/learning-rate` (§6). §9 (future work) asks whether other
//! monotonically increasing functions can be plugged in unchanged — we
//! implement several and benchmark them in `bench_ablations`.
//!
//! Contract: `k(n)` is a non-decreasing function of the number of gradient
//! arrivals `n`, with `k(0) ≥ 1`, clamped to `[1, k_max]`. `k_max` defaults
//! to the worker count (beyond that a flush can never trigger before every
//! worker contributed at least once on average). Under elastic membership
//! the caller passes a cap that tracks the *live* worker set
//! ([`super::policy::Aggregator`] renormalization, DESIGN.md §2.7), so
//! `k(n)` stays monotone in `n` for a fixed cap but may step down across a
//! membership epoch when workers depart — the schedule itself never needs
//! to know.

/// A monotone threshold schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Fixed K — `Constant(1)` is exactly the asynchronous baseline.
    Constant { k: usize },
    /// The paper's choice: K = 1 + ⌊n / step⌋.
    Step { step: usize },
    /// K = 1 + rate·n (rate ≪ 1).
    Linear { rate: f64 },
    /// K = growth^(n / step): doubles every `step` arrivals for growth=2.
    Exponential { step: usize, growth: f64 },
    /// Smooth sigmoid ramp from 1 to k_max centred at `mid` arrivals.
    Sigmoid { mid: f64, scale: f64 },
}

impl Schedule {
    /// Threshold after `n` gradient arrivals, clamped to [1, k_max].
    pub fn k(&self, n: u64, k_max: usize) -> usize {
        let raw: f64 = match self {
            Schedule::Constant { k } => *k as f64,
            Schedule::Step { step } => 1.0 + (n / (*step).max(1) as u64) as f64,
            Schedule::Linear { rate } => 1.0 + rate * n as f64,
            Schedule::Exponential { step, growth } => {
                growth.powf(n as f64 / (*step).max(1) as f64)
            }
            Schedule::Sigmoid { mid, scale } => {
                let z = (n as f64 - mid) / scale.max(1e-9);
                1.0 + (k_max.saturating_sub(1) as f64) / (1.0 + (-z).exp())
            }
        };
        (raw.floor() as usize).clamp(1, k_max.max(1))
    }

    /// Parse from CLI syntax: `step:500`, `const:1`, `linear:0.002`,
    /// `exp:500:2`, `sigmoid:2000:400`.
    pub fn parse(s: &str) -> anyhow::Result<Schedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let err = || anyhow::anyhow!("bad schedule spec `{s}`");
        match parts.as_slice() {
            ["const", k] => Ok(Schedule::Constant {
                k: k.parse().map_err(|_| err())?,
            }),
            ["step", step] => Ok(Schedule::Step {
                step: step.parse().map_err(|_| err())?,
            }),
            ["linear", rate] => Ok(Schedule::Linear {
                rate: rate.parse().map_err(|_| err())?,
            }),
            ["exp", step, growth] => Ok(Schedule::Exponential {
                step: step.parse().map_err(|_| err())?,
                growth: growth.parse().map_err(|_| err())?,
            }),
            ["sigmoid", mid, scale] => Ok(Schedule::Sigmoid {
                mid: mid.parse().map_err(|_| err())?,
                scale: scale.parse().map_err(|_| err())?,
            }),
            _ => Err(err()),
        }
    }

    /// The paper's parameterisation: step size as `multiple × (1/lr)`.
    pub fn paper_step(multiple: f64, lr: f64) -> Schedule {
        Schedule::Step {
            step: (multiple / lr).round().max(1.0) as usize,
        }
    }
}

impl std::fmt::Display for Schedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Schedule::Constant { k } => write!(f, "const:{k}"),
            Schedule::Step { step } => write!(f, "step:{step}"),
            Schedule::Linear { rate } => write!(f, "linear:{rate}"),
            Schedule::Exponential { step, growth } => write!(f, "exp:{step}:{growth}"),
            Schedule::Sigmoid { mid, scale } => write!(f, "sigmoid:{mid}:{scale}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_matches_paper_formula() {
        let s = Schedule::Step { step: 300 };
        assert_eq!(s.k(0, 25), 1);
        assert_eq!(s.k(299, 25), 1);
        assert_eq!(s.k(300, 25), 2);
        assert_eq!(s.k(2999, 25), 10);
        assert_eq!(s.k(1_000_000, 25), 25); // clamped at k_max
    }

    #[test]
    fn paper_step_uses_reciprocal_lr() {
        // step size "3 × (1/lr)" with lr = 0.01 → 300 arrivals per increment
        let s = Schedule::paper_step(3.0, 0.01);
        assert_eq!(s, Schedule::Step { step: 300 });
        assert_eq!(Schedule::paper_step(5.0, 0.01), Schedule::Step { step: 500 });
    }

    #[test]
    fn all_schedules_monotone_and_bounded() {
        let schedules = [
            Schedule::Constant { k: 3 },
            Schedule::Step { step: 100 },
            Schedule::Linear { rate: 0.01 },
            Schedule::Exponential {
                step: 200,
                growth: 2.0,
            },
            Schedule::Sigmoid {
                mid: 500.0,
                scale: 100.0,
            },
        ];
        for s in &schedules {
            let mut prev = 0;
            for n in (0..5000).step_by(17) {
                let k = s.k(n, 16);
                assert!((1..=16).contains(&k), "{s} out of range at n={n}: {k}");
                assert!(k >= prev, "{s} not monotone at n={n}");
                prev = k;
            }
        }
    }

    #[test]
    fn constant_one_is_async() {
        let s = Schedule::Constant { k: 1 };
        for n in [0u64, 10, 1000] {
            assert_eq!(s.k(n, 25), 1);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for spec in ["const:1", "step:500", "linear:0.002", "exp:500:2", "sigmoid:2000:400"] {
            let s = Schedule::parse(spec).unwrap();
            let again = Schedule::parse(&s.to_string()).unwrap();
            assert_eq!(s, again);
        }
        assert!(Schedule::parse("bogus").is_err());
        assert!(Schedule::parse("step:x").is_err());
    }

    #[test]
    fn shrinking_cap_renormalizes_k_without_touching_the_schedule() {
        // The elastic-membership contract: the same schedule under a
        // smaller cap (live workers dropped) yields a clamped K, and the
        // cap restoring recovers the schedule's trajectory exactly.
        let s = Schedule::Step { step: 10 };
        assert_eq!(s.k(100, 25), 11);
        assert_eq!(s.k(100, 4), 4, "cap at live membership");
        assert_eq!(s.k(100, 25), 11, "schedule state is untouched by the cap");
        assert_eq!(s.k(100, 1), 1, "a lone survivor runs async");
    }

    #[test]
    fn sigmoid_saturates_at_kmax() {
        let s = Schedule::Sigmoid {
            mid: 100.0,
            scale: 10.0,
        };
        assert_eq!(s.k(10_000, 8), 8);
        assert_eq!(s.k(0, 8), 1);
    }
}
