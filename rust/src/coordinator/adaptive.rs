//! Adaptive threshold controller — the paper's §9 future work:
//! "Currently, finding the threshold for aggregating parameters is based
//! upon experimental data. However, a good heuristic can be devised which
//! can form a base for selecting the aggregation threshold for different
//! types of models and datasets."
//!
//! The heuristic implemented here closes the loop on the quantity the
//! algorithm is actually trading off: **observed gradient staleness**. The
//! controller keeps an EWMA of the staleness of applied gradients and of the
//! per-flush loss trend, and moves K:
//!
//! - staleness above target ⇒ the async component is hurting ⇒ raise K
//!   (more synchronous aggregation);
//! - staleness below target *and* the loss still falling steeply ⇒ cheap
//!   asynchronous progress is available ⇒ lower K;
//! - loss plateaued ⇒ drift K towards `k_max` (variance reduction is all
//!   that is left to gain).
//!
//! K moves by at most ±1 per adjustment window, so the transition stays
//! smooth — the same property the paper's step schedule has by construction.
//!
//! Sharding note: the sharded parameter server instantiates one controller
//! per shard. The controller is a pure deterministic function of its
//! observation stream, so replicas fed the identical stream hold the same K
//! at every arrival — pinned by `identical_streams_keep_replicas_in_lockstep`
//! below and by the sharded equivalence property tests (which drive the
//! sequential machine). In the *threaded* server, concurrent sends can
//! interleave differently per shard channel, and since the EWMA is
//! order-sensitive the per-shard K may transiently diverge with `S > 1` —
//! see `server.rs` module docs.

/// Configuration for the adaptive controller.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveConfig {
    /// Target mean staleness of applied gradients (in parameter versions).
    /// The natural scale is O(workers): async sits near `W − 1`, sync at 0.
    pub target_staleness: f64,
    /// Gradient arrivals per adjustment window.
    pub window: usize,
    /// EWMA smoothing for staleness / loss (0 < alpha ≤ 1).
    pub alpha: f64,
    /// Relative loss-improvement per window below which the run counts as
    /// plateaued.
    pub plateau_eps: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            target_staleness: 2.0,
            window: 64,
            alpha: 0.2,
            plateau_eps: 0.005,
        }
    }
}

/// Stateful K controller driven by per-arrival observations.
#[derive(Clone, Debug)]
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    k: usize,
    seen_in_window: usize,
    staleness_ewma: f64,
    loss_ewma: f64,
    prev_window_loss: f64,
    initialized: bool,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        AdaptiveController {
            cfg,
            k: 1,
            seen_in_window: 0,
            staleness_ewma: 0.0,
            loss_ewma: 0.0,
            prev_window_loss: f64::INFINITY,
            initialized: false,
        }
    }

    /// Current threshold.
    pub fn k(&self) -> usize {
        self.k
    }

    pub fn staleness_ewma(&self) -> f64 {
        self.staleness_ewma
    }

    /// Observe one gradient arrival (staleness in versions, training loss
    /// reported by the worker). Returns the possibly-updated K, clamped to
    /// `[1, k_max]`.
    pub fn observe(&mut self, staleness: u64, loss: f32, k_max: usize) -> usize {
        let a = self.cfg.alpha;
        if !self.initialized {
            self.staleness_ewma = staleness as f64;
            self.loss_ewma = loss as f64;
            self.initialized = true;
        } else {
            self.staleness_ewma = (1.0 - a) * self.staleness_ewma + a * staleness as f64;
            self.loss_ewma = (1.0 - a) * self.loss_ewma + a * loss as f64;
        }
        self.seen_in_window += 1;
        if self.seen_in_window >= self.cfg.window {
            self.seen_in_window = 0;
            self.adjust();
        }
        self.k = self.k.clamp(1, k_max.max(1));
        self.k
    }

    fn adjust(&mut self) {
        let improving = if self.prev_window_loss.is_finite() && self.prev_window_loss.abs() > 1e-12
        {
            (self.prev_window_loss - self.loss_ewma) / self.prev_window_loss.abs()
        } else {
            1.0
        };
        self.prev_window_loss = self.loss_ewma;

        if self.staleness_ewma > self.cfg.target_staleness {
            // stale updates dominate: get more synchronous
            self.k += 1;
        } else if improving < self.cfg.plateau_eps {
            // plateau: buy variance reduction
            self.k += 1;
        } else if self.staleness_ewma < self.cfg.target_staleness * 0.5 && self.k > 1 {
            // plenty of fresh progress available: allow more asynchrony
            self.k -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_async() {
        let c = AdaptiveController::new(AdaptiveConfig::default());
        assert_eq!(c.k(), 1);
    }

    #[test]
    fn high_staleness_raises_k() {
        let mut c = AdaptiveController::new(AdaptiveConfig {
            window: 10,
            ..Default::default()
        });
        // staleness 8 ≫ target 2, loss falling fast (no plateau trigger)
        let mut loss = 10.0f32;
        for _ in 0..100 {
            c.observe(8, loss, 16);
            loss *= 0.95;
        }
        assert!(c.k() >= 5, "K should climb under high staleness: {}", c.k());
    }

    #[test]
    fn fresh_gradients_keep_k_low() {
        let mut c = AdaptiveController::new(AdaptiveConfig {
            window: 10,
            ..Default::default()
        });
        let mut loss = 10.0f32;
        for _ in 0..200 {
            c.observe(0, loss, 16);
            loss *= 0.9; // steady improvement, zero staleness
        }
        assert!(c.k() <= 2, "K should stay low: {}", c.k());
    }

    #[test]
    fn plateau_drifts_k_up() {
        let mut c = AdaptiveController::new(AdaptiveConfig {
            window: 10,
            ..Default::default()
        });
        for _ in 0..300 {
            c.observe(1, 1.0, 8); // constant loss = plateau, low staleness
        }
        assert_eq!(c.k(), 8, "plateau should saturate K at k_max");
    }

    #[test]
    fn identical_streams_keep_replicas_in_lockstep() {
        // Per-shard controllers see the same (staleness, loss) stream; their
        // K must agree at every step for sharding to be policy-invisible.
        let cfg = AdaptiveConfig {
            window: 8,
            ..Default::default()
        };
        let mut a = AdaptiveController::new(cfg.clone());
        let mut b = AdaptiveController::new(cfg);
        let mut loss = 4.0f32;
        for i in 0..500u64 {
            let stale = (i * 7919) % 9;
            let ka = a.observe(stale, loss, 12);
            let kb = b.observe(stale, loss, 12);
            assert_eq!(ka, kb, "replicas diverged at arrival {i}");
            assert_eq!(a.staleness_ewma(), b.staleness_ewma());
            loss = (loss * 0.99).max(0.5) + if i % 3 == 0 { 0.01 } else { 0.0 };
        }
    }

    #[test]
    fn k_respects_bounds_and_moves_by_one() {
        let mut c = AdaptiveController::new(AdaptiveConfig {
            window: 5,
            ..Default::default()
        });
        let mut prev = c.k();
        for i in 0..500 {
            let stale = if i % 2 == 0 { 10 } else { 0 };
            let k = c.observe(stale, 1.0, 6);
            assert!((1..=6).contains(&k));
            assert!(k.abs_diff(prev) <= 1, "K jumped {prev} -> {k}");
            prev = k;
        }
    }
}
