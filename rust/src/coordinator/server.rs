//! The parameter-server thread: message routing around the [`Aggregator`].
//!
//! One `mpsc` channel carries gradients from all workers; each worker owns a
//! private reply channel. The server applies the policy per arrival and
//! replies with either fresh parameters (after an update), a cheap
//! "unchanged" token (smooth-hybrid buffering while θ is frozen — no copy),
//! or defers the reply until the flush (barrier semantics).
//!
//! Buffer-recycling protocol: gradient vectors travel worker→server inside
//! [`GradMsg`] and return inside the reply, so the steady state allocates
//! nothing on either side.

use super::metrics::RunMetrics;
use super::params::ParamStore;
use super::policy::{Aggregator, Outcome, Policy};
use crate::log_debug;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// A gradient submission.
pub struct GradMsg {
    pub worker: usize,
    /// Parameter version the gradient was computed against.
    pub base_version: u64,
    /// Training loss observed on the mini-batch (telemetry only).
    pub loss: f32,
    pub grad: Vec<f32>,
}

/// Server → worker reply.
pub enum Reply {
    /// Parameters changed: here is a fresh copy (+ your recycled buffer).
    Fresh {
        theta: Vec<f32>,
        version: u64,
        recycled: Vec<f32>,
    },
    /// Parameters did not change since `base_version`; keep your copy.
    Unchanged { recycled: Vec<f32> },
}

/// Server-side configuration.
pub struct ServerConfig {
    pub policy: Policy,
    pub workers: usize,
    pub lr: f32,
    /// Threshold cap; defaults to the worker count.
    pub k_max: Option<usize>,
    /// Sample the (t, K) / (t, version) trajectories at most this often.
    pub trace_interval: Duration,
    /// Shared cell the evaluator reads parameter snapshots from; created by
    /// the trainer. `None` → the store creates a private one.
    pub snapshot: Option<std::sync::Arc<std::sync::Mutex<(Vec<f32>, u64)>>>,
    /// Reply with a cheap `Unchanged` token (no θ copy) when a buffered
    /// gradient arrives and the submitter already holds the current version.
    /// On by default; disable (`HYBRID_SGD_NO_REPLY_OPT=1` via trainer) to
    /// measure the copy cost — see EXPERIMENTS.md §Perf.
    pub reply_unchanged_optim: bool,
}

/// What the server hands back when the run ends.
pub struct ServerReport {
    pub final_params: Vec<f32>,
    pub updates_total: u64,
    pub gradients_total: u64,
    pub flushes: u64,
    pub mean_staleness: f64,
    pub per_worker_grads: Vec<u64>,
    pub k_trajectory: crate::util::stats::Series,
    pub version_trajectory: crate::util::stats::Series,
}

impl ServerReport {
    /// Merge server counters into a [`RunMetrics`].
    pub fn fill(&self, m: &mut RunMetrics) {
        m.gradients_total = self.gradients_total;
        m.updates_total = self.updates_total;
        m.flushes = self.flushes;
        m.mean_staleness = self.mean_staleness;
        m.per_worker_grads = self.per_worker_grads.clone();
        m.k_trajectory = self.k_trajectory.clone();
        m.version_trajectory = self.version_trajectory.clone();
    }
}

/// Run the parameter server until every worker sender disconnects.
///
/// Call on a dedicated thread. `reply_txs[i]` is worker i's reply channel;
/// `stop` is the trainer's shutdown flag (used to release barrier-blocked
/// workers so they can observe the flag).
pub fn run_server(
    init: Vec<f32>,
    cfg: &ServerConfig,
    grad_rx: Receiver<GradMsg>,
    reply_txs: Vec<Sender<Reply>>,
    stop: &AtomicBool,
    start: Instant,
) -> ServerReport {
    let dim = init.len();
    let mut store = match &cfg.snapshot {
        Some(cell) => ParamStore::with_shared(init, cfg.lr, std::sync::Arc::clone(cell)),
        None => ParamStore::new(init, cfg.lr),
    };
    let mut agg = Aggregator::new(cfg.policy.clone(), dim, cfg.workers);
    if let Some(k) = cfg.k_max {
        agg = agg.with_k_max(k);
    }
    // Reply slots for workers blocked at a barrier: (worker, recycled buf).
    let mut blocked: Vec<(usize, Vec<f32>)> = Vec::with_capacity(cfg.workers);
    let mut per_worker = vec![0u64; cfg.workers];
    let mut k_traj = crate::util::stats::Series::new();
    let mut v_traj = crate::util::stats::Series::new();
    let mut last_trace = Instant::now() - cfg.trace_interval;
    let mut released_on_stop = false;

    loop {
        match grad_rx.recv_timeout(Duration::from_millis(20)) {
            Ok(msg) => {
                per_worker[msg.worker] += 1;
                let outcome = agg.on_gradient(&mut store, &msg.grad, msg.worker, msg.base_version, 1.0);
                let recycled = msg.grad;
                match outcome {
                    Outcome::AppliedNow => {
                        send_fresh(&reply_txs[msg.worker], &store, recycled);
                    }
                    Outcome::Buffered => {
                        // θ frozen since the last flush: if the worker already
                        // has this version, skip the copy entirely.
                        if cfg.reply_unchanged_optim && msg.base_version == store.version() {
                            let _ = reply_txs[msg.worker].send(Reply::Unchanged { recycled });
                        } else {
                            send_fresh(&reply_txs[msg.worker], &store, recycled);
                        }
                    }
                    Outcome::BufferedBlocked => {
                        blocked.push((msg.worker, recycled));
                    }
                    Outcome::Flushed { count, k_at_flush, .. } => {
                        log_debug!(
                            "server",
                            "flush of {count} gradients at K={k_at_flush}, v={}",
                            store.version()
                        );
                        send_fresh(&reply_txs[msg.worker], &store, recycled);
                        for (w, buf) in blocked.drain(..) {
                            send_fresh(&reply_txs[w], &store, buf);
                        }
                        let t = start.elapsed().as_secs_f64();
                        k_traj.push(t, agg.current_k() as f64);
                    }
                }
                if last_trace.elapsed() >= cfg.trace_interval {
                    last_trace = Instant::now();
                    v_traj.push(start.elapsed().as_secs_f64(), store.version() as f64);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        if stop.load(Ordering::Relaxed) && !released_on_stop {
            // Release barrier-blocked workers so they can see the stop flag.
            for (w, buf) in blocked.drain(..) {
                send_fresh(&reply_txs[w], &store, buf);
            }
            released_on_stop = true;
        }
    }

    // Apply whatever is still buffered so no gradient is silently dropped.
    agg.drain(&mut store);
    store.publish();
    v_traj.push(start.elapsed().as_secs_f64(), store.version() as f64);

    let stats = &agg.stats;
    ServerReport {
        updates_total: store.version(),
        gradients_total: stats.arrivals,
        flushes: stats.flushes,
        mean_staleness: if stats.arrivals > 0 {
            stats.staleness_sum / stats.arrivals as f64
        } else {
            0.0
        },
        per_worker_grads: per_worker,
        k_trajectory: k_traj,
        version_trajectory: v_traj,
        final_params: store.theta().to_vec(),
    }
}

fn send_fresh(tx: &Sender<Reply>, store: &ParamStore, recycled: Vec<f32>) {
    // A send error means the worker already exited (shutdown race): fine.
    let _ = tx.send(Reply::Fresh {
        theta: store.theta().to_vec(),
        version: store.version(),
        recycled,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::threshold::Schedule;
    use std::sync::mpsc;

    /// Drive the server with scripted messages on the current thread pool.
    fn run_scripted(policy: Policy, workers: usize, msgs: Vec<GradMsg>) -> (ServerReport, Vec<Vec<Reply>>) {
        let (gtx, grx) = mpsc::channel();
        let mut rtxs = Vec::new();
        let mut rrxs = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel();
            rtxs.push(tx);
            rrxs.push(rx);
        }
        let stop = AtomicBool::new(false);
        let cfg = ServerConfig {
            policy,
            workers,
            lr: 0.1,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            snapshot: None,
            reply_unchanged_optim: true,
        };
        for m in msgs {
            gtx.send(m).unwrap();
        }
        drop(gtx);
        let report = run_server(vec![0.0; 2], &cfg, grx, rtxs, &stop, Instant::now());
        let replies: Vec<Vec<Reply>> = rrxs
            .into_iter()
            .map(|rx| rx.try_iter().collect())
            .collect();
        (report, replies)
    }

    fn msg(worker: usize, v: u64) -> GradMsg {
        GradMsg {
            worker,
            base_version: v,
            loss: 1.0,
            grad: vec![1.0, 1.0],
        }
    }

    #[test]
    fn async_replies_fresh_every_time() {
        let (report, replies) = run_scripted(Policy::Async, 2, vec![msg(0, 0), msg(1, 1), msg(0, 2)]);
        assert_eq!(report.gradients_total, 3);
        assert_eq!(report.updates_total, 3);
        assert_eq!(replies[0].len(), 2);
        assert_eq!(replies[1].len(), 1);
        for r in replies.iter().flatten() {
            assert!(matches!(r, Reply::Fresh { .. }));
        }
    }

    #[test]
    fn sync_defers_until_barrier() {
        let (report, replies) =
            run_scripted(Policy::Sync, 3, vec![msg(0, 0), msg(1, 0), msg(2, 0)]);
        assert_eq!(report.updates_total, 1);
        assert_eq!(report.flushes, 1);
        // every worker got exactly one Fresh reply, all carrying version 1
        for r in &replies {
            assert_eq!(r.len(), 1);
            match &r[0] {
                Reply::Fresh { version, theta, .. } => {
                    assert_eq!(*version, 1);
                    // mean grad = 1 → θ = -0.1
                    assert!((theta[0] + 0.1).abs() < 1e-6);
                }
                _ => panic!("expected Fresh"),
            }
        }
    }

    #[test]
    fn hybrid_unchanged_replies_skip_param_copy() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 3 },
            strict: false,
        };
        let (report, replies) = run_scripted(policy, 3, vec![msg(0, 0), msg(1, 0), msg(2, 0)]);
        assert_eq!(report.flushes, 1);
        assert!(matches!(replies[0][0], Reply::Unchanged { .. }));
        assert!(matches!(replies[1][0], Reply::Unchanged { .. }));
        assert!(matches!(replies[2][0], Reply::Fresh { .. }));
    }

    #[test]
    fn leftover_buffer_drained_at_shutdown() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 10 },
            strict: false,
        };
        let (report, _) = run_scripted(policy, 2, vec![msg(0, 0), msg(1, 0)]);
        // no flush during the run, but drain applies the 2 buffered grads
        assert_eq!(report.updates_total, 1);
        assert_eq!(report.gradients_total, 2);
        assert!((report.final_params[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn stop_releases_blocked_workers() {
        let (gtx, grx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        let (rtx2, _rrx2) = mpsc::channel();
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let cfg = ServerConfig {
            policy: Policy::Sync,
            workers: 2,
            lr: 0.1,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            snapshot: None,
            reply_unchanged_optim: true,
        };
        let stop2 = std::sync::Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            run_server(vec![0.0], &cfg, grx, vec![rtx, rtx2], &stop2, Instant::now())
        });
        // worker 0 submits and would block forever (worker 1 never arrives)
        gtx.send(GradMsg {
            worker: 0,
            base_version: 0,
            loss: 0.0,
            grad: vec![1.0],
        })
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(rrx.try_recv().is_err(), "should be blocked at barrier");
        stop.store(true, Ordering::Relaxed);
        let reply = rrx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(matches!(reply, Reply::Fresh { .. }));
        drop(gtx);
        let report = h.join().unwrap();
        // the lone buffered gradient was drained into one update
        assert_eq!(report.updates_total, 1);
    }
}
