//! The sharded parameter-server threads: message routing around one
//! [`Aggregator`] + [`ParamStore`] pair per shard.
//!
//! Topology: the flat θ is split into `S` contiguous shards
//! ([`super::shard::ShardLayout`]); each shard is owned by its own server
//! thread running [`run_shard`]. A worker fans one gradient out to all `S`
//! shard channels as `Arc` clones of a single buffer (zero-copy fan-out),
//! and each shard consumes its slice, so every shard observes the same
//! *set* of arrivals. For the count-triggered policies (async, sync,
//! schedule-driven hybrid) the control flow depends only on arrival counts
//! and contributing-worker sets — both order-insensitive — so per-shard
//! `K(n)` state, barriers and flushes evolve in lockstep even though
//! concurrent sends may interleave differently per channel, and `S = 1`
//! reproduces the single-server semantics exactly. The adaptive policy's
//! controller is order-sensitive (EWMA over its observation stream), so
//! under threading its per-shard K can transiently diverge with `S > 1` —
//! the same class of nondeterminism an asynchronous PS already has across
//! runs; the sequential [`super::shard::ShardedAggregator`] is exactly
//! equivalent for every policy.
//!
//! Reply protocol: replies are O(1) version tokens — never parameter
//! copies. After an update the shard publishes an immutable snapshot into
//! its [`SnapshotCell`] (one memcpy into a recycled buffer) and replies
//! `Updated { version }`; workers refresh by a cheap `Arc` load and copy
//! only the shard slices whose version actually changed. While θ is frozen
//! (hybrid buffering) the reply is `Unchanged` and nobody copies anything.

use super::buffer::AggregateMode;
use super::clock::Clock;
use super::compress::ShardGrad;
use super::metrics::RunMetrics;
use super::params::{ParamDtype, ParamStore, SnapshotCell};
use super::policy::{Aggregator, Outcome, Policy};
use super::shard::ShardLayout;
use crate::log_debug;
use crate::util::trace::{Stage, TraceRing};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One shard's live gauges for the read-only ops plane. Relaxed atomics —
/// a status poll reads a near-instant snapshot, never a barrier: the shard
/// thread publishes after handling each event and nobody blocks on it
/// ("the status plane never touches the gradient plane", DESIGN.md §2.9).
#[derive(Debug, Default)]
pub struct ShardStatus {
    /// Current sync threshold K(n).
    pub k: AtomicU64,
    /// Gradients buffered toward the next flush.
    pub buffered: AtomicU64,
    /// Applied-update version (monotone).
    pub version: AtomicU64,
    /// Live workers as this shard sees them (static runs: the worker count).
    pub live: AtomicU64,
    /// Membership transitions applied by this shard.
    pub epoch: AtomicU64,
    /// Snapshots published into this shard's cell.
    pub snap_publishes: AtomicU64,
    /// Bytes those publishes copied into the snapshot pool (dirty blocks
    /// only — the big-model memory-path meter, DESIGN.md §2.12).
    pub snap_bytes: AtomicU64,
}

/// Staleness histogram bucket count: log2 buckets 0, 1, 2–3, 4–7, 8–15,
/// and ≥16. Staleness under async policies is bounded by in-flight
/// submissions (≈ workers), so six buckets resolve the whole useful range.
pub const STALE_BUCKETS: usize = 6;

/// Histogram bucket index for a staleness value (log2, saturating).
pub fn stale_bucket(staleness: u64) -> usize {
    ((64 - staleness.leading_zeros()) as usize).min(STALE_BUCKETS - 1)
}

/// One worker's arrival gauges for the ops plane: submission count,
/// staleness aggregates and histogram (enough for `hybrid-sgd status` to
/// print a mean / max / distribution of staleness per worker and spot
/// stragglers) and non-finite rejections (suspected-Byzantine workers).
/// Written by shard 0 only — all shards observe the same arrival sequence
/// (lockstep), so one shard's view stands for the run and nothing is
/// double-counted.
#[derive(Debug, Default)]
pub struct WorkerStatus {
    /// Gradient submissions seen from this worker.
    pub grads: AtomicU64,
    /// Submissions dropped at the boundary as non-finite (NaN/Inf).
    pub rejected: AtomicU64,
    /// Sum of staleness (shard version − base version) over submissions.
    pub stale_sum: AtomicU64,
    /// Maximum staleness observed from this worker.
    pub stale_max: AtomicU64,
    /// Staleness histogram: log2 buckets (see [`stale_bucket`]).
    pub stale_hist: [AtomicU64; STALE_BUCKETS],
}

/// Shared status gauges for a whole run: one [`ShardStatus`] per shard,
/// plus one [`WorkerStatus`] per worker slot when built via
/// [`StatusBoard::with_workers`]. Handed to the shard threads (writers)
/// and the serve frontend (reader); `None` in contexts nobody polls
/// (in-process experiments, the simulator).
#[derive(Debug)]
pub struct StatusBoard {
    pub shards: Vec<ShardStatus>,
    pub workers: Vec<WorkerStatus>,
    /// Byte-counter samples for the sliding-window rate: parallel
    /// `(uptime_ms + 1, lifetime bytes)` slots (0 = never written) pushed
    /// by the status renderer, throttled to ~one per 250 ms. 32 slots at
    /// that cadence comfortably cover the 5 s window.
    rate_t_ms: [AtomicU64; RATE_SAMPLES],
    rate_bytes: [AtomicU64; RATE_SAMPLES],
    rate_cursor: AtomicU64,
}

/// Sample slots in the byte-rate ring (see [`StatusBoard::push_rate_sample`]).
pub const RATE_SAMPLES: usize = 32;

/// The byte-rate window: `bytes_per_sec` in the status document averages
/// over roughly this much recent history instead of the whole run.
pub const RATE_WINDOW: Duration = Duration::from_secs(5);

/// Minimum spacing between recorded rate samples (rapid pollers reuse the
/// newest slot's information instead of flushing the window).
pub const RATE_SAMPLE_SPACING: Duration = Duration::from_millis(250);

impl StatusBoard {
    pub fn new(shards: usize) -> StatusBoard {
        StatusBoard::with_workers(shards, 0)
    }

    /// A board that additionally carries per-worker staleness gauges.
    pub fn with_workers(shards: usize, workers: usize) -> StatusBoard {
        StatusBoard {
            shards: (0..shards).map(|_| ShardStatus::default()).collect(),
            workers: (0..workers).map(|_| WorkerStatus::default()).collect(),
            rate_t_ms: std::array::from_fn(|_| AtomicU64::new(0)),
            rate_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
            rate_cursor: AtomicU64::new(0),
        }
    }

    /// Record a `(uptime, lifetime gradient-plane bytes)` sample for the
    /// sliding-window rate, throttled to one per [`RATE_SAMPLE_SPACING`].
    /// Called from the status renderer — every poll or subscription push
    /// feeds the window, so a 250 ms follower sees a live rate while an
    /// unpolled server pays nothing. Relaxed atomics: a torn slot under
    /// concurrent pollers at worst discards one sample at read time.
    pub fn push_rate_sample(&self, uptime: Duration, bytes: u64) {
        let t_ms = uptime.as_millis() as u64;
        let cur = self.rate_cursor.load(Ordering::Relaxed);
        if cur > 0 {
            let newest = self.rate_t_ms[(cur as usize - 1) % RATE_SAMPLES].load(Ordering::Relaxed);
            if newest != 0 && t_ms + 1 < newest + RATE_SAMPLE_SPACING.as_millis() as u64 {
                return;
            }
        }
        let slot = self.rate_cursor.fetch_add(1, Ordering::Relaxed) as usize % RATE_SAMPLES;
        self.rate_bytes[slot].store(bytes, Ordering::Relaxed);
        self.rate_t_ms[slot].store(t_ms + 1, Ordering::Relaxed);
    }

    /// The sliding-window byte rate: bytes/sec between the oldest and the
    /// newest sample inside [`RATE_WINDOW`] of `now`. `None` until two
    /// samples span the window (callers fall back to the lifetime mean).
    pub fn window_bytes_per_sec(&self, now: Duration) -> Option<f64> {
        let now_ms = now.as_millis() as u64;
        let horizon = now_ms.saturating_sub(RATE_WINDOW.as_millis() as u64);
        let mut oldest: Option<(u64, u64)> = None;
        let mut newest: Option<(u64, u64)> = None;
        for (t, b) in self.rate_t_ms.iter().zip(&self.rate_bytes) {
            let t = t.load(Ordering::Relaxed);
            if t == 0 {
                continue; // never written
            }
            let t = t - 1;
            if t < horizon || t > now_ms {
                continue; // outside the window (or a torn/stale pair)
            }
            let b = b.load(Ordering::Relaxed);
            if oldest.map_or(true, |(ot, _)| t < ot) {
                oldest = Some((t, b));
            }
            if newest.map_or(true, |(nt, _)| t > nt) {
                newest = Some((t, b));
            }
        }
        let ((t0, b0), (t1, b1)) = (oldest?, newest?);
        // A window needs actual extent; equal stamps or counter regression
        // (torn slots) fall back to the lifetime mean.
        if t1 <= t0 || b1 < b0 {
            return None;
        }
        Some((b1 - b0) as f64 / ((t1 - t0) as f64 / 1000.0))
    }
}

/// A gradient submission to one shard, in whatever wire format the worker
/// encoded ([`ShardGrad`]). Full-dimension payloads (dense, int8) are
/// shared across all shard messages of one submission — each shard reads
/// its slice and drops its handle so the worker can recycle the buffer;
/// sparse payloads arrive pre-split per shard with local indices.
pub struct ShardMsg {
    pub worker: usize,
    /// Parameter version of this shard the gradient was computed against.
    pub base_version: u64,
    /// Training loss observed on the mini-batch (feeds the adaptive
    /// controller; telemetry otherwise).
    pub loss: f32,
    pub grad: ShardGrad,
    /// Trace stamp: when this submission was enqueued on the shard
    /// channel, in nanoseconds on the run clock's timebase (in-process
    /// workers stamp with their `Clock`; the serve frontends stamp with
    /// the trace ring's epoch, which shares the run clock's anchor).
    /// `0` = unstamped (tracing off) — no queue span is recorded.
    pub enq_ns: u64,
}

/// What travels on a shard's channel: gradient submissions plus — under
/// elastic membership — join/leave control events. Membership events ride
/// the *same* per-shard FIFO as gradients so every shard observes one
/// totally ordered (gradient | membership) sequence and barrier
/// renormalization stays in lockstep across shards (DESIGN.md §2.7). On
/// the static path only `Grad` is ever sent, so the channel refactor is
/// behaviour-preserving.
pub enum ShardEvent {
    Grad(ShardMsg),
    /// Elastic: `worker` joined (or re-joined) the run.
    Join { worker: usize },
    /// Elastic: `worker` left — clean departure, crash, or eviction after
    /// a heartbeat timeout. Its slot reopens for late joiners.
    Leave { worker: usize },
}

/// Shard → worker reply. O(1): parameters travel through snapshot cells.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Reply {
    /// The shard's parameters changed: refresh from its snapshot cell
    /// (published version ≥ `version`).
    Updated { shard: usize, version: u64 },
    /// The shard's parameters did not change since `base_version`; keep
    /// your copy.
    Unchanged { shard: usize },
}

/// Server-side configuration, shared by all shard threads of a run.
#[derive(Clone)]
pub struct ServerConfig {
    pub policy: Policy,
    pub workers: usize,
    pub lr: f32,
    /// Storage precision of *published snapshots* (master weights and the
    /// update path stay f32). [`ParamDtype::F32`] — the default — keeps
    /// every existing path bitwise; the half formats halve snapshot memory
    /// and refresh bytes for big models (DESIGN.md §2.12).
    pub dtype: ParamDtype,
    /// Threshold cap; defaults to the worker count. Under `elastic` the
    /// effective cap additionally tracks live membership.
    pub k_max: Option<usize>,
    /// Sample the (t, K) / (t, version) trajectories at most this often.
    pub trace_interval: Duration,
    /// Elastic membership: renormalize `K(n)` and sync barriers to the
    /// live worker set as `Join`/`Leave` events arrive. Off (the default)
    /// reproduces the static-membership path bitwise.
    pub elastic: bool,
    /// Barrier-denominator floor under elastic membership (≥ 1).
    pub min_quorum: usize,
    /// Server-side aggregation mode (`mean` | `clip:<c>` | `trimmed:<f>` |
    /// `median`). `Mean` — the default — reproduces the historical
    /// sum-then-flush path bitwise; the robust modes are the Byzantine
    /// defenses of DESIGN.md §2.10.
    pub aggregate: AggregateMode,
    /// Invoked after every reply send with the destination worker id. The
    /// reactor frontend installs its wakeup hook here so acks leave within
    /// one loop iteration instead of a poll tick; `None` (in-process runs,
    /// the threaded frontend's blocking pumps) changes nothing.
    pub reply_notify: Option<Arc<dyn Fn(usize) + Send + Sync>>,
    /// Live ops-plane gauges. When set, each shard thread publishes its
    /// K / buffer / version / membership gauges here (relaxed stores) after
    /// every event; `None` costs nothing and changes nothing.
    pub status: Option<Arc<StatusBoard>>,
    /// Gradient-lifecycle flight recorder. When set, each shard thread
    /// records queue/accumulate/apply/flush-wait spans and flush &
    /// membership instants, stamped through `clock` so sim traces are
    /// deterministic; `None` (the default) costs one branch per event.
    pub trace: Option<Arc<TraceRing>>,
}

/// What one shard thread hands back when the run ends.
pub struct ShardReport {
    pub shard: usize,
    pub final_params: Vec<f32>,
    pub updates_total: u64,
    pub gradients_total: u64,
    pub flushes: u64,
    pub mean_staleness: f64,
    pub per_worker_grads: Vec<u64>,
    /// Submissions dropped at the boundary as non-finite (NaN/Inf).
    pub rejected: u64,
    /// Contributions scaled down by norm clipping (`--aggregate clip:<c>`;
    /// judged on this shard's slice norm, so shards may differ — shard 0
    /// is canonical in the merged report).
    pub clipped: u64,
    /// Wire bytes this shard's deliveries carried (its slice of shared
    /// full-dim payloads; its own entries of pre-split sparse ones).
    pub bytes_received: u64,
    pub k_trajectory: crate::util::stats::Series,
    pub version_trajectory: crate::util::stats::Series,
    /// Live worker count at each membership transition (empty on the
    /// static path).
    pub membership: crate::util::stats::Series,
    /// Membership transitions this shard applied.
    pub membership_epochs: u64,
    /// Snapshots this shard published into its cell.
    pub publishes: u64,
    /// Bytes those publishes copied into the snapshot pool (dirty blocks
    /// only — full dim × elem bytes would be the dense-copy cost).
    pub snapshot_bytes_published: u64,
}

/// The merged run-level report across all shards.
pub struct ServerReport {
    pub final_params: Vec<f32>,
    pub updates_total: u64,
    pub gradients_total: u64,
    pub flushes: u64,
    pub mean_staleness: f64,
    pub per_worker_grads: Vec<u64>,
    /// Non-finite submissions rejected at the boundary (shard 0's count).
    pub rejected: u64,
    /// Norm-clipped contributions (shard 0's count).
    pub clipped: u64,
    pub per_shard_updates: Vec<u64>,
    /// Total wire bytes received across all shards.
    pub bytes_received: u64,
    pub k_trajectory: crate::util::stats::Series,
    pub version_trajectory: crate::util::stats::Series,
    pub membership: crate::util::stats::Series,
    pub membership_epochs: u64,
    /// Snapshot publishes summed across all shards.
    pub publishes: u64,
    /// Snapshot-pool bytes copied by publishes, summed across all shards.
    pub snapshot_bytes_published: u64,
}

impl ServerReport {
    /// Merge server counters into a [`RunMetrics`].
    pub fn fill(&self, m: &mut RunMetrics) {
        m.gradients_total = self.gradients_total;
        m.updates_total = self.updates_total;
        m.flushes = self.flushes;
        m.mean_staleness = self.mean_staleness;
        m.per_worker_grads = self.per_worker_grads.clone();
        m.rejected_grads = self.rejected;
        m.clipped_grads = self.clipped;
        m.shards = self.per_shard_updates.len();
        m.per_shard_updates = self.per_shard_updates.clone();
        m.bytes_received = self.bytes_received;
        m.k_trajectory = self.k_trajectory.clone();
        m.version_trajectory = self.version_trajectory.clone();
        m.membership = self.membership.clone();
        m.membership_epochs = self.membership_epochs;
        m.snapshot_publishes = self.publishes;
        m.snapshot_bytes_published = self.snapshot_bytes_published;
        m.final_params = self.final_params.clone();
    }
}

/// Merge per-shard reports. Shard 0 is the canonical source for the logical
/// counters and trajectories: all shards observe the same set of arrivals,
/// and for count-triggered policies their counters can differ only by
/// messages in flight at shutdown (the adaptive policy may additionally
/// drift transiently across shards under threading — see the module docs;
/// `per_shard_updates` exposes the spread). Final parameters are
/// concatenated in shard order.
pub fn merge_reports(layout: &ShardLayout, mut reports: Vec<ShardReport>) -> ServerReport {
    assert_eq!(reports.len(), layout.shards());
    reports.sort_by_key(|r| r.shard);
    let mut final_params = Vec::with_capacity(layout.dim());
    for r in &reports {
        final_params.extend_from_slice(&r.final_params);
    }
    let per_shard_updates = reports.iter().map(|r| r.updates_total).collect();
    let bytes_received = reports.iter().map(|r| r.bytes_received).sum();
    // Snapshot-pool traffic is physical per-shard work (unlike the logical
    // counters, which are lockstep-identical): sum it.
    let publishes = reports.iter().map(|r| r.publishes).sum();
    let snapshot_bytes_published = reports.iter().map(|r| r.snapshot_bytes_published).sum();
    let first = &reports[0];
    ServerReport {
        updates_total: first.updates_total,
        gradients_total: first.gradients_total,
        flushes: first.flushes,
        mean_staleness: first.mean_staleness,
        per_worker_grads: first.per_worker_grads.clone(),
        rejected: first.rejected,
        clipped: first.clipped,
        k_trajectory: first.k_trajectory.clone(),
        version_trajectory: first.version_trajectory.clone(),
        membership: first.membership.clone(),
        membership_epochs: first.membership_epochs,
        per_shard_updates,
        bytes_received,
        publishes,
        snapshot_bytes_published,
        final_params,
    }
}

/// Run one shard's server loop until every worker sender disconnects.
///
/// Call on a dedicated thread. `range` is this shard's slice of the flat θ,
/// `init` the corresponding initial values, `reply_txs[i]` worker i's reply
/// channel (shared with the other shards), `stop` the trainer's shutdown
/// flag (used to release barrier-blocked workers so they can observe it)
/// and `clock` the run clock trace timestamps are read from.
#[allow(clippy::too_many_arguments)]
pub fn run_shard(
    shard: usize,
    range: Range<usize>,
    init: Vec<f32>,
    cell: Arc<SnapshotCell>,
    cfg: &ServerConfig,
    grad_rx: Receiver<ShardEvent>,
    reply_txs: Vec<Sender<Reply>>,
    stop: &AtomicBool,
    clock: &dyn Clock,
) -> ShardReport {
    debug_assert_eq!(init.len(), range.len());
    let mut store = ParamStore::with_cell_dtype(init, cfg.lr, cell, cfg.dtype);
    // Publish-instant bookkeeping (tracing only): counters as of the last
    // event, so each published snapshot yields one instant with the bytes
    // that publish actually copied.
    let mut last_publishes = store.publishes();
    let mut last_pub_bytes = store.snapshot_bytes_published();
    let mut agg = Aggregator::new(cfg.policy.clone(), range.len(), cfg.workers)
        .with_aggregate(cfg.aggregate.clone());
    if let Some(k) = cfg.k_max {
        agg = agg.with_k_max(k);
    }
    if cfg.elastic {
        // Every slot starts live (the TCP frontend reports attaches as
        // idempotent joins); departures and re-joins arrive as events.
        agg = agg.with_elastic(cfg.workers, cfg.min_quorum);
    }
    // Workers blocked at a barrier, released on flush (or stop). The
    // second element is the trace park stamp (ns; 0 when tracing is off)
    // so the release can record each worker's flush-wait span.
    let mut blocked: Vec<(usize, u64)> = Vec::with_capacity(cfg.workers);
    let mut per_worker = vec![0u64; cfg.workers];
    let mut k_traj = crate::util::stats::Series::new();
    let mut v_traj = crate::util::stats::Series::new();
    let mut membership = crate::util::stats::Series::new();
    // `None` = no trace yet, so the first arrival always records one.
    let mut last_trace: Option<Duration> = None;
    let mut released_on_stop = false;
    let mut bytes_received = 0u64;
    let mut rejected = 0u64;

    loop {
        match grad_rx.recv_timeout(Duration::from_millis(20)) {
            Ok(ShardEvent::Join { worker }) => {
                if cfg.elastic && agg.member_join(worker) {
                    membership.push(clock.now().as_secs_f64(), agg.live() as f64);
                    if let Some(tr) = &cfg.trace {
                        tr.instant(
                            Stage::Join,
                            worker as u32,
                            shard as u32,
                            clock.now().as_nanos() as u64,
                            agg.membership_epoch(),
                            agg.live() as u64,
                        );
                    }
                }
            }
            Ok(ShardEvent::Leave { worker }) => {
                if cfg.elastic {
                    let (changed, flushed) = agg.member_leave(&mut store, worker);
                    if changed {
                        // The departed worker is never waited on again:
                        // out of the barrier denominator, out of the
                        // blocked list.
                        blocked.retain(|&(w, _)| w != worker);
                        membership.push(clock.now().as_secs_f64(), agg.live() as f64);
                        if let Some(tr) = &cfg.trace {
                            tr.instant(
                                Stage::Leave,
                                worker as u32,
                                shard as u32,
                                clock.now().as_nanos() as u64,
                                agg.membership_epoch(),
                                agg.live() as u64,
                            );
                        }
                    }
                    if let Some(Outcome::Flushed { count, k_at_flush, .. }) = flushed {
                        if shard == 0 {
                            log_debug!(
                                "server",
                                "departure of worker {worker} released the barrier: \
                                 flush of {count} at K={k_at_flush}, v={}",
                                store.version()
                            );
                        }
                        let updated = Reply::Updated {
                            shard,
                            version: store.version(),
                        };
                        let rel_ns = cfg
                            .trace
                            .as_ref()
                            .map_or(0, |_| clock.now().as_nanos() as u64);
                        if let Some(tr) = &cfg.trace {
                            tr.instant(
                                Stage::Flush,
                                worker as u32,
                                shard as u32,
                                rel_ns,
                                store.version(),
                                count as u64,
                            );
                        }
                        for (w, park) in blocked.drain(..) {
                            if let Some(tr) = &cfg.trace {
                                tr.span(
                                    Stage::FlushWait,
                                    w as u32,
                                    shard as u32,
                                    park,
                                    rel_ns,
                                    per_worker[w],
                                    store.version(),
                                );
                            }
                            send(&reply_txs[w], updated, &cfg.reply_notify, w);
                        }
                        k_traj.push(clock.now().as_secs_f64(), agg.current_k() as f64);
                    }
                }
            }
            Ok(ShardEvent::Grad(msg)) => {
                let ShardMsg {
                    worker,
                    base_version,
                    loss,
                    grad,
                    enq_ns,
                } = msg;
                per_worker[worker] += 1;
                bytes_received += grad.wire_bytes(range.len()) as u64;
                // Dequeue stamp, read once and reused below (tracing off:
                // no clock read, no ring touch — just these branches).
                let t_deq = cfg
                    .trace
                    .as_ref()
                    .map_or(0, |_| clock.now().as_nanos() as u64);
                if let Some(tr) = &cfg.trace {
                    if enq_ns != 0 {
                        tr.span(
                            Stage::Queue,
                            worker as u32,
                            shard as u32,
                            enq_ns,
                            t_deq,
                            per_worker[worker],
                            grad.wire_bytes(range.len()) as u64,
                        );
                    }
                }
                let staleness = store.version().saturating_sub(base_version);
                let finite = grad.is_finite();
                if shard == 0 {
                    if let Some(board) = &cfg.status {
                        // Per-worker ops gauges: shard 0 writes for the
                        // run (all shards see the same arrivals).
                        if let Some(ws) = board.workers.get(worker) {
                            ws.grads.fetch_add(1, Ordering::Relaxed);
                            ws.stale_sum.fetch_add(staleness, Ordering::Relaxed);
                            ws.stale_max.fetch_max(staleness, Ordering::Relaxed);
                            ws.stale_hist[stale_bucket(staleness)]
                                .fetch_add(1, Ordering::Relaxed);
                            if !finite {
                                ws.rejected.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if !finite {
                    // Poisoned payload (NaN/Inf anywhere in it): drop the
                    // submission before it can touch the aggregation plane.
                    // `is_finite` inspects the *whole* payload, not this
                    // shard's slice, so every shard reaches the same
                    // verdict and lockstep is preserved. The submitter
                    // still gets a normal reply — a dropped gradient must
                    // never hang or kill anything (DESIGN.md §2.10).
                    rejected += 1;
                    drop(grad);
                    let reply = if base_version == store.version() {
                        Reply::Unchanged { shard }
                    } else {
                        Reply::Updated {
                            shard,
                            version: store.version(),
                        }
                    };
                    send(&reply_txs[worker], reply, &cfg.reply_notify, worker);
                } else {
                    let outcome = agg.on_gradient_view(
                        &mut store,
                        grad.view(range.clone()),
                        worker,
                        base_version,
                        loss,
                    );
                    // Release the shared payload buffer before replying so
                    // the worker's `Arc::try_unwrap` recycling never races
                    // a shard.
                    drop(grad);
                    // Post-aggregation stamp for the accumulate/apply span.
                    let t_agg = cfg
                        .trace
                        .as_ref()
                        .map_or(0, |_| clock.now().as_nanos() as u64);
                    let updated = Reply::Updated {
                        shard,
                        version: store.version(),
                    };
                    match outcome {
                        Outcome::AppliedNow => {
                            if let Some(tr) = &cfg.trace {
                                tr.span(
                                    Stage::Apply,
                                    worker as u32,
                                    shard as u32,
                                    t_deq,
                                    t_agg,
                                    per_worker[worker],
                                    store.version(),
                                );
                            }
                            send(&reply_txs[worker], updated, &cfg.reply_notify, worker);
                        }
                        Outcome::Buffered => {
                            if let Some(tr) = &cfg.trace {
                                tr.span(
                                    Stage::Accumulate,
                                    worker as u32,
                                    shard as u32,
                                    t_deq,
                                    t_agg,
                                    per_worker[worker],
                                    agg.buffered() as u64,
                                );
                            }
                            // θ frozen since the last flush: if the worker
                            // already holds this version there is nothing
                            // to do.
                            if base_version == store.version() {
                                send(
                                    &reply_txs[worker],
                                    Reply::Unchanged { shard },
                                    &cfg.reply_notify,
                                    worker,
                                );
                            } else {
                                send(&reply_txs[worker], updated, &cfg.reply_notify, worker);
                            }
                        }
                        Outcome::BufferedBlocked => {
                            if let Some(tr) = &cfg.trace {
                                tr.span(
                                    Stage::Accumulate,
                                    worker as u32,
                                    shard as u32,
                                    t_deq,
                                    t_agg,
                                    per_worker[worker],
                                    agg.buffered() as u64,
                                );
                            }
                            blocked.push((worker, t_agg));
                        }
                        Outcome::Flushed { count, k_at_flush, .. } => {
                            if shard == 0 {
                                log_debug!(
                                    "server",
                                    "flush of {count} gradients at K={k_at_flush}, v={}",
                                    store.version()
                                );
                            }
                            if let Some(tr) = &cfg.trace {
                                tr.span(
                                    Stage::Apply,
                                    worker as u32,
                                    shard as u32,
                                    t_deq,
                                    t_agg,
                                    per_worker[worker],
                                    store.version(),
                                );
                                tr.instant(
                                    Stage::Flush,
                                    worker as u32,
                                    shard as u32,
                                    t_agg,
                                    store.version(),
                                    count as u64,
                                );
                            }
                            send(&reply_txs[worker], updated, &cfg.reply_notify, worker);
                            for (w, park) in blocked.drain(..) {
                                if let Some(tr) = &cfg.trace {
                                    tr.span(
                                        Stage::FlushWait,
                                        w as u32,
                                        shard as u32,
                                        park,
                                        t_agg,
                                        per_worker[w],
                                        store.version(),
                                    );
                                }
                                send(&reply_txs[w], updated, &cfg.reply_notify, w);
                            }
                            k_traj.push(clock.now().as_secs_f64(), agg.current_k() as f64);
                        }
                    }
                }
                let now = clock.now();
                if last_trace.map_or(true, |lt| now.saturating_sub(lt) >= cfg.trace_interval) {
                    last_trace = Some(now);
                    v_traj.push(now.as_secs_f64(), store.version() as f64);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        // One instant per snapshot publish, stamped after the event that
        // produced it (aux = pool bytes that publish copied — dirty blocks
        // only on the delta path). Tracing off: two integer loads.
        if let Some(tr) = &cfg.trace {
            let pubs = store.publishes();
            if pubs != last_publishes {
                let bytes = store.snapshot_bytes_published();
                tr.instant(
                    Stage::Publish,
                    0,
                    shard as u32,
                    clock.now().as_nanos() as u64,
                    store.version(),
                    bytes - last_pub_bytes,
                );
                last_publishes = pubs;
                last_pub_bytes = bytes;
            }
        }
        if let Some(board) = &cfg.status {
            let st = &board.shards[shard];
            st.k.store(agg.current_k() as u64, Ordering::Relaxed);
            st.buffered.store(agg.buffered() as u64, Ordering::Relaxed);
            st.version.store(store.version(), Ordering::Relaxed);
            st.live.store(agg.live() as u64, Ordering::Relaxed);
            st.epoch.store(agg.membership_epoch(), Ordering::Relaxed);
            st.snap_publishes.store(store.publishes(), Ordering::Relaxed);
            st.snap_bytes
                .store(store.snapshot_bytes_published(), Ordering::Relaxed);
        }
        if stop.load(Ordering::Relaxed) && !released_on_stop {
            // Release barrier-blocked workers so they can see the stop flag.
            let reply = Reply::Updated {
                shard,
                version: store.version(),
            };
            let rel_ns = cfg
                .trace
                .as_ref()
                .map_or(0, |_| clock.now().as_nanos() as u64);
            for (w, park) in blocked.drain(..) {
                if let Some(tr) = &cfg.trace {
                    tr.span(
                        Stage::FlushWait,
                        w as u32,
                        shard as u32,
                        park,
                        rel_ns,
                        per_worker[w],
                        store.version(),
                    );
                }
                send(&reply_txs[w], reply, &cfg.reply_notify, w);
            }
            released_on_stop = true;
        }
    }

    // Apply whatever is still buffered so no gradient is silently dropped.
    agg.drain(&mut store);
    store.publish();
    if let Some(tr) = &cfg.trace {
        if store.publishes() != last_publishes {
            tr.instant(
                Stage::Publish,
                0,
                shard as u32,
                clock.now().as_nanos() as u64,
                store.version(),
                store.snapshot_bytes_published() - last_pub_bytes,
            );
        }
    }
    v_traj.push(clock.now().as_secs_f64(), store.version() as f64);

    let stats = &agg.stats;
    ShardReport {
        shard,
        updates_total: store.version(),
        gradients_total: stats.arrivals,
        flushes: stats.flushes,
        mean_staleness: if stats.arrivals > 0 {
            stats.staleness_sum / stats.arrivals as f64
        } else {
            0.0
        },
        per_worker_grads: per_worker,
        rejected,
        clipped: stats.clipped,
        bytes_received,
        k_trajectory: k_traj,
        version_trajectory: v_traj,
        membership,
        membership_epochs: agg.membership_epoch(),
        publishes: store.publishes(),
        snapshot_bytes_published: store.snapshot_bytes_published(),
        // Master weights: always f32, whatever the snapshot dtype.
        final_params: store.theta().to_vec(),
    }
}

fn send(
    tx: &Sender<Reply>,
    reply: Reply,
    notify: &Option<Arc<dyn Fn(usize) + Send + Sync>>,
    worker: usize,
) {
    // A send error means the worker already exited (shutdown race): fine.
    let _ = tx.send(reply);
    if let Some(n) = notify {
        n(worker);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::threshold::Schedule;
    use std::sync::mpsc;

    /// Drive a single shard server with scripted events.
    fn run_scripted_events(
        policy: Policy,
        workers: usize,
        elastic: bool,
        events: Vec<ShardEvent>,
    ) -> (ShardReport, Vec<Vec<Reply>>, Arc<SnapshotCell>) {
        run_scripted_cfg(policy, workers, elastic, AggregateMode::Mean, events)
    }

    /// [`run_scripted_events`] with an explicit aggregation mode.
    fn run_scripted_cfg(
        policy: Policy,
        workers: usize,
        elastic: bool,
        aggregate: AggregateMode,
        events: Vec<ShardEvent>,
    ) -> (ShardReport, Vec<Vec<Reply>>, Arc<SnapshotCell>) {
        let (gtx, grx) = mpsc::channel();
        let mut rtxs = Vec::new();
        let mut rrxs = Vec::new();
        for _ in 0..workers {
            let (tx, rx) = mpsc::channel();
            rtxs.push(tx);
            rrxs.push(rx);
        }
        let stop = AtomicBool::new(false);
        let cfg = ServerConfig {
            policy,
            workers,
            lr: 0.1,
            dtype: ParamDtype::F32,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            elastic,
            min_quorum: 1,
            aggregate,
            reply_notify: None,
            status: None,
            trace: None,
        };
        for ev in events {
            gtx.send(ev).unwrap();
        }
        drop(gtx);
        let cell = Arc::new(SnapshotCell::new(vec![0.0; 2]));
        let clock = crate::coordinator::clock::RealClock::start();
        let report = run_shard(
            0,
            0..2,
            vec![0.0; 2],
            Arc::clone(&cell),
            &cfg,
            grx,
            rtxs,
            &stop,
            &clock,
        );
        let replies: Vec<Vec<Reply>> = rrxs.into_iter().map(|rx| rx.try_iter().collect()).collect();
        (report, replies, cell)
    }

    /// Drive a single shard server with scripted gradient messages (the
    /// static path: every event is a `Grad`).
    fn run_scripted(
        policy: Policy,
        workers: usize,
        msgs: Vec<ShardMsg>,
    ) -> (ShardReport, Vec<Vec<Reply>>, Arc<SnapshotCell>) {
        run_scripted_events(
            policy,
            workers,
            false,
            msgs.into_iter().map(ShardEvent::Grad).collect(),
        )
    }

    fn msg(worker: usize, v: u64) -> ShardMsg {
        ShardMsg {
            worker,
            base_version: v,
            loss: 1.0,
            grad: ShardGrad::Dense(Arc::new(vec![1.0, 1.0])),
            enq_ns: 0,
        }
    }

    #[test]
    fn async_replies_updated_every_time() {
        let (report, replies, cell) =
            run_scripted(Policy::Async, 2, vec![msg(0, 0), msg(1, 1), msg(0, 2)]);
        assert_eq!(report.gradients_total, 3);
        assert_eq!(report.updates_total, 3);
        assert_eq!(replies[0].len(), 2);
        assert_eq!(replies[1].len(), 1);
        for r in replies.iter().flatten() {
            assert!(matches!(r, Reply::Updated { .. }));
        }
        // The cell carries the final parameters without any reply copies.
        let snap = cell.load();
        assert_eq!(snap.version, 3);
        assert!((snap.theta()[0] + 0.3).abs() < 1e-6);
    }

    #[test]
    fn sync_defers_until_barrier() {
        let (report, replies, cell) =
            run_scripted(Policy::Sync, 3, vec![msg(0, 0), msg(1, 0), msg(2, 0)]);
        assert_eq!(report.updates_total, 1);
        assert_eq!(report.flushes, 1);
        // every worker got exactly one Updated reply carrying version 1
        for r in &replies {
            assert_eq!(r.len(), 1);
            assert_eq!(r[0], Reply::Updated { shard: 0, version: 1 });
        }
        // mean grad = 1 → θ = -0.1, readable via the snapshot cell
        assert!((cell.load().theta()[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn hybrid_frozen_theta_replies_unchanged() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 3 },
            strict: false,
        };
        let (report, replies, _) = run_scripted(policy, 3, vec![msg(0, 0), msg(1, 0), msg(2, 0)]);
        assert_eq!(report.flushes, 1);
        assert_eq!(replies[0][0], Reply::Unchanged { shard: 0 });
        assert_eq!(replies[1][0], Reply::Unchanged { shard: 0 });
        assert_eq!(replies[2][0], Reply::Updated { shard: 0, version: 1 });
    }

    #[test]
    fn stale_submitter_is_told_to_refresh_while_buffering() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 4 },
            strict: false,
        };
        // First arrival flushes nothing; the second pretends to be stale
        // (base_version far behind) and must be told to refresh.
        let (_, replies, _) = run_scripted(policy, 2, vec![msg(0, 0), msg(1, 5)]);
        assert_eq!(replies[0][0], Reply::Unchanged { shard: 0 });
        assert_eq!(replies[1][0], Reply::Updated { shard: 0, version: 0 });
    }

    #[test]
    fn leftover_buffer_drained_at_shutdown() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 10 },
            strict: false,
        };
        let (report, _, _) = run_scripted(policy, 2, vec![msg(0, 0), msg(1, 0)]);
        // no flush during the run, but drain applies the 2 buffered grads
        assert_eq!(report.updates_total, 1);
        assert_eq!(report.gradients_total, 2);
        assert!((report.final_params[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn grad_buffers_are_released_for_recycling() {
        let shared = Arc::new(vec![1.0f32, 1.0]);
        let (report, _, _) = run_scripted(
            Policy::Async,
            1,
            vec![ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 1.0,
                grad: ShardGrad::Dense(Arc::clone(&shared)),
                enq_ns: 0,
            }],
        );
        assert_eq!(report.gradients_total, 1);
        // The shard dropped its clone before replying: ours is the last.
        assert_eq!(Arc::strong_count(&shared), 1);
        // Dense wire accounting: one 2-coordinate f32 slice.
        assert_eq!(report.bytes_received, 8);
    }

    #[test]
    fn sparse_submission_aggregates_and_counts_wire_bytes() {
        use crate::coordinator::compress::SparseGrad;
        // A pre-split sparse payload (local indices) applies exactly like
        // its dense reconstruction and is billed at 8 bytes per entry.
        let sparse = SparseGrad {
            dim: 2,
            idx: vec![1],
            val: vec![2.0],
        };
        let (report, replies, cell) = run_scripted(
            Policy::Async,
            1,
            vec![ShardMsg {
                worker: 0,
                base_version: 0,
                loss: 1.0,
                grad: ShardGrad::Sparse(Arc::new(sparse)),
                enq_ns: 0,
            }],
        );
        assert_eq!(report.updates_total, 1);
        assert_eq!(report.bytes_received, 8);
        assert!(matches!(replies[0][0], Reply::Updated { .. }));
        let snap = cell.load();
        assert_eq!(snap.theta()[0], 0.0);
        assert!((snap.theta()[1] + 0.2).abs() < 1e-6); // θ₁ −= 0.1·2.0
    }

    #[test]
    fn stop_releases_blocked_workers() {
        let (gtx, grx) = mpsc::channel();
        let (rtx, rrx) = mpsc::channel();
        let (rtx2, _rrx2) = mpsc::channel();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = ServerConfig {
            policy: Policy::Sync,
            workers: 2,
            lr: 0.1,
            dtype: ParamDtype::F32,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            elastic: false,
            min_quorum: 1,
            aggregate: AggregateMode::Mean,
            reply_notify: None,
            status: None,
            trace: None,
        };
        let stop2 = Arc::clone(&stop);
        let cell = Arc::new(SnapshotCell::new(vec![0.0]));
        let cell2 = Arc::clone(&cell);
        let h = std::thread::spawn(move || {
            let clock = crate::coordinator::clock::RealClock::start();
            run_shard(
                0,
                0..1,
                vec![0.0],
                cell2,
                &cfg,
                grx,
                vec![rtx, rtx2],
                &stop2,
                &clock,
            )
        });
        // worker 0 submits and would block forever (worker 1 never arrives)
        gtx.send(ShardEvent::Grad(ShardMsg {
            worker: 0,
            base_version: 0,
            loss: 0.0,
            grad: ShardGrad::Dense(Arc::new(vec![1.0])),
            enq_ns: 0,
        }))
        .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        assert!(rrx.try_recv().is_err(), "should be blocked at barrier");
        stop.store(true, Ordering::Relaxed);
        let reply = rrx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(matches!(reply, Reply::Updated { .. }));
        drop(gtx);
        let report = h.join().unwrap();
        // the lone buffered gradient was drained into one update
        assert_eq!(report.updates_total, 1);
    }

    #[test]
    fn non_finite_submission_is_rejected_not_fatal() {
        let bad = ShardMsg {
            worker: 0,
            base_version: 0,
            loss: 1.0,
            grad: ShardGrad::Dense(Arc::new(vec![f32::NAN, 1.0])),
            enq_ns: 0,
        };
        let (report, replies, cell) = run_scripted(Policy::Async, 1, vec![bad, msg(0, 0)]);
        // The poisoned payload was dropped at the boundary: only the good
        // gradient is counted or moves θ, and the shard thread survived.
        assert_eq!(report.rejected, 1);
        assert_eq!(report.gradients_total, 1);
        assert_eq!(report.updates_total, 1);
        // The rejected submitter still got a reply so it never hangs.
        assert_eq!(replies[0].len(), 2);
        assert_eq!(replies[0][0], Reply::Unchanged { shard: 0 });
        assert!((cell.load().theta()[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn trimmed_flush_shrugs_off_an_attacker_on_the_server_path() {
        // Sync barrier of 4; worker 3 submits a hugely negative gradient.
        // trimmed:0.25 drops one contribution per coordinate-wise tail, so
        // the flush applies the honest mean: θ = −0.1·1 per coordinate.
        let poisoned = ShardMsg {
            worker: 3,
            base_version: 0,
            loss: 1.0,
            grad: ShardGrad::Dense(Arc::new(vec![-1000.0, -1000.0])),
            enq_ns: 0,
        };
        let (report, _, cell) = run_scripted_cfg(
            Policy::Sync,
            4,
            false,
            AggregateMode::Trimmed(0.25),
            vec![msg(0, 0), msg(1, 0), msg(2, 0), poisoned]
                .into_iter()
                .map(ShardEvent::Grad)
                .collect(),
        );
        assert_eq!(report.flushes, 1);
        let snap = cell.load();
        assert!((snap.theta()[0] + 0.1).abs() < 1e-6, "got {}", snap.theta()[0]);
        assert!((snap.theta()[1] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn status_board_tracks_per_worker_staleness_and_rejections() {
        let (gtx, grx) = mpsc::channel();
        let mut rtxs = Vec::new();
        let mut rrxs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = mpsc::channel();
            rtxs.push(tx);
            rrxs.push(rx);
        }
        let board = Arc::new(StatusBoard::with_workers(1, 2));
        let cfg = ServerConfig {
            policy: Policy::Async,
            workers: 2,
            lr: 0.1,
            dtype: ParamDtype::F32,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            elastic: false,
            min_quorum: 1,
            aggregate: AggregateMode::Mean,
            reply_notify: None,
            status: Some(Arc::clone(&board)),
            trace: None,
        };
        gtx.send(ShardEvent::Grad(msg(0, 0))).unwrap();
        gtx.send(ShardEvent::Grad(msg(0, 1))).unwrap();
        // worker 1's gradient is 2 versions stale when it arrives
        gtx.send(ShardEvent::Grad(msg(1, 0))).unwrap();
        gtx.send(ShardEvent::Grad(ShardMsg {
            worker: 1,
            base_version: 3,
            loss: 1.0,
            grad: ShardGrad::Dense(Arc::new(vec![f32::INFINITY, 0.0])),
            enq_ns: 0,
        }))
        .unwrap();
        drop(gtx);
        let stop = AtomicBool::new(false);
        let cell = Arc::new(SnapshotCell::new(vec![0.0; 2]));
        let clock = crate::coordinator::clock::RealClock::start();
        let report = run_shard(0, 0..2, vec![0.0; 2], cell, &cfg, grx, rtxs, &stop, &clock);
        assert_eq!(report.rejected, 1);
        let w0 = &board.workers[0];
        let w1 = &board.workers[1];
        assert_eq!(w0.grads.load(Ordering::Relaxed), 2);
        assert_eq!(w0.stale_sum.load(Ordering::Relaxed), 0);
        assert_eq!(w0.rejected.load(Ordering::Relaxed), 0);
        assert_eq!(w1.grads.load(Ordering::Relaxed), 2);
        assert_eq!(w1.stale_sum.load(Ordering::Relaxed), 2);
        assert_eq!(w1.stale_max.load(Ordering::Relaxed), 2);
        assert_eq!(w1.rejected.load(Ordering::Relaxed), 1);
        // Histogram: w0's two arrivals were staleness 0; w1 saw one
        // staleness-0 and one staleness-2 arrival (bucket 2 = "2-3").
        assert_eq!(w0.stale_hist[0].load(Ordering::Relaxed), 2);
        assert_eq!(w1.stale_hist[0].load(Ordering::Relaxed), 1);
        assert_eq!(w1.stale_hist[2].load(Ordering::Relaxed), 1);
        assert_eq!(stale_bucket(0), 0);
        assert_eq!(stale_bucket(1), 1);
        assert_eq!(stale_bucket(7), 3);
        assert_eq!(stale_bucket(16), 5);
        assert_eq!(stale_bucket(u64::MAX), 5);
        drop(rrxs);
    }

    #[test]
    fn trace_ring_records_the_shard_side_lifecycle() {
        use crate::util::trace::TraceRing;
        let (gtx, grx) = mpsc::channel();
        let mut rtxs = Vec::new();
        let mut rrxs = Vec::new();
        for _ in 0..2 {
            let (tx, rx) = mpsc::channel();
            rtxs.push(tx);
            rrxs.push(rx);
        }
        let ring = Arc::new(TraceRing::new(256));
        let cfg = ServerConfig {
            policy: Policy::Sync,
            workers: 2,
            lr: 0.1,
            dtype: ParamDtype::F32,
            k_max: None,
            trace_interval: Duration::from_millis(1),
            elastic: false,
            min_quorum: 1,
            aggregate: AggregateMode::Mean,
            reply_notify: None,
            status: None,
            trace: Some(Arc::clone(&ring)),
        };
        // Stamped submissions: worker 0 blocks at the barrier, worker 1
        // completes it (flush). enq_ns = 1 (any nonzero stamp works).
        for w in 0..2 {
            gtx.send(ShardEvent::Grad(ShardMsg {
                worker: w,
                base_version: 0,
                loss: 1.0,
                grad: ShardGrad::Dense(Arc::new(vec![1.0, 1.0])),
                enq_ns: 1,
            }))
            .unwrap();
        }
        drop(gtx);
        let stop = AtomicBool::new(false);
        let cell = Arc::new(SnapshotCell::new(vec![0.0; 2]));
        let clock = crate::coordinator::clock::RealClock::start();
        let report = run_shard(0, 0..2, vec![0.0; 2], cell, &cfg, grx, rtxs, &stop, &clock);
        assert_eq!(report.flushes, 1);
        let dump = ring.drain();
        let count = |st: Stage| dump.events.iter().filter(|e| e.stage == st).count();
        // one queue span per stamped submission
        assert_eq!(count(Stage::Queue), 2);
        // worker 0 accumulated + waited for the flush worker 1 triggered
        assert_eq!(count(Stage::Accumulate), 1);
        assert_eq!(count(Stage::FlushWait), 1);
        assert_eq!(count(Stage::Apply), 1);
        assert_eq!(count(Stage::Flush), 1);
        let fw = dump
            .events
            .iter()
            .find(|e| e.stage == Stage::FlushWait)
            .unwrap();
        assert_eq!(fw.worker, 0);
        // live histograms saw the spans too
        let sums = ring.stage_summaries();
        assert_eq!(sums[Stage::Queue as usize].count, 2);
        assert_eq!(sums[Stage::Apply as usize].count, 1);
        drop(rrxs);
    }

    #[test]
    fn merge_concatenates_shard_params() {
        let layout = ShardLayout::new(4, 2);
        let mk = |shard: usize, params: Vec<f32>| ShardReport {
            shard,
            final_params: params,
            updates_total: 7,
            gradients_total: 10,
            flushes: 2,
            mean_staleness: 0.5,
            per_worker_grads: vec![5, 5],
            rejected: 1,
            clipped: 2,
            bytes_received: 40,
            k_trajectory: crate::util::stats::Series::new(),
            version_trajectory: crate::util::stats::Series::new(),
            membership: crate::util::stats::Series::new(),
            membership_epochs: 0,
            publishes: 7,
            snapshot_bytes_published: 16,
        };
        // Deliberately out of order: merge must sort by shard id.
        let merged = merge_reports(
            &layout,
            vec![mk(1, vec![3.0, 4.0]), mk(0, vec![1.0, 2.0])],
        );
        assert_eq!(merged.final_params, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(merged.updates_total, 7);
        assert_eq!(merged.per_shard_updates, vec![7, 7]);
        // bytes-on-wire sum across shards, not shard 0 only
        assert_eq!(merged.bytes_received, 80);
        // snapshot-pool traffic sums across shards too
        assert_eq!(merged.publishes, 14);
        assert_eq!(merged.snapshot_bytes_published, 32);
        // rejection/clip counters are shard-0 canonical like the rest
        assert_eq!(merged.rejected, 1);
        assert_eq!(merged.clipped, 2);
    }

    #[test]
    fn leave_event_renormalizes_the_barrier_and_releases_blocked_workers() {
        // Sync with 3 slots: two workers contribute and block; the third
        // is declared dead. Under elastic membership the departure shrinks
        // the barrier to 2, the buffered pair flushes, and both blocked
        // workers are released with the fresh version.
        let (report, replies, cell) = run_scripted_events(
            Policy::Sync,
            3,
            true,
            vec![
                ShardEvent::Grad(msg(0, 0)),
                ShardEvent::Grad(msg(1, 0)),
                ShardEvent::Leave { worker: 2 },
            ],
        );
        assert_eq!(report.flushes, 1);
        assert_eq!(report.updates_total, 1);
        assert_eq!(replies[0], vec![Reply::Updated { shard: 0, version: 1 }]);
        assert_eq!(replies[1], vec![Reply::Updated { shard: 0, version: 1 }]);
        assert!(replies[2].is_empty(), "the departed worker gets no reply");
        assert!((cell.load().theta()[0] + 0.1).abs() < 1e-6);
        // Membership telemetry recorded the transition.
        assert_eq!(report.membership_epochs, 1);
        assert_eq!(report.membership.v, vec![2.0]);
    }

    #[test]
    fn departed_worker_is_dropped_from_the_blocked_list() {
        // Worker 1 contributes and blocks, then is evicted; worker 0's
        // contribution now meets the renormalized barrier alone (live =
        // 1). Worker 1 must not receive the release reply.
        let (report, replies, _) = run_scripted_events(
            Policy::Sync,
            2,
            true,
            vec![
                ShardEvent::Grad(msg(1, 0)),
                ShardEvent::Leave { worker: 1 },
                ShardEvent::Grad(msg(0, 0)),
            ],
        );
        // The leave flushes worker 1's lone buffered gradient (quorum 1 is
        // already met by its own contribution), then worker 0's arrival
        // flushes immediately at the barrier of one.
        assert_eq!(report.flushes, 2);
        assert!(replies[1].is_empty(), "evicted worker must not be waited on or replied to");
        assert_eq!(replies[0].len(), 1);
        assert!(matches!(replies[0][0], Reply::Updated { version: 2, .. }));
    }

    #[test]
    fn rejoin_restores_the_barrier_denominator() {
        // Leave then re-join: the barrier is back to 2, so a single
        // contribution blocks again.
        let (report, replies, _) = run_scripted_events(
            Policy::Sync,
            2,
            true,
            vec![
                ShardEvent::Leave { worker: 1 },
                ShardEvent::Join { worker: 1 },
                ShardEvent::Grad(msg(0, 0)),
                ShardEvent::Grad(msg(1, 0)),
            ],
        );
        assert_eq!(report.flushes, 1);
        assert_eq!(report.membership_epochs, 2);
        assert_eq!(report.membership.v, vec![1.0, 2.0]);
        assert_eq!(replies[0].len(), 1);
        assert_eq!(replies[1].len(), 1);
    }

    #[test]
    fn static_path_ignores_membership_events() {
        // elastic off: Join/Leave events are inert, the barrier stays at
        // the launch-time worker count and blocked workers stay blocked
        // until the end-of-run drain.
        let (report, replies, _) = run_scripted_events(
            Policy::Sync,
            3,
            false,
            vec![
                ShardEvent::Grad(msg(0, 0)),
                ShardEvent::Grad(msg(1, 0)),
                ShardEvent::Leave { worker: 2 },
            ],
        );
        // No barrier release during the run: the only flush is the
        // shutdown drain, and nobody was replied to before it.
        assert_eq!(report.flushes, 1, "only the shutdown drain flushes");
        assert_eq!(report.membership_epochs, 0);
        assert!(report.membership.is_empty());
        assert_eq!(report.updates_total, 1);
        assert!(replies[0].is_empty() && replies[1].is_empty());
    }
}
