//! Time as a capability: the [`Clock`] trait and its two implementations.
//!
//! Every coordinator layer that needs "what time is it?" or "wait this long"
//! (the trainer's budget loop, the shard servers' trace throttling, the
//! workers' delay/compute-floor pacing) takes a `&dyn Clock` instead of
//! calling `Instant::now()` / `thread::sleep` directly:
//!
//! - [`RealClock`] — wall time anchored at run start; `sleep` blocks the
//!   calling thread. The threaded trainer uses this.
//! - [`VirtualClock`] — a shared nanosecond counter owned by the
//!   discrete-event simulator ([`super::sim`]); `now` reads it and `sleep`
//!   *advances* it, so simulated components experience the passage of time
//!   without any wall-clock wait. The event loop is the only writer via
//!   [`VirtualClock::set`], which keeps virtual time monotone because the
//!   event queue pops in non-decreasing time order.
//!
//! All timestamps are [`Duration`]s since run start — a value both clock
//! kinds can produce exactly, unlike `Instant`, which has no meaning in
//! virtual time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A source of run-relative time plus the ability to wait.
pub trait Clock: Send + Sync {
    /// Time elapsed since the run started.
    fn now(&self) -> Duration;

    /// Wait for `d`: blocks the thread (real) or advances time (virtual).
    fn sleep(&self, d: Duration);
}

/// Wall-clock time anchored at construction.
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    /// Anchor a new clock at the current instant.
    pub fn start() -> RealClock {
        RealClock {
            start: Instant::now(),
        }
    }

    /// The anchor instant — lets components without a `Clock` handle
    /// (e.g. the trace ring's frontend stamping) share this timebase.
    pub fn started_at(&self) -> Instant {
        self.start
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.start.elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Simulated time: a shared nanosecond counter advanced by the event loop
/// (or by `sleep` when a simulated component waits explicitly).
///
/// Nanosecond `u64` resolution covers ~584 years of virtual time — far
/// beyond any scenario — and makes every timestamp exactly representable,
/// which the bitwise-reproducibility guarantee relies on.
#[derive(Debug, Default)]
pub struct VirtualClock {
    nanos: AtomicU64,
}

impl VirtualClock {
    /// A clock at t = 0.
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Jump to an absolute time (the event loop calls this with each popped
    /// event's timestamp; event-queue ordering keeps it monotone).
    pub fn set(&self, t: Duration) {
        self.nanos.store(t.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Advance by a relative amount.
    pub fn advance(&self, d: Duration) {
        self.nanos
            .fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_advances_and_sleeps() {
        let c = RealClock::start();
        let t0 = c.now();
        c.sleep(Duration::from_millis(5));
        assert!(c.now() >= t0 + Duration::from_millis(5));
    }

    #[test]
    fn virtual_clock_is_free() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.set(Duration::from_secs(5));
        assert_eq!(c.now(), Duration::from_secs(5));
        c.advance(Duration::from_millis(250));
        assert_eq!(c.now(), Duration::from_millis(5250));
        // sleep advances instead of blocking
        let wall = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(1));
        assert_eq!(c.now(), Duration::from_millis(3_605_250));
    }

    #[test]
    fn dyn_clock_is_object_safe() {
        let real = RealClock::start();
        let virt = VirtualClock::new();
        let clocks: [&dyn Clock; 2] = [&real, &virt];
        for c in clocks {
            let _ = c.now();
        }
    }
}
