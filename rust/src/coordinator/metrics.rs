//! Metric recording for training runs.
//!
//! The evaluator thread records (wall-time, train-loss, test-loss, test-acc)
//! samples; the server loop records the threshold/buffer trajectory. A
//! finished run is summarised in [`RunMetrics`], exportable as JSON and
//! consumable by the experiment runner (resampling + round averaging happens
//! in `experiments::runner`).

use crate::util::json::{Json, Utf8JsonWriter};
use crate::util::stats::Series;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// The metric series that are sampled *while the run is live* (as opposed
/// to the counters and trajectories filled in from the server report at the
/// end). Each maps to one [`RunMetrics`] field and one stable stream name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeriesId {
    TrainLoss,
    TestLoss,
    TestAcc,
    CompressionRatio,
    Membership,
}

impl SeriesId {
    pub fn name(self) -> &'static str {
        match self {
            SeriesId::TrainLoss => "train_loss",
            SeriesId::TestLoss => "test_loss",
            SeriesId::TestAcc => "test_acc",
            SeriesId::CompressionRatio => "compression_ratio",
            SeriesId::Membership => "membership",
        }
    }

    pub fn from_name(name: &str) -> Option<SeriesId> {
        Some(match name {
            "train_loss" => SeriesId::TrainLoss,
            "test_loss" => SeriesId::TestLoss,
            "test_acc" => SeriesId::TestAcc,
            "compression_ratio" => SeriesId::CompressionRatio,
            "membership" => SeriesId::Membership,
            _ => None?,
        })
    }
}

/// A streaming metrics sink: every [`RunMetrics::record`] sample is
/// appended to a JSONL file (`{"s":"test_loss","t":…,"v":…}` per line, via
/// the incremental [`Utf8JsonWriter`]) the moment it happens, so a crash or
/// a multi-hour run never loses or accumulates history. With a window cap,
/// the in-memory series keep only the most recent samples — the file is
/// the full record ([`replay_stream`] rebuilds it).
pub struct MetricsStream {
    path: PathBuf,
    out: Mutex<std::io::BufWriter<std::fs::File>>,
    /// In-memory window: keep at most this many samples per series.
    cap: Option<usize>,
}

impl std::fmt::Debug for MetricsStream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsStream")
            .field("path", &self.path)
            .field("cap", &self.cap)
            .finish()
    }
}

impl MetricsStream {
    pub fn create(path: &Path) -> anyhow::Result<MetricsStream> {
        let file = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("cannot create metrics stream {path:?}: {e}"))?;
        Ok(MetricsStream {
            path: path.to_path_buf(),
            out: Mutex::new(std::io::BufWriter::new(file)),
            cap: None,
        })
    }

    /// Bound the *in-memory* series to the `cap` most recent samples each;
    /// the stream file still receives everything.
    pub fn with_cap(mut self, cap: usize) -> MetricsStream {
        assert!(cap > 0, "metrics window cap must be positive");
        self.cap = Some(cap);
        self
    }

    fn append(&self, series: SeriesId, t: f64, v: f64) {
        let mut w = Utf8JsonWriter::new();
        w.begin_object();
        w.key("s").str(series.name());
        w.key("t").num(t);
        w.key("v").num(v);
        w.end_object();
        let mut line = w.finish();
        line.push('\n');
        let mut out = self.out.lock().unwrap();
        // Disk-full mid-run must degrade observability, not kill training.
        let _ = out.write_all(line.as_bytes());
    }

    pub fn flush(&self) {
        let _ = self.out.lock().unwrap().flush();
    }
}

impl Drop for MetricsStream {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Rebuild the live-sampled series from a JSONL stream file. The values
/// come back bit-for-bit (shortest-roundtrip printing on the way out), so
/// an uncapped replay compares `==` with the in-memory series.
pub fn replay_stream(path: &Path) -> anyhow::Result<RunMetrics> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read metrics stream {path:?}: {e}"))?;
    let mut m = RunMetrics::default();
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() {
            continue;
        }
        let j = crate::util::json::parse(line)
            .map_err(|e| anyhow::anyhow!("bad stream line {}: {e}", lineno + 1))?;
        let name = j.str_field("s")?;
        let id = SeriesId::from_name(&name)
            .ok_or_else(|| anyhow::anyhow!("unknown series `{name}` at line {}", lineno + 1))?;
        m.series_mut(id).push(j.f64_field("t")?, j.f64_field("v")?);
    }
    Ok(m)
}

/// Peak resident-set size of the current process, in bytes. Reads the
/// `VmHWM` high-water mark from `/proc/self/status` (Linux); returns 0
/// anywhere the file or the line is missing — callers treat 0 as "not
/// measured", never as "no memory used".
pub fn peak_rss_bytes() -> u64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            // Format: "VmHWM:     12345 kB"
            let kb: u64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb * 1024;
        }
    }
    0
}

/// Everything measured during one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Mean NLL on the fixed train probe subset, vs wall-clock seconds.
    pub train_loss: Series,
    /// Mean NLL on the test set.
    pub test_loss: Series,
    /// Accuracy (%) on the test set — the paper reports percentages.
    pub test_acc: Series,
    /// Threshold K observed at flush boundaries.
    pub k_trajectory: Series,
    /// Parameter version over time (update progress).
    pub version_trajectory: Series,
    /// Cumulative dense-equivalent / wire-bytes ratio over time (1.0 for
    /// `compress=dense`; sampled at eval boundaries in the simulator, once
    /// at run end on the threaded stack).
    pub compression_ratio: Series,
    /// Live worker count at each elastic-membership transition (the
    /// membership trajectory; empty for static-membership runs).
    pub membership: Series,

    // run-level counters
    pub gradients_total: u64,
    pub updates_total: u64,
    pub flushes: u64,
    /// Submissions dropped at the server boundary as non-finite (NaN/Inf
    /// payloads — Byzantine workers or genuinely diverged replicas).
    pub rejected_grads: u64,
    /// Contributions scaled down by norm clipping (`--aggregate clip:<c>`).
    pub clipped_grads: u64,
    pub mean_staleness: f64,
    pub wall_time: f64,
    pub per_worker_grads: Vec<u64>,
    /// Parameter-server shard count of the run (0 until the server reports).
    pub shards: usize,
    /// Updates applied by each shard (they agree up to in-flight messages).
    pub per_shard_updates: Vec<u64>,
    /// Bytes-on-wire workers submitted (dropped submissions count — the
    /// transport lost them after the send).
    pub bytes_sent: u64,
    /// Bytes-on-wire shard servers received (duplicated deliveries count
    /// twice, dropped ones not at all).
    pub bytes_received: u64,
    /// What the same submissions would have cost dense (dim × 4 B each) —
    /// the denominator of the compression ratio.
    pub bytes_dense_equiv: u64,
    /// Elastic-membership transitions over the run (joins + leaves +
    /// evictions; 0 for static-membership runs).
    pub membership_epochs: u64,
    /// Snapshot publications across all shards (each is one copy of the
    /// dirty blocks into the snapshot pool).
    pub snapshot_publishes: u64,
    /// Bytes copied into published snapshots across all shards — the
    /// memory-traffic cost of the publish path. With delta tracking this
    /// is proportional to *dirty* blocks, not `dim`, per publish.
    pub snapshot_bytes_published: u64,
    /// Bytes of parameter state workers pulled via refresh. Logical
    /// (4 B × slice length) on transports without wire accounting; actual
    /// snapshot-response payload bytes on TCP, where the delta protocol
    /// makes this much smaller than refreshes × slice size.
    pub refresh_bytes: u64,
    /// Peak resident-set size of this process (bytes; Linux `VmHWM`, 0
    /// where unavailable). Excluded from equality — it is a property of
    /// the machine and allocator, not of the run.
    pub peak_rss_bytes: u64,
    /// Final parameters after the end-of-run drain (concatenated in shard
    /// order). The multi-process acceptance tests compare runs bitwise on
    /// this field; empty when a path does not report them.
    pub final_params: Vec<f32>,

    /// Optional streaming sink: [`RunMetrics::record`] appends every
    /// sample here the moment it happens. Excluded from equality — a run
    /// is the same run with or without an observer attached.
    pub stream: Option<Arc<MetricsStream>>,
}

/// Equality is exact — *bitwise* on every float (via [`Series`]'s bitwise
/// comparison and `f64::to_bits` on the scalars), so `NaN == NaN` and even
/// diverging runs replay-compare equal. The virtual-time simulator's
/// reproducibility guarantee is stated as "identical `RunMetrics` for
/// identical (seed, scenario)" and tested with plain `assert_eq!`.
impl PartialEq for RunMetrics {
    fn eq(&self, other: &Self) -> bool {
        self.train_loss == other.train_loss
            && self.test_loss == other.test_loss
            && self.test_acc == other.test_acc
            && self.k_trajectory == other.k_trajectory
            && self.version_trajectory == other.version_trajectory
            && self.gradients_total == other.gradients_total
            && self.updates_total == other.updates_total
            && self.flushes == other.flushes
            && self.rejected_grads == other.rejected_grads
            && self.clipped_grads == other.clipped_grads
            && self.mean_staleness.to_bits() == other.mean_staleness.to_bits()
            && self.wall_time.to_bits() == other.wall_time.to_bits()
            && self.per_worker_grads == other.per_worker_grads
            && self.shards == other.shards
            && self.per_shard_updates == other.per_shard_updates
            && self.compression_ratio == other.compression_ratio
            && self.membership == other.membership
            && self.membership_epochs == other.membership_epochs
            && self.bytes_sent == other.bytes_sent
            && self.bytes_received == other.bytes_received
            && self.bytes_dense_equiv == other.bytes_dense_equiv
            && self.snapshot_publishes == other.snapshot_publishes
            && self.snapshot_bytes_published == other.snapshot_bytes_published
            && self.refresh_bytes == other.refresh_bytes
            && self.final_params.len() == other.final_params.len()
            && self
                .final_params
                .iter()
                .zip(&other.final_params)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl RunMetrics {
    /// The in-memory series behind a [`SeriesId`].
    fn series_mut(&mut self, id: SeriesId) -> &mut Series {
        match id {
            SeriesId::TrainLoss => &mut self.train_loss,
            SeriesId::TestLoss => &mut self.test_loss,
            SeriesId::TestAcc => &mut self.test_acc,
            SeriesId::CompressionRatio => &mut self.compression_ratio,
            SeriesId::Membership => &mut self.membership,
        }
    }

    /// Record one live sample: push in-memory *and* append to the stream
    /// sink if one is attached. With a stream cap, the in-memory series is
    /// trimmed to the window (amortised: the front is drained in batches,
    /// so memory stays ≤ 2×cap and pushes stay O(1) amortised).
    pub fn record(&mut self, id: SeriesId, t: f64, v: f64) {
        let cap = match &self.stream {
            Some(st) => {
                st.append(id, t, v);
                st.cap
            }
            None => None,
        };
        let s = self.series_mut(id);
        s.push(t, v);
        if let Some(cap) = cap {
            if s.len() >= cap.saturating_mul(2) {
                let drop = s.len() - cap;
                s.t.drain(..drop);
                s.v.drain(..drop);
            }
        }
    }

    /// Gradient throughput over the whole run.
    pub fn grads_per_sec(&self) -> f64 {
        if self.wall_time > 0.0 {
            self.gradients_total as f64 / self.wall_time
        } else {
            0.0
        }
    }

    /// End-of-run wire compression: dense-equivalent bytes over bytes
    /// actually sent (1.0 when nothing was sent or the format is dense).
    pub fn wire_compression(&self) -> f64 {
        if self.bytes_sent == 0 {
            1.0
        } else {
            self.bytes_dense_equiv as f64 / self.bytes_sent as f64
        }
    }

    /// Imbalance: max/min gradients produced per worker (∞ if a worker
    /// produced none). 1.0 = perfectly even.
    pub fn worker_imbalance(&self) -> f64 {
        let max = self.per_worker_grads.iter().copied().max().unwrap_or(0);
        let min = self.per_worker_grads.iter().copied().min().unwrap_or(0);
        if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        }
    }

    /// Final (last-sample) metric triple, if any evaluation happened.
    pub fn final_metrics(&self) -> Option<(f64, f64, f64)> {
        if self.test_acc.is_empty() {
            return None;
        }
        Some((
            *self.train_loss.v.last()?,
            *self.test_loss.v.last()?,
            *self.test_acc.v.last()?,
        ))
    }

    pub fn to_json(&self) -> Json {
        fn series(s: &Series) -> Json {
            Json::from_pairs(vec![("t", Json::arr_f64(&s.t)), ("v", Json::arr_f64(&s.v))])
        }
        Json::from_pairs(vec![
            ("train_loss", series(&self.train_loss)),
            ("test_loss", series(&self.test_loss)),
            ("test_acc", series(&self.test_acc)),
            ("k_trajectory", series(&self.k_trajectory)),
            ("version_trajectory", series(&self.version_trajectory)),
            ("compression_ratio", series(&self.compression_ratio)),
            ("membership", series(&self.membership)),
            ("membership_epochs", Json::Num(self.membership_epochs as f64)),
            ("bytes_sent", Json::Num(self.bytes_sent as f64)),
            ("bytes_received", Json::Num(self.bytes_received as f64)),
            ("bytes_dense_equiv", Json::Num(self.bytes_dense_equiv as f64)),
            (
                "snapshot_publishes",
                Json::Num(self.snapshot_publishes as f64),
            ),
            (
                "snapshot_bytes_published",
                Json::Num(self.snapshot_bytes_published as f64),
            ),
            ("refresh_bytes", Json::Num(self.refresh_bytes as f64)),
            ("peak_rss_bytes", Json::Num(self.peak_rss_bytes as f64)),
            // f32 values are exact in f64, and the JSON writer prints
            // shortest-roundtrip floats, so this survives a JSON round
            // trip bit-for-bit (the multi-process tests rely on it).
            ("final_params", Json::arr_f32(&self.final_params)),
            ("wire_compression", Json::Num(self.wire_compression())),
            ("gradients_total", Json::Num(self.gradients_total as f64)),
            ("updates_total", Json::Num(self.updates_total as f64)),
            ("flushes", Json::Num(self.flushes as f64)),
            ("rejected_grads", Json::Num(self.rejected_grads as f64)),
            ("clipped_grads", Json::Num(self.clipped_grads as f64)),
            ("mean_staleness", Json::Num(self.mean_staleness)),
            ("wall_time", Json::Num(self.wall_time)),
            ("grads_per_sec", Json::Num(self.grads_per_sec())),
            (
                "per_worker_grads",
                Json::Arr(
                    self.per_worker_grads
                        .iter()
                        .map(|&g| Json::Num(g as f64))
                        .collect(),
                ),
            ),
            ("shards", Json::Num(self.shards as f64)),
            (
                "per_shard_updates",
                Json::Arr(
                    self.per_shard_updates
                        .iter()
                        .map(|&u| Json::Num(u as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        let mut m = RunMetrics::default();
        m.train_loss.push(0.0, 2.3);
        m.train_loss.push(1.0, 1.5);
        m.test_loss.push(0.0, 2.3);
        m.test_loss.push(1.0, 1.6);
        m.test_acc.push(0.0, 10.0);
        m.test_acc.push(1.0, 45.0);
        m.gradients_total = 100;
        m.updates_total = 80;
        m.rejected_grads = 3;
        m.clipped_grads = 4;
        m.wall_time = 2.0;
        m.per_worker_grads = vec![30, 40, 30];
        m.shards = 2;
        m.per_shard_updates = vec![80, 80];
        m.bytes_sent = 1000;
        m.bytes_received = 1000;
        m.bytes_dense_equiv = 50_000;
        m.membership.push(0.5, 2.0);
        m.membership_epochs = 1;
        m.snapshot_publishes = 12;
        m.snapshot_bytes_published = 4096;
        m.refresh_bytes = 2048;
        m
    }

    #[test]
    fn throughput_and_finals() {
        let m = sample();
        assert_eq!(m.grads_per_sec(), 50.0);
        let (tr, te, acc) = m.final_metrics().unwrap();
        assert_eq!((tr, te, acc), (1.5, 1.6, 45.0));
        assert_eq!(m.wire_compression(), 50.0);
        assert_eq!(RunMetrics::default().wire_compression(), 1.0);
    }

    #[test]
    fn imbalance() {
        let m = sample();
        assert!((m.worker_imbalance() - 40.0 / 30.0).abs() < 1e-12);
        let empty = RunMetrics {
            per_worker_grads: vec![5, 0],
            ..Default::default()
        };
        assert!(empty.worker_imbalance().is_infinite());
    }

    #[test]
    fn stream_replay_matches_in_memory_bitwise() {
        let dir = std::env::temp_dir().join("hsgd_metrics_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("replay.jsonl");
        let mut m = RunMetrics {
            stream: Some(Arc::new(MetricsStream::create(&path).unwrap())),
            ..Default::default()
        };
        // Awkward values on purpose: exact-f32 floats, huge ints, tiny
        // fractions — the shortest-roundtrip printer must carry all bits.
        let mut t = 0.0;
        for i in 0..200u32 {
            t += 0.1 + f64::from(i) * 1e-7;
            m.record(SeriesId::TestLoss, t, f64::from(f32::from_bits(0x3f80_0000 + i)));
            m.record(SeriesId::TestAcc, t, f64::from(i) * 0.5);
            m.record(SeriesId::TrainLoss, t, 1.0 / f64::from(i + 1));
        }
        m.record(SeriesId::CompressionRatio, t, 51.37);
        m.record(SeriesId::Membership, t, 3.0);
        m.stream.as_ref().unwrap().flush();
        let r = replay_stream(&path).unwrap();
        assert_eq!(r.test_loss, m.test_loss);
        assert_eq!(r.test_acc, m.test_acc);
        assert_eq!(r.train_loss, m.train_loss);
        assert_eq!(r.compression_ratio, m.compression_ratio);
        assert_eq!(r.membership, m.membership);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn capped_stream_bounds_memory_but_files_everything() {
        let dir = std::env::temp_dir().join("hsgd_metrics_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("capped.jsonl");
        let stream = MetricsStream::create(&path).unwrap().with_cap(16);
        let mut m = RunMetrics {
            stream: Some(Arc::new(stream)),
            ..Default::default()
        };
        for i in 0..10_000 {
            m.record(SeriesId::TestLoss, i as f64, (i as f64).sin());
        }
        // In-memory window stays within 2×cap; the tail is the live view.
        assert!(m.test_loss.len() < 32, "window len {}", m.test_loss.len());
        assert_eq!(*m.test_loss.t.last().unwrap(), 9999.0);
        m.stream.as_ref().unwrap().flush();
        // The file is the complete history, bit-for-bit.
        let r = replay_stream(&path).unwrap();
        assert_eq!(r.test_loss.len(), 10_000);
        let n = m.test_loss.len();
        assert_eq!(r.test_loss.t[10_000 - n..], m.test_loss.t[..]);
        assert_eq!(r.test_loss.v[10_000 - n..], m.test_loss.v[..]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn equality_ignores_the_stream_sink() {
        let dir = std::env::temp_dir().join("hsgd_metrics_stream_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("eq.jsonl");
        let a = sample();
        let mut b = sample();
        b.stream = Some(Arc::new(MetricsStream::create(&path).unwrap()));
        // Peak RSS is machine-dependent, so it must not break equality
        // either — two identical runs on different hosts compare equal.
        b.peak_rss_bytes = 123_456_789;
        assert_eq!(a, b);
        // The snapshot/refresh counters, by contrast, are deterministic
        // under the simulator and *do* participate.
        b.refresh_bytes += 1;
        assert_ne!(a, b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn peak_rss_reads_nonzero_on_linux() {
        let rss = peak_rss_bytes();
        if cfg!(target_os = "linux") {
            // Any running process has touched at least a page.
            assert!(rss > 0, "VmHWM parse returned 0 on Linux");
            assert_eq!(rss % 1024, 0, "VmHWM is reported in kB");
        }
    }

    #[test]
    fn json_roundtrips_fields() {
        let m = sample();
        let j = m.to_json();
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.usize_field("gradients_total").unwrap(), 100);
        assert_eq!(parsed.usize_field("shards").unwrap(), 2);
        assert_eq!(parsed.usize_field("bytes_sent").unwrap(), 1000);
        assert_eq!(parsed.f64_field("wire_compression").unwrap(), 50.0);
        assert_eq!(parsed.usize_field("membership_epochs").unwrap(), 1);
        assert_eq!(parsed.usize_field("rejected_grads").unwrap(), 3);
        assert_eq!(parsed.usize_field("clipped_grads").unwrap(), 4);
        assert_eq!(parsed.usize_field("snapshot_publishes").unwrap(), 12);
        assert_eq!(
            parsed.usize_field("snapshot_bytes_published").unwrap(),
            4096
        );
        assert_eq!(parsed.usize_field("refresh_bytes").unwrap(), 2048);
        assert_eq!(parsed.usize_field("peak_rss_bytes").unwrap(), 0);
        assert_eq!(
            parsed
                .get("membership")
                .unwrap()
                .get("v")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            1
        );
        assert_eq!(
            parsed
                .get("per_shard_updates")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
        assert_eq!(
            parsed
                .get("test_acc")
                .unwrap()
                .get("v")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
