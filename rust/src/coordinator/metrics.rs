//! Metric recording for training runs.
//!
//! The evaluator thread records (wall-time, train-loss, test-loss, test-acc)
//! samples; the server loop records the threshold/buffer trajectory. A
//! finished run is summarised in [`RunMetrics`], exportable as JSON and
//! consumable by the experiment runner (resampling + round averaging happens
//! in `experiments::runner`).

use crate::util::json::Json;
use crate::util::stats::Series;

/// Everything measured during one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Mean NLL on the fixed train probe subset, vs wall-clock seconds.
    pub train_loss: Series,
    /// Mean NLL on the test set.
    pub test_loss: Series,
    /// Accuracy (%) on the test set — the paper reports percentages.
    pub test_acc: Series,
    /// Threshold K observed at flush boundaries.
    pub k_trajectory: Series,
    /// Parameter version over time (update progress).
    pub version_trajectory: Series,
    /// Cumulative dense-equivalent / wire-bytes ratio over time (1.0 for
    /// `compress=dense`; sampled at eval boundaries in the simulator, once
    /// at run end on the threaded stack).
    pub compression_ratio: Series,
    /// Live worker count at each elastic-membership transition (the
    /// membership trajectory; empty for static-membership runs).
    pub membership: Series,

    // run-level counters
    pub gradients_total: u64,
    pub updates_total: u64,
    pub flushes: u64,
    pub mean_staleness: f64,
    pub wall_time: f64,
    pub per_worker_grads: Vec<u64>,
    /// Parameter-server shard count of the run (0 until the server reports).
    pub shards: usize,
    /// Updates applied by each shard (they agree up to in-flight messages).
    pub per_shard_updates: Vec<u64>,
    /// Bytes-on-wire workers submitted (dropped submissions count — the
    /// transport lost them after the send).
    pub bytes_sent: u64,
    /// Bytes-on-wire shard servers received (duplicated deliveries count
    /// twice, dropped ones not at all).
    pub bytes_received: u64,
    /// What the same submissions would have cost dense (dim × 4 B each) —
    /// the denominator of the compression ratio.
    pub bytes_dense_equiv: u64,
    /// Elastic-membership transitions over the run (joins + leaves +
    /// evictions; 0 for static-membership runs).
    pub membership_epochs: u64,
    /// Final parameters after the end-of-run drain (concatenated in shard
    /// order). The multi-process acceptance tests compare runs bitwise on
    /// this field; empty when a path does not report them.
    pub final_params: Vec<f32>,
}

/// Equality is exact — *bitwise* on every float (via [`Series`]'s bitwise
/// comparison and `f64::to_bits` on the scalars), so `NaN == NaN` and even
/// diverging runs replay-compare equal. The virtual-time simulator's
/// reproducibility guarantee is stated as "identical `RunMetrics` for
/// identical (seed, scenario)" and tested with plain `assert_eq!`.
impl PartialEq for RunMetrics {
    fn eq(&self, other: &Self) -> bool {
        self.train_loss == other.train_loss
            && self.test_loss == other.test_loss
            && self.test_acc == other.test_acc
            && self.k_trajectory == other.k_trajectory
            && self.version_trajectory == other.version_trajectory
            && self.gradients_total == other.gradients_total
            && self.updates_total == other.updates_total
            && self.flushes == other.flushes
            && self.mean_staleness.to_bits() == other.mean_staleness.to_bits()
            && self.wall_time.to_bits() == other.wall_time.to_bits()
            && self.per_worker_grads == other.per_worker_grads
            && self.shards == other.shards
            && self.per_shard_updates == other.per_shard_updates
            && self.compression_ratio == other.compression_ratio
            && self.membership == other.membership
            && self.membership_epochs == other.membership_epochs
            && self.bytes_sent == other.bytes_sent
            && self.bytes_received == other.bytes_received
            && self.bytes_dense_equiv == other.bytes_dense_equiv
            && self.final_params.len() == other.final_params.len()
            && self
                .final_params
                .iter()
                .zip(&other.final_params)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

impl RunMetrics {
    /// Gradient throughput over the whole run.
    pub fn grads_per_sec(&self) -> f64 {
        if self.wall_time > 0.0 {
            self.gradients_total as f64 / self.wall_time
        } else {
            0.0
        }
    }

    /// End-of-run wire compression: dense-equivalent bytes over bytes
    /// actually sent (1.0 when nothing was sent or the format is dense).
    pub fn wire_compression(&self) -> f64 {
        if self.bytes_sent == 0 {
            1.0
        } else {
            self.bytes_dense_equiv as f64 / self.bytes_sent as f64
        }
    }

    /// Imbalance: max/min gradients produced per worker (∞ if a worker
    /// produced none). 1.0 = perfectly even.
    pub fn worker_imbalance(&self) -> f64 {
        let max = self.per_worker_grads.iter().copied().max().unwrap_or(0);
        let min = self.per_worker_grads.iter().copied().min().unwrap_or(0);
        if min == 0 {
            f64::INFINITY
        } else {
            max as f64 / min as f64
        }
    }

    /// Final (last-sample) metric triple, if any evaluation happened.
    pub fn final_metrics(&self) -> Option<(f64, f64, f64)> {
        if self.test_acc.is_empty() {
            return None;
        }
        Some((
            *self.train_loss.v.last()?,
            *self.test_loss.v.last()?,
            *self.test_acc.v.last()?,
        ))
    }

    pub fn to_json(&self) -> Json {
        fn series(s: &Series) -> Json {
            Json::from_pairs(vec![("t", Json::arr_f64(&s.t)), ("v", Json::arr_f64(&s.v))])
        }
        Json::from_pairs(vec![
            ("train_loss", series(&self.train_loss)),
            ("test_loss", series(&self.test_loss)),
            ("test_acc", series(&self.test_acc)),
            ("k_trajectory", series(&self.k_trajectory)),
            ("version_trajectory", series(&self.version_trajectory)),
            ("compression_ratio", series(&self.compression_ratio)),
            ("membership", series(&self.membership)),
            ("membership_epochs", Json::Num(self.membership_epochs as f64)),
            ("bytes_sent", Json::Num(self.bytes_sent as f64)),
            ("bytes_received", Json::Num(self.bytes_received as f64)),
            ("bytes_dense_equiv", Json::Num(self.bytes_dense_equiv as f64)),
            // f32 values are exact in f64, and the JSON writer prints
            // shortest-roundtrip floats, so this survives a JSON round
            // trip bit-for-bit (the multi-process tests rely on it).
            ("final_params", Json::arr_f32(&self.final_params)),
            ("wire_compression", Json::Num(self.wire_compression())),
            ("gradients_total", Json::Num(self.gradients_total as f64)),
            ("updates_total", Json::Num(self.updates_total as f64)),
            ("flushes", Json::Num(self.flushes as f64)),
            ("mean_staleness", Json::Num(self.mean_staleness)),
            ("wall_time", Json::Num(self.wall_time)),
            ("grads_per_sec", Json::Num(self.grads_per_sec())),
            (
                "per_worker_grads",
                Json::Arr(
                    self.per_worker_grads
                        .iter()
                        .map(|&g| Json::Num(g as f64))
                        .collect(),
                ),
            ),
            ("shards", Json::Num(self.shards as f64)),
            (
                "per_shard_updates",
                Json::Arr(
                    self.per_shard_updates
                        .iter()
                        .map(|&u| Json::Num(u as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunMetrics {
        let mut m = RunMetrics::default();
        m.train_loss.push(0.0, 2.3);
        m.train_loss.push(1.0, 1.5);
        m.test_loss.push(0.0, 2.3);
        m.test_loss.push(1.0, 1.6);
        m.test_acc.push(0.0, 10.0);
        m.test_acc.push(1.0, 45.0);
        m.gradients_total = 100;
        m.updates_total = 80;
        m.wall_time = 2.0;
        m.per_worker_grads = vec![30, 40, 30];
        m.shards = 2;
        m.per_shard_updates = vec![80, 80];
        m.bytes_sent = 1000;
        m.bytes_received = 1000;
        m.bytes_dense_equiv = 50_000;
        m.membership.push(0.5, 2.0);
        m.membership_epochs = 1;
        m
    }

    #[test]
    fn throughput_and_finals() {
        let m = sample();
        assert_eq!(m.grads_per_sec(), 50.0);
        let (tr, te, acc) = m.final_metrics().unwrap();
        assert_eq!((tr, te, acc), (1.5, 1.6, 45.0));
        assert_eq!(m.wire_compression(), 50.0);
        assert_eq!(RunMetrics::default().wire_compression(), 1.0);
    }

    #[test]
    fn imbalance() {
        let m = sample();
        assert!((m.worker_imbalance() - 40.0 / 30.0).abs() < 1e-12);
        let empty = RunMetrics {
            per_worker_grads: vec![5, 0],
            ..Default::default()
        };
        assert!(empty.worker_imbalance().is_infinite());
    }

    #[test]
    fn json_roundtrips_fields() {
        let m = sample();
        let j = m.to_json();
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.usize_field("gradients_total").unwrap(), 100);
        assert_eq!(parsed.usize_field("shards").unwrap(), 2);
        assert_eq!(parsed.usize_field("bytes_sent").unwrap(), 1000);
        assert_eq!(parsed.f64_field("wire_compression").unwrap(), 50.0);
        assert_eq!(parsed.usize_field("membership_epochs").unwrap(), 1);
        assert_eq!(
            parsed
                .get("membership")
                .unwrap()
                .get("v")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            1
        );
        assert_eq!(
            parsed
                .get("per_shard_updates")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
        assert_eq!(
            parsed
                .get("test_acc")
                .unwrap()
                .get("v")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
