//! Run checkpointing: persist/restore parameters + run metadata.
//!
//! Format: a little-endian binary parameter file (`<name>.params.bin`,
//! magic + version + dim + f32 payload + xor checksum) next to a JSON
//! metadata file (`<name>.meta.json`) with the model name, PS version,
//! policy string and metric summary. A production deployment would
//! checkpoint periodically from the PS thread; here checkpointing is offered
//! at run boundaries (`Checkpoint::save` / `load`) and covered by tests.

use crate::util::json::{parse, Json};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"HSGDCKPT";
const FORMAT_VERSION: u32 = 1;

/// A saved training state.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub policy: String,
    pub ps_version: u64,
    /// Parameter-server shard count of the run that produced the params
    /// (informational: the flat layout is shard-count independent, so a
    /// checkpoint restores under any `S`). Pre-shard checkpoints load as 1.
    pub shards: usize,
    pub params: Vec<f32>,
}

fn xor_checksum(data: &[u8]) -> u64 {
    let mut acc = 0xDEADBEEFu64;
    for chunk in data.chunks(8) {
        let mut buf = [0u8; 8];
        buf[..chunk.len()].copy_from_slice(chunk);
        acc = acc.rotate_left(13) ^ u64::from_le_bytes(buf);
    }
    acc
}

impl Checkpoint {
    /// Write `<dir>/<name>.params.bin` + `<dir>/<name>.meta.json`.
    pub fn save(&self, dir: impl AsRef<Path>, name: &str) -> anyhow::Result<(PathBuf, PathBuf)> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let bin_path = dir.join(format!("{name}.params.bin"));
        let meta_path = dir.join(format!("{name}.meta.json"));

        let payload: Vec<u8> = self
            .params
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut f = std::fs::File::create(&bin_path)?;
        f.write_all(MAGIC)?;
        f.write_all(&FORMAT_VERSION.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&xor_checksum(&payload).to_le_bytes())?;

        let meta = Json::from_pairs(vec![
            ("model", Json::Str(self.model.clone())),
            ("policy", Json::Str(self.policy.clone())),
            ("ps_version", Json::Num(self.ps_version as f64)),
            ("shards", Json::Num(self.shards.max(1) as f64)),
            ("param_count", Json::Num(self.params.len() as f64)),
        ]);
        std::fs::write(&meta_path, meta.to_string_pretty())?;
        Ok((bin_path, meta_path))
    }

    /// Load and verify a checkpoint pair.
    pub fn load(dir: impl AsRef<Path>, name: &str) -> anyhow::Result<Checkpoint> {
        let dir = dir.as_ref();
        let bin_path = dir.join(format!("{name}.params.bin"));
        let meta_path = dir.join(format!("{name}.meta.json"));

        let mut f = std::fs::File::open(&bin_path)
            .map_err(|e| anyhow::anyhow!("open {}: {e}", bin_path.display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "bad checkpoint magic");
        let mut v4 = [0u8; 4];
        f.read_exact(&mut v4)?;
        anyhow::ensure!(
            u32::from_le_bytes(v4) == FORMAT_VERSION,
            "unsupported checkpoint version"
        );
        let mut n8 = [0u8; 8];
        f.read_exact(&mut n8)?;
        let n = u64::from_le_bytes(n8) as usize;
        let mut payload = vec![0u8; n * 4];
        f.read_exact(&mut payload)?;
        let mut ck = [0u8; 8];
        f.read_exact(&mut ck)?;
        anyhow::ensure!(
            u64::from_le_bytes(ck) == xor_checksum(&payload),
            "checkpoint checksum mismatch (corrupt file)"
        );
        let params: Vec<f32> = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let meta = parse(&std::fs::read_to_string(&meta_path)?)?;
        anyhow::ensure!(
            meta.usize_field("param_count")? == n,
            "meta/binary param_count mismatch"
        );
        Ok(Checkpoint {
            model: meta.str_field("model")?,
            policy: meta.str_field("policy")?,
            ps_version: meta.usize_field("ps_version")? as u64,
            // Absent in pre-shard checkpoints: default to a single shard.
            shards: meta.get("shards").and_then(Json::as_usize).unwrap_or(1),
            params,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("hsgd_ckpt_{tag}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            model: "mlp".into(),
            policy: "hybrid:step:500".into(),
            ps_version: 1234,
            shards: 4,
            params: (0..1000).map(|i| (i as f32).sin()).collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let dir = tmpdir("rt");
        let ck = sample();
        ck.save(&dir, "run1").unwrap();
        let back = Checkpoint::load(&dir, "run1").unwrap();
        assert_eq!(ck, back);
    }

    #[test]
    fn corruption_detected() {
        let dir = tmpdir("corrupt");
        let ck = sample();
        let (bin, _) = ck.save(&dir, "run1").unwrap();
        // flip a payload byte
        let mut bytes = std::fs::read(&bin).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&bin, bytes).unwrap();
        let err = Checkpoint::load(&dir, "run1").unwrap_err();
        assert!(err.to_string().contains("checksum"));
    }

    #[test]
    fn missing_files_error() {
        let dir = tmpdir("missing");
        assert!(Checkpoint::load(&dir, "nope").is_err());
    }

    #[test]
    fn pre_shard_meta_loads_as_single_shard() {
        let dir = tmpdir("legacy");
        let ck = sample();
        let (_, meta) = ck.save(&dir, "run1").unwrap();
        // Rewrite the meta without the `shards` key (pre-shard format).
        std::fs::write(
            &meta,
            format!(
                r#"{{"model":"mlp","policy":"hybrid:step:500","ps_version":1234,"param_count":{}}}"#,
                ck.params.len()
            ),
        )
        .unwrap();
        let back = Checkpoint::load(&dir, "run1").unwrap();
        assert_eq!(back.shards, 1);
        assert_eq!(back.params, ck.params);
    }

    #[test]
    fn meta_mismatch_detected() {
        let dir = tmpdir("meta");
        let ck = sample();
        let (_, meta) = ck.save(&dir, "run1").unwrap();
        std::fs::write(
            &meta,
            r#"{"model":"mlp","policy":"async","ps_version":1,"param_count":7}"#,
        )
        .unwrap();
        let err = Checkpoint::load(&dir, "run1").unwrap_err();
        assert!(err.to_string().contains("mismatch"));
    }
}
