//! The gradient buffer at the heart of the hybrid algorithm.
//!
//! Arriving gradients are *summed in place* into one pre-allocated vector —
//! the PS hot path never allocates and never stores k individual gradients
//! (an O(k·d) memory / O(d) flush-time win over the naive list-of-gradients
//! the paper sketches; `bench_hotpath` quantifies it). Staleness bookkeeping
//! records, per buffered gradient, how many versions behind the gradient's
//! base version was at arrival — the quantity the paper's narrative is about.
//!
//! Under the sharded parameter server each shard owns one buffer of its
//! slice length (`dim = |shard|`), so the total buffered state stays O(d)
//! across any shard count and each shard's flush is an O(d / S) scan.
//!
//! Compressed submissions ([`GradView::Sparse`] / [`GradView::Quant`] /
//! [`GradView::SparseQuant`]) are accumulated **without densifying**: a
//! sparse arrival is an O(nnz) scatter-add into the running sum and an
//! int8 arrival dequantizes on the fly — the buffer never materialises a
//! dense copy of a payload.

use super::compress::GradView;

/// Accumulating gradient buffer with staleness statistics.
pub struct GradientBuffer {
    sum: Vec<f32>,
    count: usize,
    /// Number of gradients per contributing worker in the current epoch.
    per_worker: Vec<u32>,
    /// Σ (current_version − base_version) over buffered gradients.
    staleness_sum: u64,
    max_staleness: u64,
}

impl GradientBuffer {
    pub fn new(dim: usize, workers: usize) -> Self {
        GradientBuffer {
            sum: vec![0.0; dim],
            count: 0,
            per_worker: vec![0; workers],
            staleness_sum: 0,
            max_staleness: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Accumulate one dense gradient computed at `base_version` by
    /// `worker`, with `current_version` the PS version at arrival.
    pub fn push(&mut self, grad: &[f32], worker: usize, base_version: u64, current_version: u64) {
        self.push_view(GradView::Dense(grad), worker, base_version, current_version);
    }

    /// Accumulate one gradient arriving in any wire format: dense adds run
    /// the exact summing loop `push` always did; sparse views scatter-add
    /// their nnz coordinates; quantized views dequantize on the fly.
    pub fn push_view(
        &mut self,
        grad: GradView<'_>,
        worker: usize,
        base_version: u64,
        current_version: u64,
    ) {
        grad.add_to(&mut self.sum);
        self.count += 1;
        self.per_worker[worker] += 1;
        let stale = current_version.saturating_sub(base_version);
        self.staleness_sum += stale;
        self.max_staleness = self.max_staleness.max(stale);
    }

    /// Summed gradient (valid while count > 0).
    pub fn sum(&self) -> &[f32] {
        &self.sum
    }

    /// How many distinct workers contributed this epoch.
    pub fn distinct_workers(&self) -> usize {
        self.per_worker.iter().filter(|&&c| c > 0).count()
    }

    /// Mean staleness of buffered gradients.
    pub fn mean_staleness(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.count as f64
        }
    }

    pub fn max_staleness(&self) -> u64 {
        self.max_staleness
    }

    /// Reset for the next epoch. O(d) but only on flush boundaries.
    pub fn clear(&mut self) {
        self.sum.fill(0.0);
        self.count = 0;
        self.per_worker.fill(0);
        self.staleness_sum = 0;
        self.max_staleness = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sums() {
        let mut b = GradientBuffer::new(3, 2);
        b.push(&[1.0, 2.0, 3.0], 0, 0, 0);
        b.push(&[0.5, 0.5, 0.5], 1, 0, 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.sum(), &[1.5, 2.5, 3.5]);
        assert_eq!(b.distinct_workers(), 2);
    }

    #[test]
    fn staleness_tracking() {
        let mut b = GradientBuffer::new(1, 3);
        b.push(&[0.0], 0, 5, 5); // fresh
        b.push(&[0.0], 1, 2, 5); // 3 behind
        b.push(&[0.0], 2, 0, 6); // 6 behind
        assert_eq!(b.mean_staleness(), 3.0);
        assert_eq!(b.max_staleness(), 6);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = GradientBuffer::new(2, 2);
        b.push(&[1.0, 1.0], 0, 0, 4);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.sum(), &[0.0, 0.0]);
        assert_eq!(b.distinct_workers(), 0);
        assert_eq!(b.mean_staleness(), 0.0);
        assert_eq!(b.max_staleness(), 0);
    }

    #[test]
    fn sparse_and_quant_views_accumulate_without_densifying() {
        let mut dense = GradientBuffer::new(4, 2);
        let mut sparse = GradientBuffer::new(4, 2);
        dense.push(&[1.0, 0.0, -2.0, 0.0], 0, 0, 1);
        sparse.push_view(
            GradView::Sparse {
                idx: &[0, 2],
                val: &[1.0, -2.0],
            },
            0,
            0,
            1,
        );
        assert_eq!(dense.sum(), sparse.sum());
        assert_eq!(dense.mean_staleness(), sparse.mean_staleness());
        // int8 view dequantizes on the fly: 127 · (2/127) = 2.0 exactly
        let mut quant = GradientBuffer::new(2, 1);
        quant.push_view(
            GradView::Quant {
                scale: 2.0 / 127.0,
                data: &[127, -127],
            },
            0,
            0,
            0,
        );
        assert!((quant.sum()[0] - 2.0).abs() < 1e-6);
        assert!((quant.sum()[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn same_worker_multiple_contributions() {
        let mut b = GradientBuffer::new(1, 2);
        b.push(&[1.0], 0, 0, 0);
        b.push(&[1.0], 0, 0, 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.distinct_workers(), 1);
    }
}
