//! The gradient buffer at the heart of the hybrid algorithm.
//!
//! Arriving gradients are *summed in place* into one pre-allocated vector —
//! the PS hot path never allocates and never stores k individual gradients
//! (an O(k·d) memory / O(d) flush-time win over the naive list-of-gradients
//! the paper sketches; `bench_hotpath` quantifies it). Staleness bookkeeping
//! records, per buffered gradient, how many versions behind the gradient's
//! base version was at arrival — the quantity the paper's narrative is about.
//!
//! Under the sharded parameter server each shard owns one buffer of its
//! slice length (`dim = |shard|`), so the total buffered state stays O(d)
//! across any shard count and each shard's flush is an O(d / S) scan.
//!
//! Compressed submissions ([`GradView::Sparse`] / [`GradView::Quant`] /
//! [`GradView::SparseQuant`]) are accumulated **without densifying**: a
//! sparse arrival is an O(nnz) scatter-add into the running sum and an
//! int8 arrival dequantizes on the fly — the buffer never materialises a
//! dense copy of a payload.
//!
//! Robust aggregation (DESIGN.md §2.10): the coordinate-wise trimmed-mean
//! and median defenses need the individual contributions at flush time, so
//! under those modes the buffer *additionally* retains each gradient as a
//! dense row (recycled across epochs — no steady-state allocation). The
//! running sum keeps accumulating exactly as before, so `--aggregate mean`
//! and `clip` never pay the O(k·d) retention cost and the mean flush path
//! stays bitwise-identical to the sum-only buffer.

use super::compress::GradView;

/// Server-side aggregation mode: how a flush turns the buffered gradients
/// into one update (DESIGN.md §2.10). `Mean` is the paper's averaged flush
/// and the bitwise-pinned default; the rest are Byzantine defenses.
#[derive(Clone, Debug, PartialEq)]
pub enum AggregateMode {
    /// Average of the buffered gradients (the pre-defense flush, pinned
    /// bitwise).
    Mean,
    /// Mean of per-gradient L2-norm-clipped contributions: each gradient is
    /// scaled by `min(1, c / ‖g‖)` at accumulation time, so it composes
    /// with sparse/int8 wire formats without densifying.
    Clip(f32),
    /// Coordinate-wise trimmed mean: drop the `⌊f·k⌋` lowest and highest
    /// values per coordinate, mean the rest. Requires `f ∈ (0, 0.5)`.
    Trimmed(f64),
    /// Coordinate-wise median (mean of the two middle values for even
    /// counts).
    Median,
}

impl AggregateMode {
    /// Parse CLI/scenario syntax: `mean`, `clip:<c>`, `trimmed:<f>`,
    /// `median`.
    pub fn parse(s: &str) -> anyhow::Result<AggregateMode> {
        match s {
            "mean" => return Ok(AggregateMode::Mean),
            "median" => return Ok(AggregateMode::Median),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("clip:") {
            let c: f32 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad clip radius `{rest}`"))?;
            anyhow::ensure!(
                c.is_finite() && c > 0.0,
                "clip radius must be finite and > 0, got `{rest}`"
            );
            return Ok(AggregateMode::Clip(c));
        }
        if let Some(rest) = s.strip_prefix("trimmed:") {
            let f: f64 = rest
                .parse()
                .map_err(|_| anyhow::anyhow!("bad trim fraction `{rest}`"))?;
            anyhow::ensure!(
                f.is_finite() && f > 0.0 && f < 0.5,
                "trim fraction must be in (0, 0.5), got `{rest}`"
            );
            return Ok(AggregateMode::Trimmed(f));
        }
        anyhow::bail!("unknown aggregate mode `{s}` (mean | clip:<c> | trimmed:<f> | median)")
    }

    /// Whether this mode needs the buffer to retain per-gradient rows.
    pub fn retains_rows(&self) -> bool {
        matches!(self, AggregateMode::Trimmed(_) | AggregateMode::Median)
    }

    /// Whether this mode is the bitwise-pinned default.
    pub fn is_mean(&self) -> bool {
        *self == AggregateMode::Mean
    }
}

impl Default for AggregateMode {
    fn default() -> Self {
        AggregateMode::Mean
    }
}

impl std::fmt::Display for AggregateMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AggregateMode::Mean => write!(f, "mean"),
            AggregateMode::Clip(c) => write!(f, "clip:{c}"),
            AggregateMode::Trimmed(t) => write!(f, "trimmed:{t}"),
            AggregateMode::Median => write!(f, "median"),
        }
    }
}

/// Accumulating gradient buffer with staleness statistics.
pub struct GradientBuffer {
    sum: Vec<f32>,
    count: usize,
    /// Number of gradients per contributing worker in the current epoch.
    per_worker: Vec<u32>,
    /// Σ (current_version − base_version) over buffered gradients.
    staleness_sum: u64,
    max_staleness: u64,
    /// Robust modes only: each buffered gradient densified as one row
    /// (empty and never touched under mean/clip).
    rows: Vec<Vec<f32>>,
    /// Recycled row storage — rows move back here on `clear` so the
    /// steady state allocates nothing.
    row_pool: Vec<Vec<f32>>,
    retain_rows: bool,
    /// Scratch for the robust estimate and the per-coordinate sort column.
    est: Vec<f32>,
    col: Vec<f32>,
}

impl GradientBuffer {
    pub fn new(dim: usize, workers: usize) -> Self {
        GradientBuffer {
            sum: vec![0.0; dim],
            count: 0,
            per_worker: vec![0; workers],
            staleness_sum: 0,
            max_staleness: 0,
            rows: Vec::new(),
            row_pool: Vec::new(),
            retain_rows: false,
            est: Vec::new(),
            col: Vec::new(),
        }
    }

    /// Enable per-gradient row retention (trimmed-mean / median flushes
    /// need the individual contributions, not just the sum).
    pub fn with_row_retention(mut self) -> Self {
        self.retain_rows = true;
        self.est = vec![0.0; self.sum.len()];
        self
    }

    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Accumulate one dense gradient computed at `base_version` by
    /// `worker`, with `current_version` the PS version at arrival.
    pub fn push(&mut self, grad: &[f32], worker: usize, base_version: u64, current_version: u64) {
        self.push_view(GradView::Dense(grad), worker, base_version, current_version);
    }

    /// Accumulate one gradient arriving in any wire format: dense adds run
    /// the exact summing loop `push` always did; sparse views scatter-add
    /// their nnz coordinates; quantized views dequantize on the fly.
    pub fn push_view(
        &mut self,
        grad: GradView<'_>,
        worker: usize,
        base_version: u64,
        current_version: u64,
    ) {
        if self.retain_rows {
            let mut row = self.row_pool.pop().unwrap_or_else(|| vec![0.0; self.sum.len()]);
            row.fill(0.0);
            grad.add_to(&mut row);
            self.rows.push(row);
        }
        grad.add_to(&mut self.sum);
        self.count += 1;
        self.per_worker[worker] += 1;
        let stale = current_version.saturating_sub(base_version);
        self.staleness_sum += stale;
        self.max_staleness = self.max_staleness.max(stale);
    }

    /// [`GradientBuffer::push_view`] with every accumulated value scaled by
    /// `factor` — the norm-clipping path (`factor = min(1, c/‖g‖)`), which
    /// works per wire entry so sparse/int8 submissions stay undensified.
    pub fn push_view_scaled(
        &mut self,
        grad: GradView<'_>,
        factor: f32,
        worker: usize,
        base_version: u64,
        current_version: u64,
    ) {
        if self.retain_rows {
            let mut row = self.row_pool.pop().unwrap_or_else(|| vec![0.0; self.sum.len()]);
            row.fill(0.0);
            grad.add_scaled_to(&mut row, factor);
            self.rows.push(row);
        }
        grad.add_scaled_to(&mut self.sum, factor);
        self.count += 1;
        self.per_worker[worker] += 1;
        let stale = current_version.saturating_sub(base_version);
        self.staleness_sum += stale;
        self.max_staleness = self.max_staleness.max(stale);
    }

    /// Summed gradient (valid while count > 0).
    pub fn sum(&self) -> &[f32] {
        &self.sum
    }

    /// Coordinate-wise robust estimate over the retained rows: per
    /// coordinate, sort the `k` buffered values, drop the `trim` lowest
    /// and `trim` highest, and mean the survivors. `trim = 0` is the
    /// coordinate-wise mean; `trim = (k-1)/2` is the median (the mean of
    /// the two middle values for even `k`). Requires row retention and
    /// `2·trim < len()`.
    pub fn robust_estimate(&mut self, trim: usize) -> &[f32] {
        let k = self.rows.len();
        assert!(self.retain_rows && k == self.count, "robust flush without row retention");
        assert!(2 * trim < k, "trim {trim} leaves nothing of {k} rows");
        let kept = (k - 2 * trim) as f32;
        self.col.resize(k, 0.0);
        for j in 0..self.sum.len() {
            for (c, row) in self.col.iter_mut().zip(&self.rows) {
                *c = row[j];
            }
            self.col.sort_unstable_by(f32::total_cmp);
            let mut s = 0.0f32;
            for &v in &self.col[trim..k - trim] {
                s += v;
            }
            self.est[j] = s / kept;
        }
        &self.est
    }

    /// How many distinct workers contributed this epoch.
    pub fn distinct_workers(&self) -> usize {
        self.per_worker.iter().filter(|&&c| c > 0).count()
    }

    /// Mean staleness of buffered gradients.
    pub fn mean_staleness(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.staleness_sum as f64 / self.count as f64
        }
    }

    pub fn max_staleness(&self) -> u64 {
        self.max_staleness
    }

    /// Reset for the next epoch. O(d) but only on flush boundaries.
    pub fn clear(&mut self) {
        self.sum.fill(0.0);
        self.count = 0;
        self.per_worker.fill(0);
        self.staleness_sum = 0;
        self.max_staleness = 0;
        self.row_pool.append(&mut self.rows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_sums() {
        let mut b = GradientBuffer::new(3, 2);
        b.push(&[1.0, 2.0, 3.0], 0, 0, 0);
        b.push(&[0.5, 0.5, 0.5], 1, 0, 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.sum(), &[1.5, 2.5, 3.5]);
        assert_eq!(b.distinct_workers(), 2);
    }

    #[test]
    fn staleness_tracking() {
        let mut b = GradientBuffer::new(1, 3);
        b.push(&[0.0], 0, 5, 5); // fresh
        b.push(&[0.0], 1, 2, 5); // 3 behind
        b.push(&[0.0], 2, 0, 6); // 6 behind
        assert_eq!(b.mean_staleness(), 3.0);
        assert_eq!(b.max_staleness(), 6);
    }

    #[test]
    fn clear_resets_everything() {
        let mut b = GradientBuffer::new(2, 2);
        b.push(&[1.0, 1.0], 0, 0, 4);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.sum(), &[0.0, 0.0]);
        assert_eq!(b.distinct_workers(), 0);
        assert_eq!(b.mean_staleness(), 0.0);
        assert_eq!(b.max_staleness(), 0);
    }

    #[test]
    fn sparse_and_quant_views_accumulate_without_densifying() {
        let mut dense = GradientBuffer::new(4, 2);
        let mut sparse = GradientBuffer::new(4, 2);
        dense.push(&[1.0, 0.0, -2.0, 0.0], 0, 0, 1);
        sparse.push_view(
            GradView::Sparse {
                idx: &[0, 2],
                val: &[1.0, -2.0],
            },
            0,
            0,
            1,
        );
        assert_eq!(dense.sum(), sparse.sum());
        assert_eq!(dense.mean_staleness(), sparse.mean_staleness());
        // int8 view dequantizes on the fly: 127 · (2/127) = 2.0 exactly
        let mut quant = GradientBuffer::new(2, 1);
        quant.push_view(
            GradView::Quant {
                scale: 2.0 / 127.0,
                data: &[127, -127],
            },
            0,
            0,
            0,
        );
        assert!((quant.sum()[0] - 2.0).abs() < 1e-6);
        assert!((quant.sum()[1] + 2.0).abs() < 1e-6);
    }

    #[test]
    fn same_worker_multiple_contributions() {
        let mut b = GradientBuffer::new(1, 2);
        b.push(&[1.0], 0, 0, 0);
        b.push(&[1.0], 0, 0, 0);
        assert_eq!(b.len(), 2);
        assert_eq!(b.distinct_workers(), 1);
    }

    #[test]
    fn aggregate_mode_parse_roundtrip() {
        for s in ["mean", "clip:2.5", "trimmed:0.25", "median"] {
            let m = AggregateMode::parse(s).unwrap();
            assert_eq!(m.to_string(), s);
            assert_eq!(AggregateMode::parse(&m.to_string()).unwrap(), m);
        }
        for bad in [
            "", "avg", "clip", "clip:0", "clip:-1", "clip:nan", "trimmed:0",
            "trimmed:0.5", "trimmed:0.6", "trimmed:x", "median:2",
        ] {
            assert!(AggregateMode::parse(bad).is_err(), "`{bad}` should not parse");
        }
        assert!(AggregateMode::Mean.is_mean());
        assert!(!AggregateMode::Median.is_mean());
        assert!(AggregateMode::Trimmed(0.25).retains_rows());
        assert!(AggregateMode::Median.retains_rows());
        assert!(!AggregateMode::Clip(1.0).retains_rows());
        assert!(!AggregateMode::Mean.retains_rows());
    }

    #[test]
    fn trimmed_estimate_drops_the_outlier() {
        let mut b = GradientBuffer::new(2, 4).with_row_retention();
        b.push(&[1.0, -1.0], 0, 0, 0);
        b.push(&[1.2, -0.8], 1, 0, 0);
        b.push(&[0.8, -1.2], 2, 0, 0);
        b.push(&[1000.0, -1000.0], 3, 0, 0); // the attacker
        // trim 1 per end: the poisoned row is gone from every coordinate
        let est = b.robust_estimate(1).to_vec();
        assert!((est[0] - 1.1).abs() < 1e-6, "{est:?}");
        assert!((est[1] + 1.0).abs() < 1e-6, "{est:?}");
        // the running sum is still poisoned — only the robust flush is safe
        assert!(b.sum()[0] > 100.0);
    }

    #[test]
    fn median_is_trim_of_half() {
        let mut b = GradientBuffer::new(1, 5).with_row_retention();
        for (w, v) in [5.0f32, 1.0, 3.0, 2.0, 4.0].iter().enumerate() {
            b.push(&[*v], w, 0, 0);
        }
        // odd count: trim (5-1)/2 = 2 keeps exactly the middle value
        assert_eq!(b.robust_estimate(2), &[3.0]);
    }

    #[test]
    fn median_even_count_means_the_middles() {
        let mut b = GradientBuffer::new(1, 4).with_row_retention();
        for (w, v) in [9.0f32, 1.0, 2.0, 4.0].iter().enumerate() {
            b.push(&[*v], w, 0, 0);
        }
        // even count: trim (4-1)/2 = 1 keeps the two middles → mean(2,4)
        assert_eq!(b.robust_estimate(1), &[3.0]);
    }

    #[test]
    fn rows_recycle_across_epochs() {
        let mut b = GradientBuffer::new(2, 2).with_row_retention();
        b.push(&[1.0, 2.0], 0, 0, 0);
        b.push(&[3.0, 4.0], 1, 0, 0);
        assert_eq!(b.robust_estimate(0), &[2.0, 3.0]);
        b.clear();
        assert!(b.is_empty());
        // second epoch reuses the pooled rows and must not see stale data
        b.push(&[10.0, 10.0], 0, 0, 0);
        assert_eq!(b.robust_estimate(0), &[10.0, 10.0]);
        assert_eq!(b.sum(), &[10.0, 10.0]);
    }

    #[test]
    fn scaled_push_scales_every_format() {
        let mut a = GradientBuffer::new(3, 1);
        let mut b = GradientBuffer::new(3, 1);
        a.push_view_scaled(GradView::Dense(&[2.0, -4.0, 6.0]), 0.5, 0, 0, 0);
        b.push(&[1.0, -2.0, 3.0], 0, 0, 0);
        assert_eq!(a.sum(), b.sum());
        let mut c = GradientBuffer::new(3, 1);
        c.push_view_scaled(
            GradView::Sparse {
                idx: &[0, 2],
                val: &[2.0, 6.0],
            },
            0.5,
            0,
            0,
            0,
        );
        assert_eq!(c.sum(), &[1.0, 0.0, 3.0]);
    }
}
