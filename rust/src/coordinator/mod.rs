//! L3 — the parameter-server coordinator (the paper's contribution).
//!
//! Layering, bottom-up:
//! - [`threshold`] — monotone threshold schedules `K(n)` (paper Algorithm 1
//!   step 3; §9 pluggable variants).
//! - [`params`] / [`buffer`] — versioned parameter store and the summing
//!   gradient buffer.
//! - [`policy`] — the pure aggregation state machine: async / sync /
//!   hybrid(smooth|strict).
//! - [`delay`] — the paper's worker-heterogeneity injection model.
//! - [`server`] / [`worker`] — the threaded parameter-server protocol.
//! - [`trainer`] — one-call orchestration of a full training run.
//! - [`metrics`] — metric time series and run summaries.

pub mod adaptive;
pub mod buffer;
pub mod checkpoint;
pub mod compress;
pub mod delay;
pub mod metrics;
pub mod params;
pub mod policy;
pub mod server;
pub mod threshold;
pub mod trainer;
pub mod worker;

pub use adaptive::{AdaptiveConfig, AdaptiveController};
pub use delay::DelayModel;
pub use metrics::RunMetrics;
pub use policy::{Aggregator, Outcome, Policy};
pub use threshold::Schedule;
pub use trainer::{train, EvalSet, RunInputs, TrainConfig};
