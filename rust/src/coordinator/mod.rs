//! L3 — the parameter-server coordinator (the paper's contribution).
//!
//! Layering, bottom-up:
//! - [`threshold`] — monotone threshold schedules `K(n)` (paper Algorithm 1
//!   step 3; §9 pluggable variants).
//! - [`params`] / [`buffer`] — versioned parameter store (with zero-copy
//!   snapshot cells) and the gradient buffer: plain summing for the mean
//!   path, per-contribution row retention for the robust aggregation
//!   modes (trimmed mean / coordinate-wise median, DESIGN.md §2.10).
//! - [`policy`] — the pure aggregation state machine: async / sync /
//!   hybrid(smooth|strict).
//! - [`compress`] — selectable gradient wire formats (dense / top-k with
//!   error feedback / int8), worker-side encoding into recycled buffers,
//!   and the borrowed views the state machines consume.
//! - [`shard`] — contiguous θ sharding and the pure sharded state machine
//!   (`S = 1` reproduces the unsharded semantics bitwise).
//! - [`membership`] — elastic worker membership: the live-set tracker that
//!   lets `K(n)` and sync barriers renormalize as workers join and leave a
//!   running job (DESIGN.md §2.7).
//! - [`delay`] — the paper's worker-heterogeneity injection model.
//! - [`clock`] — time as a capability: real + virtual clocks behind one
//!   trait, threaded through every layer that paces or timestamps.
//! - [`server`] / [`worker`] — the threaded sharded parameter-server
//!   protocol (one server thread per shard, O(1) version-token replies).
//! - [`trainer`] — one-call orchestration of a full training run.
//! - [`sim`] — the deterministic virtual-time simulator: the same
//!   pipeline single-threaded over an event queue, with fault injection
//!   (crashes, stragglers, message loss, shard stalls) and a scenario DSL.
//! - [`metrics`] — metric time series and run summaries.

pub mod adaptive;
pub mod buffer;
pub mod checkpoint;
pub mod clock;
pub mod compress;
pub mod delay;
pub mod membership;
pub mod metrics;
pub mod params;
pub mod policy;
pub mod server;
pub mod shard;
pub mod sim;
pub mod threshold;
pub mod trainer;
pub mod worker;

pub use adaptive::{AdaptiveConfig, AdaptiveController};
pub use buffer::AggregateMode;
pub use clock::{Clock, RealClock, VirtualClock};
pub use compress::{
    GradEncoder, GradView, KSpec, QuantGrad, ShardGrad, SparseGrad, SparseQuantGrad,
    TopKCompressor, WireFormat,
};
pub use delay::{DelayDist, DelayModel};
pub use membership::Membership;
pub use metrics::{peak_rss_bytes, replay_stream, MetricsStream, RunMetrics, SeriesId};
pub use params::{ParamDtype, ParamSnapshot, SnapshotCell};
pub use policy::{Aggregator, Outcome, Policy};
pub use server::ShardEvent;
pub use shard::{ShardLayout, ShardedAggregator};
pub use sim::{simulate, FaultPlan, FaultSpec, Scenario, Simulation};
pub use threshold::Schedule;
pub use trainer::{join_remote, serve, serve_with, train, EvalSet, RunInputs, TrainConfig};
