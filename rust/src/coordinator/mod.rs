//! L3 — the parameter-server coordinator (the paper's contribution).
//!
//! Layering, bottom-up:
//! - [`threshold`] — monotone threshold schedules `K(n)` (paper Algorithm 1
//!   step 3; §9 pluggable variants).
//! - [`params`] / [`buffer`] — versioned parameter store (with zero-copy
//!   snapshot cells) and the summing gradient buffer.
//! - [`policy`] — the pure aggregation state machine: async / sync /
//!   hybrid(smooth|strict).
//! - [`shard`] — contiguous θ sharding and the pure sharded state machine
//!   (`S = 1` reproduces the unsharded semantics bitwise).
//! - [`delay`] — the paper's worker-heterogeneity injection model.
//! - [`server`] / [`worker`] — the threaded sharded parameter-server
//!   protocol (one server thread per shard, O(1) version-token replies).
//! - [`trainer`] — one-call orchestration of a full training run.
//! - [`metrics`] — metric time series and run summaries.

pub mod adaptive;
pub mod buffer;
pub mod checkpoint;
pub mod compress;
pub mod delay;
pub mod metrics;
pub mod params;
pub mod policy;
pub mod server;
pub mod shard;
pub mod threshold;
pub mod trainer;
pub mod worker;

pub use adaptive::{AdaptiveConfig, AdaptiveController};
pub use delay::DelayModel;
pub use metrics::RunMetrics;
pub use params::{ParamSnapshot, SnapshotCell};
pub use policy::{Aggregator, Outcome, Policy};
pub use shard::{ShardLayout, ShardedAggregator};
pub use threshold::Schedule;
pub use trainer::{train, EvalSet, RunInputs, TrainConfig};
