//! Shard layout and the pure sharded aggregation state machine.
//!
//! The sharded parameter server splits the flat θ into `S` contiguous
//! shards. Every gradient is logically delivered to *every* shard (each
//! shard consumes its slice), so each shard's [`Aggregator`] observes the
//! identical arrival sequence: per-shard `K(n)` state, barriers and flushes
//! evolve in lockstep, and the concatenation of shard parameters is bitwise
//! identical to the unsharded path for any `S`. [`ShardedAggregator`] is the
//! single-threaded embodiment of that invariant — property tests drive it
//! against the unsharded `Aggregator` + `ParamStore` pair, and the threaded
//! server (`server.rs`) runs one `Aggregator` + `ParamStore` per shard
//! thread with exactly the same per-arrival calls. (In the threaded server
//! the *order* of concurrent arrivals can differ per shard channel; the
//! count-triggered policies are order-insensitive, while the adaptive
//! controller may transiently diverge across shards — see `server.rs`.)

use super::compress::{GradView, ShardGrad, SparseGrad};
use super::params::{ParamStore, SnapshotCell};
use super::policy::{Aggregator, Outcome, Policy};
use std::ops::Range;
use std::sync::Arc;

/// Balanced contiguous partition of a flat parameter vector.
///
/// The effective shard count is clamped to `[1, dim.max(1)]` so no shard is
/// empty; the first `dim % shards` shards are one element longer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    /// Shard boundaries: `bounds[s]..bounds[s + 1]` is shard `s`.
    bounds: Vec<usize>,
}

impl ShardLayout {
    pub fn new(dim: usize, shards: usize) -> ShardLayout {
        let shards = shards.clamp(1, dim.max(1));
        let base = dim / shards;
        let extra = dim % shards;
        let mut bounds = Vec::with_capacity(shards + 1);
        let mut off = 0;
        bounds.push(0);
        for s in 0..shards {
            off += base + usize::from(s < extra);
            bounds.push(off);
        }
        debug_assert_eq!(off, dim);
        ShardLayout { bounds }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Full parameter dimension.
    pub fn dim(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Index range of shard `s`.
    pub fn range(&self, s: usize) -> Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Iterate over all shard ranges.
    pub fn ranges(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.shards()).map(|s| self.range(s))
    }

    /// Split a full-dim slice into per-shard owned vectors.
    pub fn split(&self, full: &[f32]) -> Vec<Vec<f32>> {
        assert_eq!(full.len(), self.dim());
        self.ranges().map(|r| full[r].to_vec()).collect()
    }
}

/// Fresh per-shard snapshot cells for `init` (what the trainer hands to the
/// shard servers, the workers and the evaluator).
pub fn shard_cells(init: &[f32], layout: &ShardLayout) -> Vec<Arc<SnapshotCell>> {
    layout
        .ranges()
        .map(|r| Arc::new(SnapshotCell::new(init[r].to_vec())))
        .collect()
}

/// Assemble the full parameter vector from per-shard snapshot cells into
/// `out`; returns the minimum published version across shards.
pub fn assemble_params(
    cells: &[Arc<SnapshotCell>],
    layout: &ShardLayout,
    out: &mut [f32],
) -> u64 {
    assert_eq!(out.len(), layout.dim());
    assert_eq!(cells.len(), layout.shards());
    let mut min_version = u64::MAX;
    for (cell, r) in cells.iter().zip(layout.ranges()) {
        let snap = cell.load();
        snap.copy_to(&mut out[r]);
        min_version = min_version.min(snap.version);
    }
    min_version
}

/// The sharded policy state machine: one [`Aggregator`] + [`ParamStore`] per
/// contiguous shard, driven sequentially. Semantically identical to a single
/// `Aggregator` over the full vector for every `S` (see module docs).
pub struct ShardedAggregator {
    layout: ShardLayout,
    shards: Vec<(Aggregator, ParamStore)>,
}

impl ShardedAggregator {
    pub fn new(policy: Policy, init: &[f32], lr: f32, workers: usize, shards: usize) -> Self {
        let layout = ShardLayout::new(init.len(), shards);
        let shards = layout
            .ranges()
            .map(|r| {
                let dim = r.len();
                (
                    Aggregator::new(policy.clone(), dim, workers),
                    ParamStore::new(init[r].to_vec(), lr),
                )
            })
            .collect();
        ShardedAggregator { layout, shards }
    }

    /// Override the threshold cap on every shard (default = worker count).
    pub fn with_k_max(mut self, k_max: usize) -> Self {
        self.shards = self
            .shards
            .into_iter()
            .map(|(agg, ps)| (agg.with_k_max(k_max), ps))
            .collect();
        self
    }

    /// Select the flush-time aggregation mode on every shard (see
    /// [`Aggregator::with_aggregate`]). Trimmed-mean/median act
    /// coordinate-wise, so they keep the sharding-invisibility invariant;
    /// norm clipping is computed over each shard's slice independently
    /// (documented in DESIGN.md §2.10).
    pub fn with_aggregate(mut self, mode: super::buffer::AggregateMode) -> Self {
        self.shards = self
            .shards
            .into_iter()
            .map(|(agg, ps)| (agg.with_aggregate(mode.clone()), ps))
            .collect();
        self
    }

    /// Enable elastic membership on every shard (see
    /// [`Aggregator::with_elastic`]).
    pub fn with_elastic(mut self, initial_live: usize, min_quorum: usize) -> Self {
        self.shards = self
            .shards
            .into_iter()
            .map(|(agg, ps)| (agg.with_elastic(initial_live, min_quorum), ps))
            .collect();
        self
    }

    /// Apply a membership join to every shard. Returns whether the live
    /// set changed (identical across shards by construction).
    pub fn member_join(&mut self, worker: usize) -> bool {
        let mut changed = false;
        for (agg, _) in &mut self.shards {
            changed = agg.member_join(worker);
        }
        changed
    }

    /// Apply a membership departure to every shard; returns shard 0's
    /// flush outcome, if the shrunken barrier released one (all shards
    /// agree — checked in debug builds).
    pub fn member_leave(&mut self, worker: usize) -> Option<Outcome> {
        let mut first: Option<Option<Outcome>> = None;
        for (agg, ps) in &mut self.shards {
            let (_, out) = agg.member_leave(ps, worker);
            match &first {
                None => first = Some(out),
                Some(f) => debug_assert_eq!(
                    f.is_some(),
                    out.is_some(),
                    "shards diverged on a membership flush"
                ),
            }
        }
        first.unwrap_or(None)
    }

    /// Live membership (identical across shards by construction).
    pub fn live(&self) -> usize {
        self.shards[0].0.live()
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// Parameter version (identical across shards by construction).
    pub fn version(&self) -> u64 {
        self.shards[0].1.version()
    }

    /// Current threshold of shard 0 (identical across shards).
    pub fn current_k(&self) -> usize {
        self.shards[0].0.current_k()
    }

    /// Feed one full-dim gradient to every shard; returns shard 0's outcome
    /// (all shards agree — checked in debug builds).
    pub fn on_gradient(
        &mut self,
        grad: &[f32],
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        assert_eq!(grad.len(), self.layout.dim());
        let mut first: Option<Outcome> = None;
        for (s, r) in self.layout.ranges().enumerate() {
            let (agg, ps) = &mut self.shards[s];
            let out = agg.on_gradient(ps, &grad[r], worker, base_version, loss);
            match &first {
                None => first = Some(out),
                Some(f) => debug_assert_eq!(
                    std::mem::discriminant(f),
                    std::mem::discriminant(&out),
                    "shard {s} diverged from shard 0"
                ),
            }
        }
        first.unwrap()
    }

    /// Feed one full-dim *compressed* gradient: pre-split into per-shard
    /// sparse slices via [`SparseGrad::split_shards`], then aggregated
    /// shard-by-shard as O(nnz) scatter-adds — the sequential embodiment of
    /// what the compressed wire protocol does across shard threads (no
    /// shard ever sees, or densifies, another shard's coordinates).
    pub fn on_sparse(
        &mut self,
        grad: &SparseGrad,
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        assert_eq!(grad.dim, self.layout.dim());
        let parts = grad.split_shards(&self.layout);
        let mut first: Option<Outcome> = None;
        for (s, part) in parts.iter().enumerate() {
            let (agg, ps) = &mut self.shards[s];
            let out = agg.on_gradient_view(
                ps,
                GradView::Sparse {
                    idx: &part.idx,
                    val: &part.val,
                },
                worker,
                base_version,
                loss,
            );
            match &first {
                None => first = Some(out),
                Some(f) => debug_assert_eq!(
                    std::mem::discriminant(f),
                    std::mem::discriminant(&out),
                    "shard {s} diverged from shard 0"
                ),
            }
        }
        first.unwrap()
    }

    /// Feed one submission already encoded as per-shard wire payloads (one
    /// [`ShardGrad`] per shard in shard order — what [`super::compress::GradEncoder::encode`]
    /// produces). Returns shard 0's outcome.
    pub fn on_payload(
        &mut self,
        payloads: &[ShardGrad],
        worker: usize,
        base_version: u64,
        loss: f32,
    ) -> Outcome {
        assert_eq!(payloads.len(), self.layout.shards());
        let mut first: Option<Outcome> = None;
        for (s, r) in self.layout.ranges().enumerate() {
            let (agg, ps) = &mut self.shards[s];
            let out =
                agg.on_gradient_view(ps, payloads[s].view(r), worker, base_version, loss);
            match &first {
                None => first = Some(out),
                Some(f) => debug_assert_eq!(
                    std::mem::discriminant(f),
                    std::mem::discriminant(&out),
                    "shard {s} diverged from shard 0"
                ),
            }
        }
        first.unwrap()
    }

    /// Force-flush buffered gradients on every shard (shutdown path).
    /// Returns the flushed count (identical across shards).
    pub fn drain(&mut self) -> usize {
        let mut n = 0;
        for (agg, ps) in &mut self.shards {
            n = agg.drain(ps);
        }
        n
    }

    /// Concatenated final parameters in shard order.
    pub fn final_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.layout.dim());
        for (_, ps) in &self.shards {
            out.extend_from_slice(ps.theta());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::threshold::Schedule;
    use crate::util::rng::Pcg64;

    #[test]
    fn layout_partitions_balanced() {
        let l = ShardLayout::new(10, 4);
        assert_eq!(l.shards(), 4);
        assert_eq!(l.dim(), 10);
        let lens: Vec<usize> = l.ranges().map(|r| r.len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
        assert_eq!(l.range(0), 0..3);
        assert_eq!(l.range(3), 8..10);
    }

    #[test]
    fn layout_clamps_degenerate_counts() {
        assert_eq!(ShardLayout::new(3, 8).shards(), 3);
        assert_eq!(ShardLayout::new(5, 0).shards(), 1);
        assert_eq!(ShardLayout::new(0, 4).shards(), 1);
        assert_eq!(ShardLayout::new(0, 4).dim(), 0);
    }

    #[test]
    fn split_and_cells_round_trip() {
        let full: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let l = ShardLayout::new(7, 3);
        let parts = l.split(&full);
        assert_eq!(parts.concat(), full);
        let cells = shard_cells(&full, &l);
        let mut out = vec![0.0f32; 7];
        let v = assemble_params(&cells, &l, &mut out);
        assert_eq!(out, full);
        assert_eq!(v, 0);
    }

    /// Golden-trace equivalence: the S = 1 sharded machine reproduces the
    /// unsharded `Aggregator` + `ParamStore` exactly — same update count,
    /// bitwise-identical parameters and identical K at every arrival — for
    /// a fixed seeded gradient stream.
    #[test]
    fn s1_matches_unsharded_golden_trace() {
        let policy = Policy::Hybrid {
            schedule: Schedule::Step { step: 7 },
            strict: false,
        };
        let dim = 33;
        let workers = 4;
        let mut rng = Pcg64::seeded(1234);
        let mut init = vec![0.0f32; dim];
        rng.fill_normal(&mut init, 0.5);

        let mut reference = Aggregator::new(policy.clone(), dim, workers);
        let mut ref_ps = ParamStore::new(init.clone(), 0.05);
        let mut sharded = ShardedAggregator::new(policy, &init, 0.05, workers, 1);

        let mut grad = vec![0.0f32; dim];
        for i in 0..200 {
            rng.fill_normal(&mut grad, 1.0);
            let w = i % workers;
            let (vr, vs) = (ref_ps.version(), sharded.version());
            assert_eq!(vr, vs, "version diverged at arrival {i}");
            let out_ref = reference.on_gradient(&mut ref_ps, &grad, w, vr, 1.0);
            let out_sh = sharded.on_gradient(&grad, w, vs, 1.0);
            assert_eq!(out_ref, out_sh, "outcome diverged at arrival {i}");
            assert_eq!(reference.current_k(), sharded.current_k());
        }
        reference.drain(&mut ref_ps);
        sharded.drain();
        assert_eq!(ref_ps.version(), sharded.version());
        assert_eq!(ref_ps.theta(), &sharded.final_params()[..]);
    }

    /// Golden trace for the wire-format refactor: driving the machine with
    /// `dense` wire payloads (the full `GradEncoder` → `ShardGrad::view`
    /// path) is bitwise identical to the plain `on_gradient` slice path —
    /// i.e. `compress=dense` reproduces the pre-wire-format pipeline
    /// exactly, outcome by outcome and parameter by parameter.
    #[test]
    fn dense_payload_path_matches_plain_dense_golden_trace() {
        use crate::coordinator::compress::{GradEncoder, WireFormat};
        let policy = Policy::Hybrid {
            schedule: Schedule::Step { step: 6 },
            strict: false,
        };
        let dim = 29;
        let workers = 3;
        let mut rng = Pcg64::seeded(4321);
        let mut init = vec![0.0f32; dim];
        rng.fill_normal(&mut init, 0.5);
        for shards in [1usize, 3] {
            let mut reference = ShardedAggregator::new(policy.clone(), &init, 0.05, workers, shards);
            let mut wired = ShardedAggregator::new(policy.clone(), &init, 0.05, workers, shards);
            let mut enc = GradEncoder::new(WireFormat::Dense, dim, wired.layout().shards());
            let mut payloads = Vec::new();
            let layout = wired.layout().clone();
            let mut grad = vec![0.0f32; dim];
            for i in 0..150 {
                rng.fill_normal(&mut grad, 1.0);
                let w = i % workers;
                let (vr, vw) = (reference.version(), wired.version());
                assert_eq!(vr, vw, "version diverged at arrival {i}");
                enc.encode(&grad, &layout, &mut payloads);
                let out_ref = reference.on_gradient(&grad, w, vr, 1.0);
                let out_wire = wired.on_payload(&payloads, w, vw, 1.0);
                assert_eq!(out_ref, out_wire, "outcome diverged at arrival {i}");
            }
            reference.drain();
            wired.drain();
            assert_eq!(reference.final_params(), wired.final_params(), "S={shards}");
        }
    }

    /// Sparse submissions split per shard reproduce the whole-vector dense
    /// apply of their reconstruction, for every shard count.
    #[test]
    fn sparse_split_matches_dense_reconstruction() {
        let dim = 23;
        let workers = 2;
        let mut rng = Pcg64::seeded(87);
        let mut init = vec![0.0f32; dim];
        rng.fill_normal(&mut init, 1.0);
        for shards in [1usize, 2, 4] {
            let mut dense_m = ShardedAggregator::new(Policy::Async, &init, 0.1, workers, shards);
            let mut sparse_m = ShardedAggregator::new(Policy::Async, &init, 0.1, workers, shards);
            let mut comp = crate::coordinator::compress::TopKCompressor::new(dim, 5);
            let mut grad = vec![0.0f32; dim];
            for i in 0..60 {
                rng.fill_normal(&mut grad, 1.0);
                let sg = comp.compress(&grad);
                let recon = sg.to_dense();
                let v = dense_m.version();
                assert_eq!(v, sparse_m.version());
                dense_m.on_gradient(&recon, i % workers, v, 1.0);
                sparse_m.on_sparse(&sg, i % workers, v, 1.0);
            }
            assert_eq!(
                dense_m.final_params(),
                sparse_m.final_params(),
                "S={shards}"
            );
        }
    }

    /// Elastic membership keeps the lockstep invariant: the same
    /// (gradient | membership) event sequence produces bitwise-identical
    /// parameters for every shard count, and the membership flush fires on
    /// all shard counts alike.
    #[test]
    fn elastic_membership_agrees_across_shard_counts_bitwise() {
        let dim = 19;
        let workers = 3;
        let mut rng = Pcg64::seeded(31);
        let mut init = vec![0.0f32; dim];
        rng.fill_normal(&mut init, 1.0);
        let policy = Policy::Hybrid {
            schedule: Schedule::Constant { k: 3 },
            strict: true,
        };
        let mut machines: Vec<ShardedAggregator> = [1usize, 2, 4]
            .iter()
            .map(|&s| {
                ShardedAggregator::new(policy.clone(), &init, 0.1, workers, s)
                    .with_elastic(workers, 1)
            })
            .collect();
        let mut grad = vec![0.0f32; dim];
        // Two contributions buffer toward the strict K=3 barrier …
        for w in 0..2usize {
            rng.fill_normal(&mut grad, 1.0);
            let v = machines[0].version();
            for m in &mut machines {
                assert_eq!(m.version(), v);
                m.on_gradient(&grad, w, v, 1.0);
            }
        }
        // … and worker 2's departure releases it on every shard count.
        for m in &mut machines {
            let out = m.member_leave(2);
            assert!(
                matches!(out, Some(Outcome::Flushed { count: 2, .. })),
                "departure must flush the shrunken barrier, got {out:?}"
            );
            assert_eq!(m.live(), 2);
            assert_eq!(m.current_k(), 2);
        }
        let finals: Vec<Vec<f32>> = machines
            .iter_mut()
            .map(|m| {
                m.drain();
                m.final_params()
            })
            .collect();
        assert_eq!(finals[0], finals[1]);
        assert_eq!(finals[0], finals[2]);
    }

    /// Robust aggregation is coordinate-wise, so it keeps the
    /// sharding-invisibility invariant: trimmed-mean and median flushes
    /// produce bitwise the same parameters for every shard count, even
    /// with a Byzantine worker in the stream.
    #[test]
    fn robust_modes_agree_across_shard_counts_bitwise() {
        use crate::coordinator::buffer::AggregateMode;
        for mode in [AggregateMode::Trimmed(0.25), AggregateMode::Median] {
            let dim = 21;
            let workers = 4;
            let mut rng = Pcg64::seeded(55);
            let mut init = vec![0.0f32; dim];
            rng.fill_normal(&mut init, 1.0);
            let policy = Policy::Hybrid {
                schedule: Schedule::Constant { k: 4 },
                strict: false,
            };
            let mut machines: Vec<ShardedAggregator> = [1usize, 2, 4]
                .iter()
                .map(|&s| {
                    ShardedAggregator::new(policy.clone(), &init, 0.1, workers, s)
                        .with_aggregate(mode.clone())
                })
                .collect();
            let mut grad = vec![0.0f32; dim];
            for i in 0..80 {
                rng.fill_normal(&mut grad, 1.0);
                let w = i % workers;
                if w == 3 {
                    // Byzantine: scaled sign-flip
                    for g in grad.iter_mut() {
                        *g *= -50.0;
                    }
                }
                let v = machines[0].version();
                for m in &mut machines {
                    assert_eq!(m.version(), v);
                    m.on_gradient(&grad, w, v, 1.0);
                }
            }
            let finals: Vec<Vec<f32>> = machines
                .iter_mut()
                .map(|m| {
                    m.drain();
                    m.final_params()
                })
                .collect();
            assert_eq!(finals[0], finals[1], "{mode}: S=2 diverged");
            assert_eq!(finals[0], finals[2], "{mode}: S=4 diverged");
            // and the defense actually defended: θ stayed bounded
            let norm: f64 = finals[0].iter().map(|&v| v as f64 * v as f64).sum();
            assert!(norm.sqrt() < 100.0, "{mode}: θ blew up: {}", norm.sqrt());
        }
    }

    /// Sharding is invisible to the math: S ∈ {2, 5} produce bitwise the
    /// same parameters as S = 1 under async, sync and hybrid.
    #[test]
    fn shard_counts_agree_bitwise() {
        for policy in [
            Policy::Async,
            Policy::Sync,
            Policy::Hybrid {
                schedule: Schedule::Step { step: 5 },
                strict: true,
            },
        ] {
            let dim = 17;
            let workers = 3;
            let mut rng = Pcg64::seeded(9);
            let mut init = vec![0.0f32; dim];
            rng.fill_normal(&mut init, 1.0);
            let mut machines: Vec<ShardedAggregator> = [1usize, 2, 5]
                .iter()
                .map(|&s| ShardedAggregator::new(policy.clone(), &init, 0.1, workers, s))
                .collect();
            let mut grad = vec![0.0f32; dim];
            for i in 0..120 {
                rng.fill_normal(&mut grad, 1.0);
                let w = i % workers;
                let v = machines[0].version();
                for m in &mut machines {
                    assert_eq!(m.version(), v);
                    m.on_gradient(&grad, w, v, 1.0);
                }
            }
            let finals: Vec<Vec<f32>> = machines
                .iter_mut()
                .map(|m| {
                    m.drain();
                    m.final_params()
                })
                .collect();
            assert_eq!(finals[0], finals[1], "{policy}: S=2 diverged");
            assert_eq!(finals[0], finals[2], "{policy}: S=5 diverged");
        }
    }
}
