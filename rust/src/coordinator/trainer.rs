//! End-to-end training orchestration: spawn `S` shard-server threads, `W`
//! gradient workers and an evaluator; run for a wall-clock budget; return
//! the metric series. This is the function every example, experiment and
//! benchmark drives.

use super::buffer::AggregateMode;
use super::clock::{Clock, RealClock};
use super::compress::WireFormat;
use super::delay::DelayModel;
use super::metrics::{MetricsStream, RunMetrics, SeriesId};
use super::params::ParamDtype;
use super::policy::Policy;
use super::server::{merge_reports, run_shard, Reply, ServerConfig, ShardEvent, StatusBoard};
use super::shard::{assemble_params, shard_cells, ShardLayout};
use super::worker::{run_worker, BatchSource, ShardEndpoints, WorkerConfig};
use crate::data::Dataset;
use crate::engine::EngineFactory;
use crate::log_info;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Evaluation tensors: `n` samples of `x_dim` features and `y_dim` label
/// items each (`y_dim = 1` for classification, `seq_len` for LM targets).
#[derive(Clone, Debug)]
pub struct EvalSet {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub n: usize,
    pub x_dim: usize,
    pub y_dim: usize,
}

impl EvalSet {
    /// Build from a supervised dataset, capped at `max_n` samples.
    pub fn from_dataset(d: &Dataset, max_n: usize, rng: &mut Pcg64) -> EvalSet {
        let sub = if d.len() > max_n {
            d.subsample(max_n, rng)
        } else {
            d.clone()
        };
        EvalSet {
            n: sub.len(),
            x_dim: sub.dim,
            y_dim: 1,
            x: sub.x,
            y: sub.y,
        }
    }

    /// Build from token windows (LM): each sample is a window; labels are
    /// the `seq_len` next-token targets.
    pub fn from_tokens(
        d: &crate::data::tokens::TokenDataset,
        windows: &[usize],
        max_n: usize,
    ) -> EvalSet {
        let n = windows.len().min(max_n);
        let l = d.seq_len;
        let mut x = vec![0.0f32; n * l];
        let mut y = vec![0i32; n * l];
        let mut inp = vec![0i32; l];
        for (j, &w) in windows.iter().take(n).enumerate() {
            d.window(w, &mut inp, &mut y[j * l..(j + 1) * l]);
            for (o, &t) in x[j * l..(j + 1) * l].iter_mut().zip(&inp) {
                *o = t as f32;
            }
        }
        EvalSet {
            x,
            y,
            n,
            x_dim: l,
            y_dim: l,
        }
    }
}

/// Full training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub policy: Policy,
    pub workers: usize,
    pub lr: f32,
    /// Training budget: wall-clock under [`train`], virtual time under
    /// [`super::sim::simulate`].
    pub duration: Duration,
    pub delay: DelayModel,
    pub seed: u64,
    /// How often the evaluator samples metrics.
    pub eval_interval: Duration,
    /// Cap on the threshold K (None → worker count).
    pub k_max: Option<usize>,
    /// Per-gradient compute-cost floor applied to every worker
    /// (see `WorkerConfig::min_iter`).
    pub compute_floor: Duration,
    /// Parameter-server shard count (contiguous θ slices, one server
    /// thread each). 1 reproduces the single-server semantics exactly.
    pub shards: usize,
    /// Gradient wire format (`dense` reproduces the uncompressed pipeline
    /// bitwise; see `coordinator::compress`).
    pub wire: WireFormat,
    /// Per-worker gradient-submission budget (`--steps`). When set, the
    /// run ends as soon as every worker has submitted this many gradients
    /// (with `duration` as a hard deadline backstop) — the deterministic
    /// alternative to a wall-clock budget, used by the multi-process
    /// acceptance tests to compare runs bitwise.
    pub steps: Option<u64>,
    /// Elastic membership (`--elastic`): renormalize `K(n)` and sync
    /// barriers to the live worker set as workers join/leave/crash, so a
    /// permanent worker loss shrinks the barrier instead of stalling it.
    /// Off (the default) reproduces the static-membership pipeline
    /// bitwise.
    pub elastic: bool,
    /// Barrier-denominator floor under `elastic` (`--min-quorum`, >= 1):
    /// the renormalized barrier never drops below this many workers, so a
    /// depleted run waits for joiners instead of degenerating to K = 1.
    pub min_quorum: usize,
    /// Streaming metrics sink (`--metrics-stream`): live series samples
    /// are appended here as JSONL the moment they are recorded, instead of
    /// only living in memory until the end-of-run dump. `None` (the
    /// default) reproduces the in-memory-only behaviour bitwise.
    pub stream: Option<Arc<MetricsStream>>,
    /// Server-side aggregation mode (`--aggregate mean|clip:<c>|
    /// trimmed:<f>|median`). `Mean` — the default — reproduces the
    /// historical sum-then-flush path bitwise; the robust modes are the
    /// Byzantine defenses of DESIGN.md §2.10 and require a buffering
    /// policy (sync or hybrid).
    pub aggregate: AggregateMode,
    /// How synthetic training data is split across workers
    /// (`partition=iid|dirichlet:<alpha>`): round-robin IID (the default,
    /// bitwise-identical to the historical sharding) or Dirichlet
    /// label-skewed non-IID shards. Consumed by the batch-source builders,
    /// carried here so one scenario string describes the whole run.
    pub partition: crate::data::Partition,
    /// Gradient-lifecycle flight recorder (`--trace FILE`): when set, the
    /// workers, shard servers and frontends stamp span/instant events into
    /// this ring (DESIGN.md §2.11). `None` (the default) keeps the hot
    /// path free of clock reads and reproduces the untraced run bitwise.
    pub trace: Option<Arc<crate::util::trace::TraceRing>>,
    /// Snapshot storage precision (`--param-dtype f32|f16|bf16`): master
    /// weights and the update path stay f32; published snapshots (and
    /// their wire payloads) use this dtype. `F32` — the default —
    /// reproduces every existing path bitwise; the half formats halve
    /// big-model snapshot memory and refresh traffic (DESIGN.md §2.12).
    pub param_dtype: ParamDtype,
}

impl TrainConfig {
    pub fn quick(policy: Policy, workers: usize, secs: f64) -> TrainConfig {
        TrainConfig {
            policy,
            workers,
            lr: 0.01,
            duration: Duration::from_secs_f64(secs),
            delay: DelayModel::paper_default(),
            seed: 0,
            eval_interval: Duration::from_millis(500),
            k_max: None,
            compute_floor: Duration::ZERO,
            shards: 1,
            wire: WireFormat::Dense,
            steps: None,
            elastic: false,
            min_quorum: 1,
            stream: None,
            aggregate: AggregateMode::Mean,
            partition: crate::data::Partition::Iid,
            trace: None,
            param_dtype: ParamDtype::F32,
        }
    }
}

/// Config validation shared by [`train`], [`serve_with`] and the
/// simulator's scenario checks.
pub(crate) fn validate_config(cfg: &TrainConfig) -> anyhow::Result<()> {
    if cfg.elastic {
        anyhow::ensure!(
            cfg.min_quorum <= cfg.workers,
            "--min-quorum {} can never be met with {} worker slots \
             (the barrier would stall forever)",
            cfg.min_quorum,
            cfg.workers
        );
    }
    // The robust estimators need a buffered round to trim across; the
    // async policy applies every gradient immediately and never flushes.
    anyhow::ensure!(
        !(cfg.aggregate.retains_rows() && matches!(cfg.policy, Policy::Async)),
        "--aggregate {} needs a buffering policy (sync or hybrid): \
         async applies each gradient on arrival, so there is no round to \
         trim across",
        cfg.aggregate
    );
    Ok(())
}

/// Startup guard for the TCP paths (serve and join): a gradient submission
/// travels as ONE frame per shard, so a geometry whose worst-case
/// `SubmitGrad` payload exceeds the frame limit would not fail until the
/// first gradient poisons the stream mid-run. Caught here at config time
/// instead, with the fix spelled out. Snapshot refreshes no longer
/// constrain the geometry — oversized slices are chunked into
/// `SnapshotDelta` frames (DESIGN.md §2.12) — so only the gradient plane
/// binds. In-process and simulated runs never hit the framing layer and
/// are not subject to this check.
pub fn validate_net_geometry(dim: usize, shards: usize, wire: &WireFormat) -> anyhow::Result<()> {
    use crate::transport::frame::MAX_PAYLOAD;
    let layout = ShardLayout::new(dim, shards);
    let max_len = layout.ranges().map(|r| r.len()).max().unwrap_or(0);
    // Worst-case encoded SubmitGrad payload (25 B submit header + grad
    // arm); for the sparse arms the worst case is every kept coordinate
    // landing in the largest shard.
    let (bytes, per_coord) = match wire {
        WireFormat::Dense => (30 + 4 * max_len, 4usize),
        WireFormat::Int8 => (34 + max_len, 1),
        WireFormat::TopK(k) => (34 + 8 * k.resolve(dim).min(max_len), 8),
        WireFormat::TopKInt8(k) => (38 + 5 * k.resolve(dim).min(max_len), 5),
    };
    if bytes > MAX_PAYLOAD {
        // Largest shard that fits this wire format, with header headroom;
        // splitting to that size always fits (sparse worst cases shrink
        // with the shard).
        let fit_len = (MAX_PAYLOAD - 64) / per_coord;
        let need = (dim + fit_len - 1) / fit_len;
        anyhow::bail!(
            "wire format `{wire}` needs up to {bytes} B for one gradient frame of the \
             largest shard ({max_len} of {dim} coordinates over {shards} shard(s)), \
             above the {MAX_PAYLOAD} B frame limit; run both serve and join with \
             --shards {need} (or more), or pick a sparser --wire"
        );
    }
    Ok(())
}

/// Raises the stop flag on *every* exit from a training thread scope
/// (including `?` error paths), or the scoped joins would hang forever.
struct StopGuard<'a>(&'a AtomicBool);

impl Drop for StopGuard<'_> {
    fn drop(&mut self) {
        self.0.store(true, Ordering::Relaxed);
    }
}

/// Everything a run needs besides the config: per-worker engines + batch
/// sources (constructed inside the worker threads) and eval data.
pub struct RunInputs<'a> {
    /// Engine factory for gradient workers (batch-size of the training batch).
    pub worker_engine: EngineFactory,
    /// Engine factory for the evaluator (its batch size defines eval chunks).
    pub eval_engine: EngineFactory,
    /// Builds worker `id`'s batch source (seeded shard sampler).
    pub batch_source: Arc<dyn Fn(usize) -> Box<dyn BatchSource> + Send + Sync>,
    /// Initial flat parameters (identical across compared algorithms).
    pub init_params: &'a [f32],
    /// Test set for test-loss/accuracy.
    pub test: &'a EvalSet,
    /// Fixed train subset for the train-loss probe.
    pub train_probe: &'a EvalSet,
}

/// Run one training job; blocks until the budget elapses and all threads
/// join. Deterministic given (config.seed, inputs) up to OS scheduling.
/// For a *fully* deterministic single-threaded run of the same pipeline in
/// virtual time, see [`super::sim::simulate`].
pub fn train(cfg: &TrainConfig, inputs: &RunInputs) -> anyhow::Result<RunMetrics> {
    validate_config(cfg)?;
    let clock_owned = Arc::new(RealClock::start());
    let clock: &dyn Clock = clock_owned.as_ref();
    // Trace timestamps and log lines share the run's timebase: the ring's
    // epoch is the clock anchor, and the logger reads run-relative time for
    // the duration of the run (restored on exit by the guard).
    if let Some(tr) = &cfg.trace {
        tr.set_epoch(clock_owned.started_at());
    }
    let _log_clock = crate::util::logging::set_run_clock({
        let c = Arc::clone(&clock_owned);
        Arc::new(move || c.now())
    });
    let stop = AtomicBool::new(false);
    let layout = ShardLayout::new(inputs.init_params.len(), cfg.shards);
    let cells = shard_cells(inputs.init_params, &layout);

    // One gradient channel per shard; one reply channel per worker, its
    // sender cloned into every shard thread.
    let mut grad_txs = Vec::with_capacity(layout.shards());
    let mut grad_rxs = Vec::with_capacity(layout.shards());
    for _ in 0..layout.shards() {
        let (tx, rx) = mpsc::channel::<ShardEvent>();
        grad_txs.push(tx);
        grad_rxs.push(Some(rx));
    }
    let mut reply_txs = Vec::with_capacity(cfg.workers);
    let mut reply_rxs = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<Reply>();
        reply_txs.push(tx);
        reply_rxs.push(Some(rx));
    }
    let mut delay_rng = Pcg64::new(cfg.seed, 7);
    let delayed_flags = cfg.delay.assign(cfg.workers, &mut delay_rng);

    let server_cfg = ServerConfig {
        policy: cfg.policy.clone(),
        workers: cfg.workers,
        lr: cfg.lr,
        dtype: cfg.param_dtype,
        k_max: cfg.k_max,
        trace_interval: Duration::from_millis(200),
        elastic: cfg.elastic,
        min_quorum: cfg.min_quorum,
        aggregate: cfg.aggregate.clone(),
        reply_notify: None,
        status: None,
        trace: cfg.trace.clone(),
    };

    let mut metrics = RunMetrics {
        stream: cfg.stream.clone(),
        ..Default::default()
    };
    // Workers that have returned (steps-budget runs end when all have).
    let finished = std::sync::atomic::AtomicUsize::new(0);
    let result: anyhow::Result<()> = std::thread::scope(|s| {
        let _stop_guard = StopGuard(&stop);
        // --- shard-server threads ---
        let mut shard_handles = Vec::with_capacity(layout.shards());
        for shard in 0..layout.shards() {
            let range = layout.range(shard);
            let init = inputs.init_params[range.clone()].to_vec();
            let cell = Arc::clone(&cells[shard]);
            let scfg = server_cfg.clone();
            let rtxs = reply_txs.clone();
            let grad_rx = grad_rxs[shard].take().unwrap();
            let stop_ref = &stop;
            shard_handles.push(s.spawn(move || {
                run_shard(shard, range, init, cell, &scfg, grad_rx, rtxs, stop_ref, clock)
            }));
        }
        drop(reply_txs); // shard threads own the only reply senders now

        // --- workers ---
        let mut worker_handles = Vec::new();
        for id in 0..cfg.workers {
            let reply_rx = reply_rxs[id].take().unwrap();
            let wcfg = WorkerConfig {
                id,
                delayed: delayed_flags[id],
                delay: cfg.delay.clone(),
                seed: cfg.seed.wrapping_add(1000 + id as u64),
                min_iter: cfg.compute_floor,
                wire: cfg.wire.clone(),
                max_grads: cfg.steps,
                trace: cfg.trace.clone(),
            };
            let endpoints = ShardEndpoints {
                layout: layout.clone(),
                grad_txs: grad_txs.clone(),
                cells: cells.clone(),
            };
            let factory = Arc::clone(&inputs.worker_engine);
            let source_factory = Arc::clone(&inputs.batch_source);
            let init = inputs.init_params.to_vec();
            let stop_ref = &stop;
            let finished_ref = &finished;
            // Elastic membership: announce a finished worker's departure
            // to the shard servers (budget spent, engine failure), exactly
            // as a TCP worker's disconnect does — suppressed once the run
            // is stopping, since end-of-run exits are not churn. Same
            // thread as the worker's own sends, so the Leave enqueues
            // after its last gradient on every shard channel.
            let leave_txs = if cfg.elastic { grad_txs.clone() } else { Vec::new() };
            worker_handles.push(s.spawn(move || {
                let report = (|| {
                    let engine = match factory() {
                        Ok(e) => e,
                        Err(e) => {
                            crate::log_warn!("trainer", "worker {id} engine init failed: {e:#}");
                            return super::worker::WorkerReport::default();
                        }
                    };
                    let source = source_factory(id);
                    let mut transport =
                        crate::transport::InProcTransport::new(endpoints, reply_rx);
                    run_worker(&wcfg, engine, source, init, &mut transport, stop_ref, clock)
                })();
                if !stop_ref.load(Ordering::Relaxed) {
                    for tx in &leave_txs {
                        let _ = tx.send(ShardEvent::Leave { worker: id });
                    }
                }
                finished_ref.fetch_add(1, Ordering::Relaxed);
                report
            }));
        }
        drop(grad_txs); // shard servers exit when the last worker sender drops

        // --- evaluator (this thread) ---
        let mut eval_engine = (inputs.eval_engine)()?;
        let mut eval_loop = EvalLoop {
            engine: eval_engine.as_mut(),
            test: inputs.test,
            train_probe: inputs.train_probe,
            cells: &cells,
            layout: &layout,
            clock,
        };
        let mut params_buf = inputs.init_params.to_vec();
        // t=0 sample, then periodic until the budget elapses. Under a
        // `steps` budget the loop also ends as soon as every worker has
        // spent its submissions (polling in short slices so the run does
        // not idle up to a full eval interval after the last gradient);
        // without one, the cadence is exactly the pre-steps behaviour.
        eval_loop.sample(&mut metrics, &mut params_buf)?;
        let mut since_eval = Duration::ZERO;
        while clock.now() < cfg.duration {
            if cfg.steps.is_some() && finished.load(Ordering::Relaxed) >= cfg.workers {
                break;
            }
            let remaining = cfg.duration.saturating_sub(clock.now());
            let slice = if cfg.steps.is_some() {
                Duration::from_millis(25).min(cfg.eval_interval)
            } else {
                cfg.eval_interval
            };
            clock.sleep(slice.min(remaining));
            since_eval += slice;
            if cfg.steps.is_none() || since_eval >= cfg.eval_interval {
                since_eval = Duration::ZERO;
                eval_loop.sample(&mut metrics, &mut params_buf)?;
            }
        }

        stop.store(true, Ordering::Relaxed);
        let mut bytes_sent = 0u64;
        let mut submissions = 0u64;
        let mut refresh_bytes = 0u64;
        for h in worker_handles {
            if let Ok(r) = h.join() {
                bytes_sent += r.bytes_sent;
                submissions += r.grads_sent;
                refresh_bytes += r.refresh_bytes;
            }
        }
        let reports = shard_handles
            .into_iter()
            .map(|h| h.join().expect("shard-server thread panicked"))
            .collect::<Vec<_>>();
        merge_reports(&layout, reports).fill(&mut metrics);
        metrics.bytes_sent = bytes_sent;
        metrics.refresh_bytes = refresh_bytes;
        metrics.bytes_dense_equiv = submissions * inputs.init_params.len() as u64 * 4;
        // Final sample on the drained parameters.
        eval_loop.sample(&mut metrics, &mut params_buf)?;
        Ok(())
    });
    result?;
    metrics.wall_time = clock.now().as_secs_f64();
    // Machine-level gauge, excluded from RunMetrics equality.
    metrics.peak_rss_bytes = super::metrics::peak_rss_bytes();
    if metrics.bytes_sent > 0 {
        let (t, v) = (metrics.wall_time, metrics.wire_compression());
        metrics.record(SeriesId::CompressionRatio, t, v);
    }
    if let Some(st) = &metrics.stream {
        st.flush();
    }
    log_info!(
        "trainer",
        "{} done: {} grads, {} updates, {} shards, {:.1} grads/s, final acc {:.2}%",
        cfg.policy,
        metrics.gradients_total,
        metrics.updates_total,
        metrics.shards,
        metrics.grads_per_sec(),
        metrics.final_metrics().map(|m| m.2).unwrap_or(f64::NAN)
    );
    Ok(metrics)
}

/// Serve the sharded parameter server over TCP: the multi-process
/// counterpart of [`train`]. Shard-server threads, the evaluator and the
/// metrics pipeline are identical to the in-process run; the worker
/// threads are replaced by a [`crate::transport::TcpFrontend`] bridging
/// remote workers (`hybrid-sgd join`) onto the same shard channels.
///
/// The run ends when the wall-clock budget elapses **or** when at least
/// one worker has joined and all workers have since disconnected (the
/// step-budget completion path: `join --steps N` workers leave when their
/// budget is spent). On the TCP path `bytes_sent`/`bytes_received` are
/// measured at true frame granularity over the gradient plane (DESIGN.md
/// §2.6), and `bytes_dense_equiv` uses the server-observed submission
/// count.
pub fn serve(
    cfg: &TrainConfig,
    inputs: &RunInputs,
    listener: std::net::TcpListener,
    net: &crate::transport::NetOptions,
) -> anyhow::Result<RunMetrics> {
    serve_with(
        cfg,
        inputs,
        listener,
        net,
        crate::transport::FrontendKind::Reactor,
    )
}

/// [`serve`] with an explicit frontend choice: the event-driven reactor
/// (default) or the legacy thread-per-connection frontend kept as the
/// baseline for the connections-vs-throughput comparison. Both speak the
/// identical wire protocol, so workers cannot tell them apart.
pub fn serve_with(
    cfg: &TrainConfig,
    inputs: &RunInputs,
    listener: std::net::TcpListener,
    net: &crate::transport::NetOptions,
    kind: crate::transport::FrontendKind,
) -> anyhow::Result<RunMetrics> {
    validate_config(cfg)?;
    validate_net_geometry(inputs.init_params.len(), cfg.shards, &cfg.wire)?;
    let clock_owned = Arc::new(RealClock::start());
    let clock: &dyn Clock = clock_owned.as_ref();
    // Anchor the trace ring and the logger on this run's clock, exactly as
    // in [`train`]; the frontends (which hold no `Clock`) stamp arrivals
    // through the ring's epoch so both timebases agree.
    if let Some(tr) = &cfg.trace {
        tr.set_epoch(clock_owned.started_at());
    }
    let _log_clock = crate::util::logging::set_run_clock({
        let c = Arc::clone(&clock_owned);
        Arc::new(move || c.now())
    });
    let stop = Arc::new(AtomicBool::new(false));
    let layout = ShardLayout::new(inputs.init_params.len(), cfg.shards);
    let cells = shard_cells(inputs.init_params, &layout);
    let dim = layout.dim() as u64;

    let mut grad_txs = Vec::with_capacity(layout.shards());
    let mut grad_rxs = Vec::with_capacity(layout.shards());
    for _ in 0..layout.shards() {
        let (tx, rx) = mpsc::channel::<ShardEvent>();
        grad_txs.push(tx);
        grad_rxs.push(Some(rx));
    }
    let mut reply_txs = Vec::with_capacity(cfg.workers);
    let mut reply_rxs = Vec::with_capacity(cfg.workers);
    for _ in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<Reply>();
        reply_txs.push(tx);
        reply_rxs.push(rx);
    }
    // Same heterogeneity draw as the in-process trainer; the flags travel
    // to each worker in its Welcome.
    let mut delay_rng = Pcg64::new(cfg.seed, 7);
    let delayed_flags = cfg.delay.assign(cfg.workers, &mut delay_rng);

    // The read-only ops plane: shard threads publish gauges, the frontend
    // answers StatusRequest probes from them — no shared locks, no
    // gradient-plane involvement.
    let status = Arc::new(StatusBoard::with_workers(layout.shards(), cfg.workers));
    let mut server_cfg = ServerConfig {
        policy: cfg.policy.clone(),
        workers: cfg.workers,
        lr: cfg.lr,
        dtype: cfg.param_dtype,
        k_max: cfg.k_max,
        trace_interval: Duration::from_millis(200),
        elastic: cfg.elastic,
        min_quorum: cfg.min_quorum,
        aggregate: cfg.aggregate.clone(),
        reply_notify: None,
        status: Some(Arc::clone(&status)),
        trace: cfg.trace.clone(),
    };

    let listen_addr = listener.local_addr()?;
    let frontend = crate::transport::Frontend::start(
        kind,
        listener,
        layout.clone(),
        grad_txs.clone(),
        cells.clone(),
        reply_rxs,
        delayed_flags,
        Arc::clone(&stop),
        net.clone(),
        cfg.elastic,
        Some(status),
        cfg.trace.clone(),
    )?;
    // The reactor sleeps in poll(2); replies wake it immediately instead of
    // waiting out the tick. The threaded frontend's blocking pumps need no
    // hook and return None here.
    server_cfg.reply_notify = frontend.reply_notifier();
    log_info!(
        "trainer",
        "serving {} on {listen_addr}: {} shards, {} worker slots",
        cfg.policy,
        layout.shards(),
        cfg.workers
    );

    let mut metrics = RunMetrics {
        stream: cfg.stream.clone(),
        ..Default::default()
    };
    let mut fstats = crate::transport::tcp::FrontendStats::default();
    let result: anyhow::Result<()> = std::thread::scope(|s| {
        let _stop_guard = StopGuard(stop.as_ref());
        let mut shard_handles = Vec::with_capacity(layout.shards());
        for shard in 0..layout.shards() {
            let range = layout.range(shard);
            let init = inputs.init_params[range.clone()].to_vec();
            let cell = Arc::clone(&cells[shard]);
            let scfg = server_cfg.clone();
            let rtxs = reply_txs.clone();
            let grad_rx = grad_rxs[shard].take().unwrap();
            let stop_ref: &AtomicBool = &stop;
            shard_handles.push(s.spawn(move || {
                run_shard(shard, range, init, cell, &scfg, grad_rx, rtxs, stop_ref, clock)
            }));
        }
        drop(reply_txs); // shard threads own the only reply senders now
        drop(grad_txs); // the frontend owns the remaining gradient senders

        // --- evaluator (this thread) ---
        let mut eval_engine = (inputs.eval_engine)()?;
        let mut eval_loop = EvalLoop {
            engine: eval_engine.as_mut(),
            test: inputs.test,
            train_probe: inputs.train_probe,
            cells: &cells,
            layout: &layout,
            clock,
        };
        let mut params_buf = inputs.init_params.to_vec();
        eval_loop.sample(&mut metrics, &mut params_buf)?;
        let slice = Duration::from_millis(25).min(cfg.eval_interval);
        let mut since_eval = Duration::ZERO;
        // Completion: everyone joined has left — but only after the state
        // has been stable for a grace window, so a worker mid-reconnect
        // (active transiently 0) does not end the run under it. Under a
        // steps budget the run additionally waits for the full worker
        // complement to have attached, so a fast first worker finishing
        // its budget cannot end the run before slower processes arrive.
        let min_joined = if cfg.steps.is_some() { cfg.workers } else { 1 };
        let mut idle_polls = 0u32;
        while clock.now() < cfg.duration {
            if frontend.ever_joined() >= min_joined && frontend.active_conns() == 0 {
                idle_polls += 1;
                if idle_polls >= 20 {
                    break;
                }
            } else {
                idle_polls = 0;
            }
            let remaining = cfg.duration.saturating_sub(clock.now());
            clock.sleep(slice.min(remaining));
            since_eval += slice;
            if since_eval >= cfg.eval_interval {
                since_eval = Duration::ZERO;
                eval_loop.sample(&mut metrics, &mut params_buf)?;
            }
        }

        stop.store(true, Ordering::Relaxed);
        // Joins every connection thread, sends Shutdown to live workers and
        // releases the frontend's gradient senders — after this the shard
        // servers drain and exit exactly as when in-process workers finish.
        fstats = frontend.shutdown();
        let reports = shard_handles
            .into_iter()
            .map(|h| h.join().expect("shard-server thread panicked"))
            .collect::<Vec<_>>();
        merge_reports(&layout, reports).fill(&mut metrics);
        // Frame-granularity gradient-plane accounting (headers included);
        // sender and receiver sides agree by construction on loss-free TCP.
        metrics.bytes_sent = fstats.grad_frame_bytes;
        metrics.bytes_received = fstats.grad_frame_bytes;
        metrics.bytes_dense_equiv = fstats.submissions * dim * 4;
        eval_loop.sample(&mut metrics, &mut params_buf)?;
        Ok(())
    });
    result?;
    metrics.wall_time = clock.now().as_secs_f64();
    // Machine-level gauge, excluded from RunMetrics equality. Workers'
    // refresh bytes live in their own processes; `refresh_bytes` stays 0
    // here (each `join` process reports its own pull volume).
    metrics.peak_rss_bytes = super::metrics::peak_rss_bytes();
    if metrics.bytes_sent > 0 {
        let (t, v) = (metrics.wall_time, metrics.wire_compression());
        metrics.record(SeriesId::CompressionRatio, t, v);
    }
    if let Some(st) = &metrics.stream {
        st.flush();
    }
    log_info!(
        "trainer",
        "serve done: {} grads over TCP ({} submissions, {} B on the gradient plane), {} updates",
        metrics.gradients_total,
        fstats.submissions,
        fstats.grad_frame_bytes,
        metrics.updates_total
    );
    Ok(metrics)
}

/// Run one gradient worker against a remote parameter server: the
/// multi-process counterpart of a worker thread inside [`train`]. Dials
/// `connect` (with backoff), attaches, pulls the initial parameters over
/// the wire, then runs the standard worker loop until the server shuts the
/// run down, the `steps` budget is spent, or `deadline` elapses.
///
/// Seed derivations match the in-process trainer exactly (`seed + 1000 +
/// id` for the worker stream, `batch_source(id)` for data), so a TCP run
/// with the same geometry reproduces the in-process math.
#[allow(clippy::too_many_arguments)]
pub fn join_remote(
    connect: &str,
    net: &crate::transport::NetOptions,
    wire: WireFormat,
    delay: DelayModel,
    seed: u64,
    compute_floor: Duration,
    steps: Option<u64>,
    deadline: Duration,
    worker_engine: crate::engine::EngineFactory,
    batch_source: Arc<dyn Fn(usize) -> Box<dyn BatchSource> + Send + Sync>,
    expected_workers: Option<usize>,
    trace: Option<Arc<crate::util::trace::TraceRing>>,
) -> anyhow::Result<super::worker::WorkerReport> {
    use crate::transport::{TcpTransport, Transport, TransportError};
    let clock_owned = Arc::new(RealClock::start());
    let clock: &dyn Clock = clock_owned.as_ref();
    if let Some(tr) = &trace {
        tr.set_epoch(clock_owned.started_at());
    }
    let _log_clock = crate::util::logging::set_run_clock({
        let c = Arc::clone(&clock_owned);
        Arc::new(move || c.now())
    });
    let mut transport = TcpTransport::connect(connect, &wire.to_string(), net.clone())?;
    let info = transport.attach_info();
    if let Some(w) = expected_workers {
        anyhow::ensure!(
            info.workers == w,
            "server runs {} worker slots but --workers {w} was given \
             (data sharding would diverge from the in-process run)",
            info.workers
        );
    }
    let engine = worker_engine()?;
    anyhow::ensure!(
        engine.param_count() == info.dim,
        "local model has {} parameters but the server serves {}",
        engine.param_count(),
        info.dim
    );
    validate_net_geometry(info.dim, info.shards, &wire)?;
    let source = batch_source(info.worker);
    log_info!(
        "trainer",
        "joined {connect} as worker {}/{} (shards={}, dim={}, delayed={}, wire={wire})",
        info.worker,
        info.workers,
        info.shards,
        info.dim,
        info.delayed
    );
    // Initial parameters: a full refresh over the wire (the in-process
    // worker receives them by value from the trainer).
    let mut init = vec![0.0f32; info.dim];
    let layout = transport.layout().clone();
    for shard in 0..layout.shards() {
        let range = layout.range(shard);
        let mut attempts = 0;
        loop {
            match transport.refresh(shard, &mut init[range.clone()]) {
                Ok(_) => break,
                Err(TransportError::Closed(why)) => {
                    anyhow::bail!("initial parameter fetch failed: {why}")
                }
                Err(_) => {
                    attempts += 1;
                    anyhow::ensure!(
                        attempts < 5,
                        "could not fetch initial parameters for shard {shard}"
                    );
                }
            }
        }
    }
    let wcfg = WorkerConfig {
        id: info.worker,
        delayed: info.delayed,
        delay,
        seed: seed.wrapping_add(1000 + info.worker as u64),
        min_iter: compute_floor,
        wire,
        max_grads: steps,
        trace,
    };
    // Deadline watchdog: the worker loop only checks a stop flag.
    let stop = Arc::new(AtomicBool::new(false));
    let watchdog = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let start = std::time::Instant::now();
            while !stop.load(Ordering::Relaxed) {
                if start.elapsed() >= deadline {
                    stop.store(true, Ordering::Relaxed);
                    break;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };
    let report = run_worker(&wcfg, engine, source, init, &mut transport, &stop, clock);
    stop.store(true, Ordering::Relaxed);
    let _ = watchdog.join();
    log_info!(
        "trainer",
        "worker {} done: {} grads, {} refreshes ({} B pulled), {} B sent (frame granularity)",
        info.worker,
        report.grads_sent,
        report.refreshes,
        report.refresh_bytes,
        report.bytes_sent
    );
    Ok(report)
}

/// The evaluator: assembles a parameter view from the per-shard snapshot
/// cells (pointer reads + one memcpy per shard) and computes metrics over
/// the eval sets in engine-batch chunks.
///
/// Consistency: each shard slice is internally consistent, but the view
/// across shards is relaxed — cells are loaded one after another, so under
/// concurrent updates the assembled θ can mix adjacent versions (the
/// pre-shard evaluator read one throttled snapshot, which was equally stale
/// just uniformly so). This is telemetry-grade sampling, not a training
/// input; `assemble_params` returns the minimum version for callers that
/// want to detect the spread.
struct EvalLoop<'a> {
    engine: &'a mut dyn crate::engine::GradEngine,
    test: &'a EvalSet,
    train_probe: &'a EvalSet,
    cells: &'a [Arc<super::params::SnapshotCell>],
    layout: &'a ShardLayout,
    clock: &'a dyn Clock,
}

impl<'a> EvalLoop<'a> {
    fn sample(&mut self, m: &mut RunMetrics, params_buf: &mut [f32]) -> anyhow::Result<()> {
        let _version = assemble_params(self.cells, self.layout, params_buf);
        let t = self.clock.now().as_secs_f64();
        let (test_loss, test_acc) = eval_on(self.engine, params_buf, self.test)?;
        let (train_loss, _) = eval_on(self.engine, params_buf, self.train_probe)?;
        m.record(SeriesId::TestLoss, t, test_loss);
        m.record(SeriesId::TestAcc, t, test_acc * 100.0);
        m.record(SeriesId::TrainLoss, t, train_loss);
        Ok(())
    }
}

/// Evaluate `params` over an [`EvalSet`] in engine-batch chunks; returns
/// (mean loss per label item, accuracy fraction). Samples beyond the last
/// full chunk are dropped (the sets are sized as multiples in practice).
pub fn eval_on(
    engine: &mut dyn crate::engine::GradEngine,
    params: &[f32],
    set: &EvalSet,
) -> anyhow::Result<(f64, f64)> {
    let chunk = engine.eval_batch_size();
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    let mut items = 0usize;
    let n_chunks = set.n / chunk;
    anyhow::ensure!(n_chunks > 0, "eval set smaller than eval batch");
    for c in 0..n_chunks {
        let xs = &set.x[c * chunk * set.x_dim..(c + 1) * chunk * set.x_dim];
        let ys = &set.y[c * chunk * set.y_dim..(c + 1) * chunk * set.y_dim];
        let (l, corr) = engine.eval(params, xs, ys)?;
        loss_sum += l;
        correct += corr;
        items += chunk * set.y_dim;
    }
    Ok((loss_sum / items as f64, correct as f64 / items as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::threshold::Schedule;
    use crate::data::random_cluster::{generate, ClusterSpec};
    use crate::engine::factory;
    use crate::native::MlpEngine;

    fn mlp_inputs<'a>(
        train: Arc<Dataset>,
        test: &'a EvalSet,
        probe: &'a EvalSet,
        init: &'a [f32],
        dims: Vec<usize>,
        batch: usize,
        workers: usize,
    ) -> RunInputs<'a> {
        // Note: lifetimes tie to test/probe/init.
        let dims_w = dims.clone();
        let shards = train.shard_indices(workers);
        RunInputs {
            worker_engine: factory(move || Ok(Box::new(MlpEngine::new(dims_w.clone(), batch)))),
            eval_engine: {
                let dims_e = dims.clone();
                factory(move || Ok(Box::new(MlpEngine::new(dims_e.clone(), 50))))
            },
            batch_source: Arc::new(move |id| {
                Box::new(crate::data::Batcher::new(
                    Arc::clone(&train),
                    shards[id].clone(),
                    batch,
                    Pcg64::new(42, id as u64),
                )) as Box<dyn BatchSource>
            }),
            init_params: init,
            test,
            train_probe: probe,
        }
    }

    fn short_run(policy: Policy) -> RunMetrics {
        short_run_sharded(policy, 1)
    }

    fn short_run_sharded(policy: Policy, shards: usize) -> RunMetrics {
        let spec = ClusterSpec {
            n_samples: 600,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(11);
        let full = generate(&spec, &mut rng);
        let (train, test) = full.split(0.8, &mut rng);
        let dims = vec![20, 32, 10];
        let init = MlpEngine::init_params(&dims, &mut rng);
        let test_set = EvalSet::from_dataset(&test, 100, &mut rng);
        let probe = EvalSet::from_dataset(&train, 100, &mut rng);
        let train = Arc::new(train);
        let inputs = mlp_inputs(train, &test_set, &probe, &init, dims, 16, 3);
        let mut cfg = TrainConfig::quick(policy, 3, 1.0);
        cfg.delay = DelayModel::none();
        cfg.lr = 0.05;
        cfg.shards = shards;
        train_run(&cfg, &inputs)
    }

    fn train_run(cfg: &TrainConfig, inputs: &RunInputs) -> RunMetrics {
        crate::util::logging::set_level(crate::util::logging::Level::Off);
        train(cfg, inputs).expect("train failed")
    }

    #[test]
    fn async_run_learns_and_reports() {
        let m = short_run(Policy::Async);
        assert!(m.gradients_total > 20, "too few gradients: {}", m.gradients_total);
        assert_eq!(m.updates_total, m.gradients_total);
        assert_eq!(m.shards, 1);
        let first_acc = m.test_acc.v[0];
        let last_acc = *m.test_acc.v.last().unwrap();
        assert!(
            last_acc > first_acc + 10.0,
            "accuracy did not improve: {first_acc} → {last_acc}"
        );
    }

    #[test]
    fn sync_run_applies_barrier_updates() {
        let m = short_run(Policy::Sync);
        assert!(m.flushes > 0);
        assert!(m.updates_total <= m.gradients_total / 2);
    }

    #[test]
    fn hybrid_run_flushes_and_learns() {
        let m = short_run(Policy::Hybrid {
            schedule: Schedule::Step { step: 50 },
            strict: false,
        });
        assert!(m.flushes > 0);
        assert!(m.gradients_total > 20);
        let last_acc = *m.test_acc.v.last().unwrap();
        assert!(last_acc > 20.0, "acc {last_acc}");
        // K trajectory must be monotone non-decreasing
        for w in m.k_trajectory.v.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }

    #[test]
    fn compressed_threaded_run_cuts_wire_bytes() {
        let spec = ClusterSpec {
            n_samples: 600,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(11);
        let full = generate(&spec, &mut rng);
        let (train, test) = full.split(0.8, &mut rng);
        let dims = vec![20, 32, 10];
        let init = MlpEngine::init_params(&dims, &mut rng);
        let test_set = EvalSet::from_dataset(&test, 100, &mut rng);
        let probe = EvalSet::from_dataset(&train, 100, &mut rng);
        let train = Arc::new(train);
        let inputs = mlp_inputs(train, &test_set, &probe, &init, dims, 16, 3);
        let mut cfg = TrainConfig::quick(Policy::Async, 3, 1.0);
        cfg.delay = DelayModel::none();
        cfg.lr = 0.05;
        cfg.wire = WireFormat::parse("topk:0.1").unwrap();
        let m = train_run(&cfg, &inputs);
        assert!(m.gradients_total > 20, "too few gradients: {}", m.gradients_total);
        assert!(m.bytes_sent > 0);
        assert!(m.bytes_received > 0);
        // 10% density at 8 B/coordinate ≈ 5× fewer bytes than dense f32.
        assert!(
            m.bytes_sent * 4 < m.bytes_dense_equiv,
            "topk:0.1 should cut bytes ≥4×: {} vs {}",
            m.bytes_sent,
            m.bytes_dense_equiv
        );
        assert!(m.wire_compression() > 4.0);
        assert!(!m.compression_ratio.is_empty());
    }

    #[test]
    fn sharded_runs_complete_and_learn() {
        for shards in [2usize, 4] {
            let m = short_run_sharded(Policy::Async, shards);
            assert_eq!(m.shards, shards, "effective shard count");
            assert_eq!(m.per_shard_updates.len(), shards);
            assert!(m.gradients_total > 20, "S={shards}: too few gradients");
            let last_acc = *m.test_acc.v.last().unwrap();
            assert!(last_acc > 25.0, "S={shards}: final acc {last_acc}");
        }
    }

    #[test]
    fn sharded_hybrid_flushes_on_every_shard() {
        let m = short_run_sharded(
            Policy::Hybrid {
                schedule: Schedule::Step { step: 40 },
                strict: false,
            },
            3,
        );
        assert!(m.flushes > 0);
        // All shards see (nearly) the same arrival stream; their update
        // counts can differ only by messages in flight at shutdown.
        let max = *m.per_shard_updates.iter().max().unwrap();
        let min = *m.per_shard_updates.iter().min().unwrap();
        // At most one in-flight message per worker per shard at shutdown.
        assert!(max - min <= 3, "shard updates diverged: {:?}", m.per_shard_updates);
    }

    #[test]
    fn robust_aggregate_needs_a_buffering_policy() {
        let mut cfg = TrainConfig::quick(Policy::Async, 2, 0.1);
        cfg.aggregate = AggregateMode::Median;
        assert!(validate_config(&cfg).is_err());
        cfg.aggregate = AggregateMode::Trimmed(0.25);
        assert!(validate_config(&cfg).is_err());
        // Clipping is per-contribution, so it composes with async fine.
        cfg.aggregate = AggregateMode::Clip(1.0);
        assert!(validate_config(&cfg).is_ok());
        cfg.policy = Policy::Sync;
        cfg.aggregate = AggregateMode::Median;
        assert!(validate_config(&cfg).is_ok());
    }

    #[test]
    fn trimmed_run_trains_end_to_end() {
        let spec = ClusterSpec {
            n_samples: 600,
            ..Default::default()
        };
        let mut rng = Pcg64::seeded(11);
        let full = generate(&spec, &mut rng);
        let (train, test) = full.split(0.8, &mut rng);
        let dims = vec![20, 32, 10];
        let init = MlpEngine::init_params(&dims, &mut rng);
        let test_set = EvalSet::from_dataset(&test, 100, &mut rng);
        let probe = EvalSet::from_dataset(&train, 100, &mut rng);
        let train = Arc::new(train);
        let inputs = mlp_inputs(train, &test_set, &probe, &init, dims, 16, 4);
        let mut cfg = TrainConfig::quick(Policy::Sync, 4, 1.0);
        cfg.delay = DelayModel::none();
        cfg.lr = 0.05;
        cfg.aggregate = AggregateMode::Trimmed(0.25);
        let m = train_run(&cfg, &inputs);
        assert!(m.flushes > 0, "no barrier rounds completed");
        assert!(m.final_params.iter().all(|v| v.is_finite()));
        let last_acc = *m.test_acc.v.last().unwrap();
        assert!(last_acc > 20.0, "trimmed-mean run did not learn: acc {last_acc}");
    }

    #[test]
    fn net_geometry_guard_catches_oversized_gradient_frames() {
        use crate::coordinator::compress::WireFormat;
        // 1e8 dense f32 coordinates on one shard: ~400 MB per gradient
        // frame, far past the 64 MiB limit. The error must name the limit
        // and the --shards workaround.
        let dim = 100_000_000;
        let err = validate_net_geometry(dim, 1, &WireFormat::Dense).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--shards"), "no workaround in: {msg}");
        assert!(
            msg.contains(&crate::transport::frame::MAX_PAYLOAD.to_string()),
            "limit not named in: {msg}"
        );
        // Enough shards (or a 1-byte/coordinate format with a few) fits.
        assert!(validate_net_geometry(dim, 8, &WireFormat::Dense).is_ok());
        assert!(validate_net_geometry(dim, 2, &WireFormat::Int8).is_ok());
        // Sparse formats are bounded by k, not dim.
        let topk = WireFormat::parse("topk:100000").unwrap();
        assert!(validate_net_geometry(dim, 1, &topk).is_ok());
        // ...unless k itself blows the frame; splitting shards still fixes
        // it because the per-shard worst case shrinks with the shard.
        let huge_k = WireFormat::parse("topk:20000000").unwrap();
        assert!(validate_net_geometry(dim, 1, &huge_k).is_err());
        assert!(validate_net_geometry(dim, 16, &huge_k).is_ok());
        // Small models are untouched on every format.
        for w in ["dense", "int8", "topk:0.01", "topk+int8:0.01"] {
            let w = WireFormat::parse(w).unwrap();
            assert!(validate_net_geometry(52_138, 1, &w).is_ok());
        }
    }

    #[test]
    fn eval_on_counts_chunks() {
        let dims = vec![4, 3];
        let mut eng = MlpEngine::new(dims.clone(), 5);
        let params = vec![0.0f32; MlpEngine::n_params(&dims)];
        let set = EvalSet {
            x: vec![0.1; 10 * 4],
            y: vec![0; 10],
            n: 10,
            x_dim: 4,
            y_dim: 1,
        };
        let (loss, acc) = eval_on(&mut eng, &params, &set).unwrap();
        // zero params → uniform logits → loss = ln(3)
        assert!((loss - (3.0f64).ln()).abs() < 1e-5);
        assert!((0.0..=1.0).contains(&acc));
    }
}
