//! The gradient-worker loop and batch sources.
//!
//! A worker owns: a local parameter copy, a [`GradEngine`] (constructed
//! inside the thread — PJRT clients are not `Send`), a [`BatchSource`], and
//! its half of the channel protocol. Per iteration it computes a gradient,
//! optionally sleeps an injected delay (the paper's heterogeneity model),
//! submits, and waits for the server's reply.

use super::delay::DelayModel;
use super::server::{GradMsg, Reply};
use crate::data::tokens::TokenBatcher;
use crate::data::Batcher;
use crate::engine::GradEngine;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Produces mini-batches as (features, labels) slices. Implementations must
/// reuse internal buffers (the worker loop is allocation-free).
pub trait BatchSource: Send {
    fn next(&mut self) -> (&[f32], &[i32]);
}

impl BatchSource for Batcher {
    fn next(&mut self) -> (&[f32], &[i32]) {
        self.next_batch()
    }
}

/// Adapter: token windows → f32 features (token ids are exactly
/// representable in f32 for any realistic vocab; the L2 model casts back to
/// int32 before the embedding lookup).
pub struct TokenBatchSource {
    inner: TokenBatcher,
    x_buf: Vec<f32>,
}

impl TokenBatchSource {
    pub fn new(inner: TokenBatcher, batch: usize, seq_len: usize) -> Self {
        TokenBatchSource {
            inner,
            x_buf: vec![0.0; batch * seq_len],
        }
    }
}

impl BatchSource for TokenBatchSource {
    fn next(&mut self) -> (&[f32], &[i32]) {
        let (inp, tgt) = self.inner.next_batch();
        for (o, &t) in self.x_buf.iter_mut().zip(inp) {
            *o = t as f32;
        }
        (&self.x_buf, tgt)
    }
}

/// Per-worker configuration.
pub struct WorkerConfig {
    pub id: usize,
    /// Whether this worker is in the delayed 50% (paper §6).
    pub delayed: bool,
    pub delay: DelayModel,
    pub seed: u64,
    /// Minimum wall time per gradient iteration. Simulates the paper's
    /// per-gradient compute cost (ray + PyTorch on their cluster) for models
    /// whose AOT executables run much faster here; zero = no floor.
    /// See DESIGN.md §1 (substitutions).
    pub min_iter: Duration,
}

/// Worker-side counters returned at join.
#[derive(Debug, Default, Clone)]
pub struct WorkerReport {
    pub grads_sent: u64,
    pub fresh_replies: u64,
    pub unchanged_replies: u64,
    pub delay_slept: f64,
}

/// Run one worker until `stop` is set. Call on a dedicated thread.
pub fn run_worker(
    cfg: &WorkerConfig,
    mut engine: Box<dyn GradEngine>,
    mut source: Box<dyn BatchSource>,
    init_params: Vec<f32>,
    grad_tx: Sender<GradMsg>,
    reply_rx: Receiver<Reply>,
    stop: &AtomicBool,
) -> WorkerReport {
    let mut report = WorkerReport::default();
    let mut params = init_params;
    let mut version: u64 = 0;
    let dim = params.len();
    let mut grad_buf = vec![0.0f32; dim];
    let mut spare = vec![0.0f32; dim];
    let mut rng = Pcg64::new(cfg.seed, cfg.id as u64 + 1);

    while !stop.load(Ordering::Relaxed) {
        let iter_start = std::time::Instant::now();
        let (x, y) = source.next();
        let loss = match engine.grad(&params, x, y, &mut grad_buf) {
            Ok(l) => l,
            Err(e) => {
                crate::log_warn!("worker", "worker {} grad failed: {e:#}", cfg.id);
                break;
            }
        };
        if cfg.delayed {
            let d = cfg.delay.sample(&mut rng);
            if !d.is_zero() {
                report.delay_slept += d.as_secs_f64();
                // Sleep in small slices so shutdown stays responsive even
                // with multi-second injected delays.
                let deadline = std::time::Instant::now() + d;
                while std::time::Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
                    std::thread::sleep(Duration::from_millis(5).min(d));
                }
            }
        }
        // Enforce the compute-cost floor (paper-regime pacing).
        if !cfg.min_iter.is_zero() {
            let elapsed = iter_start.elapsed();
            if elapsed < cfg.min_iter && !stop.load(Ordering::Relaxed) {
                std::thread::sleep(cfg.min_iter - elapsed);
            }
        }
        // Ship the gradient; swap in the spare so we keep an owned buffer.
        let outgoing = std::mem::replace(&mut grad_buf, std::mem::take(&mut spare));
        if grad_tx
            .send(GradMsg {
                worker: cfg.id,
                base_version: version,
                loss,
                grad: outgoing,
            })
            .is_err()
        {
            break; // server gone
        }
        report.grads_sent += 1;

        // Await the reply (with stop checks: barrier waits can span seconds).
        loop {
            match reply_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(Reply::Fresh {
                    theta,
                    version: v,
                    recycled,
                }) => {
                    params.copy_from_slice(&theta);
                    version = v;
                    spare = recycled;
                    report.fresh_replies += 1;
                    break;
                }
                Ok(Reply::Unchanged { recycled }) => {
                    spare = recycled;
                    report.unchanged_replies += 1;
                    break;
                }
                Err(RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::Relaxed) {
                        return report;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return report,
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::QuadraticEngine;
    use std::sync::mpsc;
    use std::sync::Arc;

    struct ConstSource {
        x: Vec<f32>,
        y: Vec<i32>,
    }

    impl BatchSource for ConstSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&self.x, &self.y)
        }
    }

    #[test]
    fn worker_submits_and_applies_replies() {
        let (gtx, grx) = mpsc::channel::<GradMsg>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 1,
            min_iter: Duration::ZERO,
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            run_worker(&cfg, engine, source, vec![0.0, 0.0], gtx, rrx, &stop2)
        });
        // Act as the server for 3 round trips.
        for i in 0..3u64 {
            let msg = grx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(msg.worker, 0);
            assert_eq!(msg.base_version, i);
            rtx.send(Reply::Fresh {
                theta: vec![0.5, 0.5],
                version: i + 1,
                recycled: msg.grad,
            })
            .unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        // Consume anything in flight, then drop our ends.
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.grads_sent >= 3);
        assert!(report.fresh_replies >= 3);
    }

    #[test]
    fn token_source_converts_to_f32() {
        use crate::data::tokens::{generate, CorpusSpec, TokenBatcher};
        let spec = CorpusSpec {
            length: 2000,
            seq_len: 8,
            ..Default::default()
        };
        let d = Arc::new(generate(&spec, &mut Pcg64::seeded(1)));
        let shard: Vec<usize> = (0..d.num_windows()).collect();
        let tb = TokenBatcher::new(Arc::clone(&d), shard, 2, Pcg64::seeded(2));
        let mut src = TokenBatchSource::new(tb, 2, 8);
        let (x, y) = src.next();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        for &v in x {
            assert_eq!(v, v.round());
            assert!((0.0..64.0).contains(&v));
        }
    }
}
