//! The gradient-worker loop and batch sources.
//!
//! A worker owns: a local parameter copy, a [`GradEngine`] (constructed
//! inside the thread — PJRT clients are not `Send`), a [`BatchSource`], and
//! a [`Transport`] to the sharded parameter server. Per iteration it
//! computes a gradient, optionally sleeps an injected delay (the paper's
//! heterogeneity model), encodes it in the configured [`WireFormat`] (dense
//! submissions fan out as `Arc` clones of one buffer; compressed ones go
//! through the worker's [`GradEncoder`], whose buffers recycle round-trip),
//! waits for all `S` shard replies, and refreshes only the shard slices
//! whose parameters actually changed. With the default
//! [`crate::transport::InProcTransport`] this is exactly the channel +
//! snapshot-cell protocol it always was (bitwise-identical); with a
//! [`crate::transport::TcpTransport`] the same loop trains against a
//! parameter server in another process.

use super::clock::Clock;
use super::compress::{submission_bytes, GradEncoder, ShardGrad, WireFormat};
use super::delay::DelayModel;
use super::params::SnapshotCell;
use super::server::{Reply, ShardEvent, ShardMsg};
use super::shard::ShardLayout;
use crate::data::tokens::TokenBatcher;
use crate::data::Batcher;
use crate::engine::GradEngine;
use crate::transport::{Transport, TransportError};
use crate::util::rng::Pcg64;
use crate::util::trace::{Stage, TraceRing};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Duration;

/// Produces mini-batches as (features, labels) slices. Implementations must
/// reuse internal buffers (the worker loop is allocation-free).
pub trait BatchSource: Send {
    fn next(&mut self) -> (&[f32], &[i32]);
}

impl BatchSource for Batcher {
    fn next(&mut self) -> (&[f32], &[i32]) {
        self.next_batch()
    }
}

/// Adapter: token windows → f32 features (token ids are exactly
/// representable in f32 for any realistic vocab; the L2 model casts back to
/// int32 before the embedding lookup).
pub struct TokenBatchSource {
    inner: TokenBatcher,
    x_buf: Vec<f32>,
}

impl TokenBatchSource {
    pub fn new(inner: TokenBatcher, batch: usize, seq_len: usize) -> Self {
        TokenBatchSource {
            inner,
            x_buf: vec![0.0; batch * seq_len],
        }
    }
}

impl BatchSource for TokenBatchSource {
    fn next(&mut self) -> (&[f32], &[i32]) {
        let (inp, tgt) = self.inner.next_batch();
        for (o, &t) in self.x_buf.iter_mut().zip(inp) {
            *o = t as f32;
        }
        (&self.x_buf, tgt)
    }
}

/// Per-worker configuration.
pub struct WorkerConfig {
    pub id: usize,
    /// Whether this worker is in the delayed 50% (paper §6).
    pub delayed: bool,
    pub delay: DelayModel,
    pub seed: u64,
    /// Minimum wall time per gradient iteration. Simulates the paper's
    /// per-gradient compute cost (ray + PyTorch on their cluster) for models
    /// whose AOT executables run much faster here; zero = no floor.
    /// See DESIGN.md §1 (substitutions).
    pub min_iter: Duration,
    /// How this worker encodes gradients on the wire.
    pub wire: WireFormat,
    /// Stop after this many gradient submissions (the `--steps` budget;
    /// `None` = run until the stop flag). Deterministic runs use a step
    /// budget instead of a wall-clock one.
    pub max_grads: Option<u64>,
    /// Gradient-lifecycle flight recorder: when set, the loop records
    /// compute/encode/wire spans (stamped through the injected `Clock`)
    /// and stamps each submission's channel-enqueue time. `None` — the
    /// default — keeps the hot path free of clock reads.
    pub trace: Option<Arc<TraceRing>>,
}

/// The worker's view of the sharded parameter server.
pub struct ShardEndpoints {
    pub layout: ShardLayout,
    /// One gradient channel per shard, in shard order (the worker only
    /// ever sends `ShardEvent::Grad`; membership events are server-side).
    pub grad_txs: Vec<Sender<ShardEvent>>,
    /// One snapshot cell per shard, in shard order.
    pub cells: Vec<Arc<SnapshotCell>>,
}

/// Worker-side counters returned at join.
#[derive(Debug, Default, Clone)]
pub struct WorkerReport {
    pub grads_sent: u64,
    /// Shard-slice refreshes actually copied from snapshot cells.
    pub refreshes: u64,
    /// Shard replies that required no parameter copy.
    pub unchanged_replies: u64,
    pub delay_slept: f64,
    /// Bytes-on-wire this worker's submissions carried (summed over the
    /// per-shard payloads of every submission).
    pub bytes_sent: u64,
    /// Bytes of parameter state pulled via refresh: logical (4 B × slice
    /// length per refresh) in process, actual snapshot-response payload
    /// bytes over TCP (where deltas ship only dirty blocks).
    pub refresh_bytes: u64,
}

/// Run one worker until `stop` is set (or its `max_grads` budget is
/// spent). Call on a dedicated thread. All timing (iteration pacing,
/// injected delays) goes through `clock`, never through
/// `Instant`/`thread::sleep` directly. The `transport` carries submissions
/// and replies — in-process channels by default, TCP frames across
/// processes — without changing the loop's protocol.
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    cfg: &WorkerConfig,
    mut engine: Box<dyn GradEngine>,
    mut source: Box<dyn BatchSource>,
    init_params: Vec<f32>,
    transport: &mut dyn Transport,
    stop: &AtomicBool,
    clock: &dyn Clock,
) -> WorkerReport {
    let mut report = WorkerReport::default();
    let mut params = init_params;
    let dim = params.len();
    let layout = transport.layout().clone();
    let shards = layout.shards();
    debug_assert_eq!(layout.dim(), dim);
    // Per-shard version of the local parameter copy.
    let mut versions = vec![0u64; shards];
    // Which shards to refresh after the current round of replies.
    let mut needs_refresh = vec![false; shards];
    let mut grad_buf = vec![0.0f32; dim];
    let mut spare = vec![0.0f32; dim];
    let mut rng = Pcg64::new(cfg.seed, cfg.id as u64 + 1);
    // Dense submissions keep the zero-copy Arc-swap fast path; compressed
    // formats go through the worker's encoder (recycled buffers).
    let mut encoder = if cfg.wire.is_dense() {
        None
    } else {
        Some(GradEncoder::new(cfg.wire.clone(), dim, shards))
    };
    let mut payloads: Vec<ShardGrad> = Vec::with_capacity(shards);

    'outer: while !stop.load(Ordering::Relaxed)
        && cfg.max_grads.map_or(true, |n| report.grads_sent < n)
    {
        let iter_start = clock.now();
        let (x, y) = source.next();
        let loss = match engine.grad(&params, x, y, &mut grad_buf) {
            Ok(l) => l,
            Err(e) => {
                crate::log_warn!("worker", "worker {} grad failed: {e:#}", cfg.id);
                break;
            }
        };
        if cfg.delayed {
            let d = cfg.delay.sample_for(cfg.id, &mut rng);
            if !d.is_zero() {
                report.delay_slept += d.as_secs_f64();
                // Sleep in small slices so shutdown stays responsive even
                // with multi-second injected delays.
                let deadline = clock.now() + d;
                while clock.now() < deadline && !stop.load(Ordering::Relaxed) {
                    clock.sleep(Duration::from_millis(5).min(d));
                }
            }
        }
        // Enforce the compute-cost floor (paper-regime pacing).
        if !cfg.min_iter.is_zero() {
            let elapsed = clock.now().saturating_sub(iter_start);
            if elapsed < cfg.min_iter && !stop.load(Ordering::Relaxed) {
                clock.sleep(cfg.min_iter - elapsed);
            }
        }
        // Compute span covers grad + injected delay + pacing floor — the
        // paper's heterogeneity lives in this stage by design.
        let seq = report.grads_sent;
        let t_compute_end = cfg
            .trace
            .as_ref()
            .map_or(0, |_| clock.now().as_nanos() as u64);
        if let Some(tr) = &cfg.trace {
            tr.span(
                Stage::Compute,
                cfg.id as u32,
                0,
                iter_start.as_nanos() as u64,
                t_compute_end,
                seq,
                0,
            );
        }
        // Encode and fan the gradient out to every shard. Dense: Arc clones
        // of one buffer, the spare swaps in so the worker always owns a
        // compute buffer. Compressed: the encoder splits per shard into its
        // recycled payload buffers.
        let bytes_before = report.bytes_sent;
        let shared = match encoder.as_mut() {
            None => {
                let arc =
                    Arc::new(std::mem::replace(&mut grad_buf, std::mem::take(&mut spare)));
                report.bytes_sent += (dim * 4) as u64;
                Some(arc)
            }
            Some(enc) => {
                enc.encode(&grad_buf, &layout, &mut payloads);
                report.bytes_sent += submission_bytes(&payloads, &layout);
                None
            }
        };
        let t_encode_end = cfg
            .trace
            .as_ref()
            .map_or(0, |_| clock.now().as_nanos() as u64);
        if let Some(tr) = &cfg.trace {
            tr.span(
                Stage::Encode,
                cfg.id as u32,
                0,
                t_compute_end,
                t_encode_end,
                seq,
                report.bytes_sent - bytes_before,
            );
        }
        let mut round_lost = false;
        for s in 0..shards {
            let grad = match &shared {
                Some(arc) => ShardGrad::Dense(Arc::clone(arc)),
                None => payloads[s].clone(),
            };
            // Stamp the channel-enqueue instant so the shard thread can
            // record the queue span (0 = unstamped, tracing off). Over
            // TCP the stamp is dropped at encode; the serving frontend
            // re-stamps arrival on its own (epoch-shared) timebase.
            let enq_ns = cfg
                .trace
                .as_ref()
                .map_or(0, |_| clock.now().as_nanos() as u64);
            match transport.submit(
                s,
                ShardMsg {
                    worker: cfg.id,
                    base_version: versions[s],
                    loss,
                    grad,
                    enq_ns,
                },
            ) {
                Ok(()) => {}
                Err(TransportError::Reconnected) => {
                    // The connection (and any shard copies of this round
                    // already sent) is gone; resync and try a fresh round.
                    round_lost = true;
                    break;
                }
                Err(_) => break 'outer, // server gone
            }
        }
        report.grads_sent += 1;

        // Await one reply per shard (with stop checks: barrier waits can
        // span seconds). A transport reconnect abandons the round: the
        // in-flight replies died with the old connection.
        let mut pending = if round_lost { 0 } else { shards };
        while pending > 0 {
            match transport.recv_reply(Duration::from_millis(50)) {
                Ok(Reply::Updated { shard, version }) => {
                    if version != versions[shard] {
                        needs_refresh[shard] = true;
                    }
                    pending -= 1;
                }
                Ok(Reply::Unchanged { .. }) => {
                    report.unchanged_replies += 1;
                    pending -= 1;
                }
                Err(TransportError::Timeout) => {
                    if stop.load(Ordering::Relaxed) {
                        break 'outer;
                    }
                }
                Err(TransportError::Reconnected) => {
                    round_lost = true;
                    break;
                }
                Err(TransportError::Closed(_)) => break 'outer,
            }
        }
        // Wire span: submit fan-out until the last shard reply landed.
        if let Some(tr) = &cfg.trace {
            tr.span(
                Stage::Wire,
                cfg.id as u32,
                0,
                t_encode_end,
                clock.now().as_nanos() as u64,
                seq,
                shards as u64,
            );
        }
        // Every shard dropped its clone before replying: recycle the dense
        // buffer (the fallback allocation only triggers on shutdown races).
        // Compressed payload buffers recycle inside the encoder on its next
        // `encode` by the same mechanism.
        if let Some(arc) = shared {
            spare = Arc::try_unwrap(arc).unwrap_or_else(|_| vec![0.0f32; dim]);
        }
        if round_lost {
            // After a reconnect every local slice is suspect: refresh all.
            for f in needs_refresh.iter_mut() {
                *f = true;
            }
        }
        // Refresh changed shard slices — a snapshot-cell pointer read +
        // memcpy in process, a SnapshotRequest/SnapshotSlice round trip
        // over TCP — one copy per *changed* shard either way.
        for (s, flag) in needs_refresh.iter_mut().enumerate() {
            if *flag {
                match transport.refresh(s, &mut params[layout.range(s)]) {
                    Ok(version) => {
                        versions[s] = version;
                        report.refreshes += 1;
                        report.refresh_bytes += (layout.range(s).len() * 4) as u64;
                        *flag = false;
                    }
                    Err(TransportError::Closed(_)) => break 'outer,
                    // Transient (timeout / mid-refresh reconnect): keep the
                    // flag; the next round retries. Stale local slices are
                    // exactly the staleness an asynchronous PS tolerates.
                    Err(_) => {}
                }
            }
        }
    }
    // Frame-granularity accounting when the transport measures it (TCP);
    // the in-process path keeps the logical payload byte counts above.
    if let Some((sent, _received)) = transport.wire_counters() {
        report.bytes_sent = sent;
    }
    if let Some(bytes) = transport.refresh_wire_bytes() {
        report.refresh_bytes = bytes;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::QuadraticEngine;
    use std::sync::mpsc;
    use std::sync::Arc;

    struct ConstSource {
        x: Vec<f32>,
        y: Vec<i32>,
    }

    fn expect_grad(ev: ShardEvent) -> ShardMsg {
        match ev {
            ShardEvent::Grad(m) => m,
            _ => panic!("expected a gradient event"),
        }
    }

    impl BatchSource for ConstSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&self.x, &self.y)
        }
    }

    #[test]
    fn worker_submits_and_refreshes_from_snapshots() {
        let (gtx, grx) = mpsc::channel::<ShardEvent>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 1,
            min_iter: Duration::ZERO,
            wire: WireFormat::Dense,
            max_grads: None,
            trace: None,
        };
        let layout = ShardLayout::new(2, 1);
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout,
            grad_txs: vec![gtx],
            cells: vec![Arc::clone(&cell)],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            let mut transport = crate::transport::InProcTransport::new(endpoints, rrx);
            run_worker(&cfg, engine, source, vec![0.0, 0.0], &mut transport, &stop2, &clock)
        });
        // Act as the shard server for 3 round trips, publishing snapshots.
        for i in 0..3u64 {
            let msg = expect_grad(grx.recv_timeout(Duration::from_secs(2)).unwrap());
            assert_eq!(msg.worker, 0);
            assert_eq!(msg.base_version, i);
            drop(msg); // release the shared buffer like a real shard
            publish(&cell, vec![0.5, 0.5], i + 1);
            rtx.send(Reply::Updated {
                shard: 0,
                version: i + 1,
            })
            .unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        // Consume anything in flight, then drop our ends.
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.grads_sent >= 3);
        assert!(report.refreshes >= 3);
    }

    #[test]
    fn unchanged_replies_skip_refresh() {
        let (gtx, grx) = mpsc::channel::<ShardEvent>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 2,
            min_iter: Duration::ZERO,
            wire: WireFormat::Dense,
            max_grads: None,
            trace: None,
        };
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout: ShardLayout::new(2, 1),
            grad_txs: vec![gtx],
            cells: vec![cell],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            let mut transport = crate::transport::InProcTransport::new(endpoints, rrx);
            run_worker(&cfg, engine, source, vec![0.0, 0.0], &mut transport, &stop2, &clock)
        });
        for _ in 0..2 {
            let msg = expect_grad(grx.recv_timeout(Duration::from_secs(2)).unwrap());
            assert_eq!(msg.base_version, 0, "worker must keep version 0");
            drop(msg);
            rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.unchanged_replies >= 2);
        assert_eq!(report.refreshes, 0);
    }

    fn publish(cell: &Arc<SnapshotCell>, theta: Vec<f32>, version: u64) {
        cell.publish_raw(theta, version);
    }

    #[test]
    fn traced_worker_records_compute_encode_wire_and_stamps_enqueue() {
        use crate::util::trace::{Stage, TraceRing};
        let (gtx, grx) = mpsc::channel::<ShardEvent>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let ring = Arc::new(TraceRing::new(256));
        let cfg = WorkerConfig {
            id: 3,
            delayed: false,
            delay: DelayModel::none(),
            seed: 9,
            min_iter: Duration::ZERO,
            wire: WireFormat::Dense,
            max_grads: Some(2),
            trace: Some(Arc::clone(&ring)),
        };
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout: ShardLayout::new(2, 1),
            grad_txs: vec![gtx],
            cells: vec![cell],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            let mut transport = crate::transport::InProcTransport::new(endpoints, rrx);
            run_worker(&cfg, engine, source, vec![0.0, 0.0], &mut transport, &stop2, &clock)
        });
        for _ in 0..2 {
            let msg = expect_grad(grx.recv_timeout(Duration::from_secs(2)).unwrap());
            assert!(msg.enq_ns > 0, "traced submissions carry an enqueue stamp");
            drop(msg);
            rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        }
        drop(rtx);
        let report = h.join().unwrap();
        assert_eq!(report.grads_sent, 2);
        let dump = ring.drain();
        let count = |st: Stage| dump.events.iter().filter(|e| e.stage == st).count();
        assert_eq!(count(Stage::Compute), 2);
        assert_eq!(count(Stage::Encode), 2);
        assert_eq!(count(Stage::Wire), 2);
        // every event belongs to this worker, with monotone per-stage seqs
        for ev in &dump.events {
            assert_eq!(ev.worker, 3);
        }
        // dense submissions bill dim × 4 bytes in the encode aux
        let enc = dump
            .events
            .iter()
            .find(|e| e.stage == Stage::Encode)
            .unwrap();
        assert_eq!(enc.aux, 8);
    }

    #[test]
    fn compressed_worker_sends_sparse_payloads_and_counts_bytes() {
        use crate::coordinator::compress::KSpec;
        let (gtx, grx) = mpsc::channel::<ShardEvent>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 3,
            min_iter: Duration::ZERO,
            wire: WireFormat::TopK(KSpec::Count(1)),
            max_grads: None,
            trace: None,
        };
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout: ShardLayout::new(2, 1),
            grad_txs: vec![gtx],
            cells: vec![cell],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            let mut transport = crate::transport::InProcTransport::new(endpoints, rrx);
            run_worker(&cfg, engine, source, vec![0.0, 0.0], &mut transport, &stop2, &clock)
        });
        for _ in 0..3 {
            let msg = expect_grad(grx.recv_timeout(Duration::from_secs(2)).unwrap());
            match &msg.grad {
                crate::coordinator::compress::ShardGrad::Sparse(s) => {
                    assert_eq!(s.idx.len(), 1, "top-1 payload carries one coordinate");
                    assert_eq!(s.dim, 2);
                }
                other => panic!("expected sparse payload, got {other:?}"),
            }
            drop(msg);
            rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.grads_sent >= 3);
        // 8 bytes per top-1 submission, far below the 2×4 B dense slice…
        // equal here only because dim = 2; the accounting is what's pinned.
        assert_eq!(report.bytes_sent, report.grads_sent * 8);
    }

    #[test]
    fn token_source_converts_to_f32() {
        use crate::data::tokens::{generate, CorpusSpec, TokenBatcher};
        let spec = CorpusSpec {
            length: 2000,
            seq_len: 8,
            ..Default::default()
        };
        let d = Arc::new(generate(&spec, &mut Pcg64::seeded(1)));
        let shard: Vec<usize> = (0..d.num_windows()).collect();
        let tb = TokenBatcher::new(Arc::clone(&d), shard, 2, Pcg64::seeded(2));
        let mut src = TokenBatchSource::new(tb, 2, 8);
        let (x, y) = src.next();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        for &v in x {
            assert_eq!(v, v.round());
            assert!((0.0..64.0).contains(&v));
        }
    }
}
