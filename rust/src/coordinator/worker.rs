//! The gradient-worker loop and batch sources.
//!
//! A worker owns: a local parameter copy, a [`GradEngine`] (constructed
//! inside the thread — PJRT clients are not `Send`), a [`BatchSource`], and
//! its half of the sharded channel protocol. Per iteration it computes a
//! gradient, optionally sleeps an injected delay (the paper's heterogeneity
//! model), encodes it in the configured [`WireFormat`] (dense submissions
//! fan out as `Arc` clones of one buffer; compressed ones go through the
//! worker's [`GradEncoder`], whose buffers recycle round-trip), waits for
//! all `S` shard replies, and refreshes only the shard slices whose
//! parameters actually changed — via snapshot-cell pointer reads, never
//! O(dim) channel payloads.

use super::clock::Clock;
use super::compress::{submission_bytes, GradEncoder, ShardGrad, WireFormat};
use super::delay::DelayModel;
use super::params::SnapshotCell;
use super::server::{Reply, ShardMsg};
use super::shard::ShardLayout;
use crate::data::tokens::TokenBatcher;
use crate::data::Batcher;
use crate::engine::GradEngine;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Produces mini-batches as (features, labels) slices. Implementations must
/// reuse internal buffers (the worker loop is allocation-free).
pub trait BatchSource: Send {
    fn next(&mut self) -> (&[f32], &[i32]);
}

impl BatchSource for Batcher {
    fn next(&mut self) -> (&[f32], &[i32]) {
        self.next_batch()
    }
}

/// Adapter: token windows → f32 features (token ids are exactly
/// representable in f32 for any realistic vocab; the L2 model casts back to
/// int32 before the embedding lookup).
pub struct TokenBatchSource {
    inner: TokenBatcher,
    x_buf: Vec<f32>,
}

impl TokenBatchSource {
    pub fn new(inner: TokenBatcher, batch: usize, seq_len: usize) -> Self {
        TokenBatchSource {
            inner,
            x_buf: vec![0.0; batch * seq_len],
        }
    }
}

impl BatchSource for TokenBatchSource {
    fn next(&mut self) -> (&[f32], &[i32]) {
        let (inp, tgt) = self.inner.next_batch();
        for (o, &t) in self.x_buf.iter_mut().zip(inp) {
            *o = t as f32;
        }
        (&self.x_buf, tgt)
    }
}

/// Per-worker configuration.
pub struct WorkerConfig {
    pub id: usize,
    /// Whether this worker is in the delayed 50% (paper §6).
    pub delayed: bool,
    pub delay: DelayModel,
    pub seed: u64,
    /// Minimum wall time per gradient iteration. Simulates the paper's
    /// per-gradient compute cost (ray + PyTorch on their cluster) for models
    /// whose AOT executables run much faster here; zero = no floor.
    /// See DESIGN.md §1 (substitutions).
    pub min_iter: Duration,
    /// How this worker encodes gradients on the wire.
    pub wire: WireFormat,
}

/// The worker's view of the sharded parameter server.
pub struct ShardEndpoints {
    pub layout: ShardLayout,
    /// One gradient channel per shard, in shard order.
    pub grad_txs: Vec<Sender<ShardMsg>>,
    /// One snapshot cell per shard, in shard order.
    pub cells: Vec<Arc<SnapshotCell>>,
}

/// Worker-side counters returned at join.
#[derive(Debug, Default, Clone)]
pub struct WorkerReport {
    pub grads_sent: u64,
    /// Shard-slice refreshes actually copied from snapshot cells.
    pub refreshes: u64,
    /// Shard replies that required no parameter copy.
    pub unchanged_replies: u64,
    pub delay_slept: f64,
    /// Bytes-on-wire this worker's submissions carried (summed over the
    /// per-shard payloads of every submission).
    pub bytes_sent: u64,
}

/// Run one worker until `stop` is set. Call on a dedicated thread. All
/// timing (iteration pacing, injected delays) goes through `clock`, never
/// through `Instant`/`thread::sleep` directly.
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    cfg: &WorkerConfig,
    mut engine: Box<dyn GradEngine>,
    mut source: Box<dyn BatchSource>,
    init_params: Vec<f32>,
    endpoints: ShardEndpoints,
    reply_rx: Receiver<Reply>,
    stop: &AtomicBool,
    clock: &dyn Clock,
) -> WorkerReport {
    let mut report = WorkerReport::default();
    let mut params = init_params;
    let dim = params.len();
    let shards = endpoints.layout.shards();
    debug_assert_eq!(endpoints.grad_txs.len(), shards);
    debug_assert_eq!(endpoints.cells.len(), shards);
    // Per-shard version of the local parameter copy.
    let mut versions = vec![0u64; shards];
    // Which shards to refresh after the current round of replies.
    let mut needs_refresh = vec![false; shards];
    let mut grad_buf = vec![0.0f32; dim];
    let mut spare = vec![0.0f32; dim];
    let mut rng = Pcg64::new(cfg.seed, cfg.id as u64 + 1);
    // Dense submissions keep the zero-copy Arc-swap fast path; compressed
    // formats go through the worker's encoder (recycled buffers).
    let mut encoder = if cfg.wire.is_dense() {
        None
    } else {
        Some(GradEncoder::new(cfg.wire.clone(), dim, shards))
    };
    let mut payloads: Vec<ShardGrad> = Vec::with_capacity(shards);

    'outer: while !stop.load(Ordering::Relaxed) {
        let iter_start = clock.now();
        let (x, y) = source.next();
        let loss = match engine.grad(&params, x, y, &mut grad_buf) {
            Ok(l) => l,
            Err(e) => {
                crate::log_warn!("worker", "worker {} grad failed: {e:#}", cfg.id);
                break;
            }
        };
        if cfg.delayed {
            let d = cfg.delay.sample(&mut rng);
            if !d.is_zero() {
                report.delay_slept += d.as_secs_f64();
                // Sleep in small slices so shutdown stays responsive even
                // with multi-second injected delays.
                let deadline = clock.now() + d;
                while clock.now() < deadline && !stop.load(Ordering::Relaxed) {
                    clock.sleep(Duration::from_millis(5).min(d));
                }
            }
        }
        // Enforce the compute-cost floor (paper-regime pacing).
        if !cfg.min_iter.is_zero() {
            let elapsed = clock.now().saturating_sub(iter_start);
            if elapsed < cfg.min_iter && !stop.load(Ordering::Relaxed) {
                clock.sleep(cfg.min_iter - elapsed);
            }
        }
        // Encode and fan the gradient out to every shard. Dense: Arc clones
        // of one buffer, the spare swaps in so the worker always owns a
        // compute buffer. Compressed: the encoder splits per shard into its
        // recycled payload buffers.
        let shared = match encoder.as_mut() {
            None => {
                let arc =
                    Arc::new(std::mem::replace(&mut grad_buf, std::mem::take(&mut spare)));
                report.bytes_sent += (dim * 4) as u64;
                Some(arc)
            }
            Some(enc) => {
                enc.encode(&grad_buf, &endpoints.layout, &mut payloads);
                report.bytes_sent += submission_bytes(&payloads, &endpoints.layout);
                None
            }
        };
        for (s, tx) in endpoints.grad_txs.iter().enumerate() {
            let grad = match &shared {
                Some(arc) => ShardGrad::Dense(Arc::clone(arc)),
                None => payloads[s].clone(),
            };
            let sent = tx.send(ShardMsg {
                worker: cfg.id,
                base_version: versions[s],
                loss,
                grad,
            });
            if sent.is_err() {
                break 'outer; // server gone
            }
        }
        report.grads_sent += 1;

        // Await one reply per shard (with stop checks: barrier waits can
        // span seconds).
        let mut pending = shards;
        while pending > 0 {
            match reply_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(Reply::Updated { shard, version }) => {
                    if version != versions[shard] {
                        needs_refresh[shard] = true;
                    }
                    pending -= 1;
                }
                Ok(Reply::Unchanged { .. }) => {
                    report.unchanged_replies += 1;
                    pending -= 1;
                }
                Err(RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::Relaxed) {
                        return report;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return report,
            }
        }
        // Every shard dropped its clone before replying: recycle the dense
        // buffer (the fallback allocation only triggers on shutdown races).
        // Compressed payload buffers recycle inside the encoder on its next
        // `encode` by the same mechanism.
        if let Some(arc) = shared {
            spare = Arc::try_unwrap(arc).unwrap_or_else(|_| vec![0.0f32; dim]);
        }
        // Refresh changed shard slices from their snapshot cells: a pointer
        // read per shard, one memcpy per *changed* shard.
        for (s, flag) in needs_refresh.iter_mut().enumerate() {
            if *flag {
                let snap = endpoints.cells[s].load();
                params[endpoints.layout.range(s)].copy_from_slice(&snap.theta);
                versions[s] = snap.version;
                report.refreshes += 1;
                *flag = false;
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::QuadraticEngine;
    use std::sync::mpsc;
    use std::sync::Arc;

    struct ConstSource {
        x: Vec<f32>,
        y: Vec<i32>,
    }

    impl BatchSource for ConstSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&self.x, &self.y)
        }
    }

    #[test]
    fn worker_submits_and_refreshes_from_snapshots() {
        let (gtx, grx) = mpsc::channel::<ShardMsg>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 1,
            min_iter: Duration::ZERO,
            wire: WireFormat::Dense,
        };
        let layout = ShardLayout::new(2, 1);
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout,
            grad_txs: vec![gtx],
            cells: vec![Arc::clone(&cell)],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            run_worker(&cfg, engine, source, vec![0.0, 0.0], endpoints, rrx, &stop2, &clock)
        });
        // Act as the shard server for 3 round trips, publishing snapshots.
        for i in 0..3u64 {
            let msg = grx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(msg.worker, 0);
            assert_eq!(msg.base_version, i);
            drop(msg); // release the shared buffer like a real shard
            publish(&cell, vec![0.5, 0.5], i + 1);
            rtx.send(Reply::Updated {
                shard: 0,
                version: i + 1,
            })
            .unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        // Consume anything in flight, then drop our ends.
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.grads_sent >= 3);
        assert!(report.refreshes >= 3);
    }

    #[test]
    fn unchanged_replies_skip_refresh() {
        let (gtx, grx) = mpsc::channel::<ShardMsg>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 2,
            min_iter: Duration::ZERO,
            wire: WireFormat::Dense,
        };
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout: ShardLayout::new(2, 1),
            grad_txs: vec![gtx],
            cells: vec![cell],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            run_worker(&cfg, engine, source, vec![0.0, 0.0], endpoints, rrx, &stop2, &clock)
        });
        for _ in 0..2 {
            let msg = grx.recv_timeout(Duration::from_secs(2)).unwrap();
            assert_eq!(msg.base_version, 0, "worker must keep version 0");
            drop(msg);
            rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.unchanged_replies >= 2);
        assert_eq!(report.refreshes, 0);
    }

    fn publish(cell: &Arc<SnapshotCell>, theta: Vec<f32>, version: u64) {
        cell.publish_raw(theta, version);
    }

    #[test]
    fn compressed_worker_sends_sparse_payloads_and_counts_bytes() {
        use crate::coordinator::compress::KSpec;
        let (gtx, grx) = mpsc::channel::<ShardMsg>();
        let (rtx, rrx) = mpsc::channel::<Reply>();
        let stop = Arc::new(AtomicBool::new(false));
        let cfg = WorkerConfig {
            id: 0,
            delayed: false,
            delay: DelayModel::none(),
            seed: 3,
            min_iter: Duration::ZERO,
            wire: WireFormat::TopK(KSpec::Count(1)),
        };
        let cell = Arc::new(SnapshotCell::new(vec![0.0, 0.0]));
        let endpoints = ShardEndpoints {
            layout: ShardLayout::new(2, 1),
            grad_txs: vec![gtx],
            cells: vec![cell],
        };
        let stop2 = Arc::clone(&stop);
        let h = std::thread::spawn(move || {
            let engine = Box::new(QuadraticEngine::new(vec![1.0, 1.0], 1, 0.0, 0));
            let source = Box::new(ConstSource {
                x: vec![],
                y: vec![],
            });
            let clock = crate::coordinator::clock::RealClock::start();
            run_worker(&cfg, engine, source, vec![0.0, 0.0], endpoints, rrx, &stop2, &clock)
        });
        for _ in 0..3 {
            let msg = grx.recv_timeout(Duration::from_secs(2)).unwrap();
            match &msg.grad {
                crate::coordinator::compress::ShardGrad::Sparse(s) => {
                    assert_eq!(s.idx.len(), 1, "top-1 payload carries one coordinate");
                    assert_eq!(s.dim, 2);
                }
                other => panic!("expected sparse payload, got {other:?}"),
            }
            drop(msg);
            rtx.send(Reply::Unchanged { shard: 0 }).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        while grx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        drop(rtx);
        let report = h.join().unwrap();
        assert!(report.grads_sent >= 3);
        // 8 bytes per top-1 submission, far below the 2×4 B dense slice…
        // equal here only because dim = 2; the accounting is what's pinned.
        assert_eq!(report.bytes_sent, report.grads_sent * 8);
    }

    #[test]
    fn token_source_converts_to_f32() {
        use crate::data::tokens::{generate, CorpusSpec, TokenBatcher};
        let spec = CorpusSpec {
            length: 2000,
            seq_len: 8,
            ..Default::default()
        };
        let d = Arc::new(generate(&spec, &mut Pcg64::seeded(1)));
        let shard: Vec<usize> = (0..d.num_windows()).collect();
        let tb = TokenBatcher::new(Arc::clone(&d), shard, 2, Pcg64::seeded(2));
        let mut src = TokenBatchSource::new(tb, 2, 8);
        let (x, y) = src.next();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        for &v in x {
            assert_eq!(v, v.round());
            assert!((0.0..64.0).contains(&v));
        }
    }
}
