//! Elastic worker membership: which worker slots are *live* right now.
//!
//! The paper's threshold `K(n)` and the sync barrier are defined against a
//! worker count. With a launch-time-fixed count, a crashed or departed
//! worker permanently stalls the sync-shifted tail of a hybrid run — the
//! exact fragility the paper argues asynchronous methods avoid. Elastic
//! membership replaces that fixed count with a live set: a worker that is
//! declared dead (heartbeat timeout on TCP, `crash`/`leave` clause in the
//! simulator, spent step budget) is removed from every barrier denominator,
//! its slot reopens for late joiners, and a rejoining worker re-enters at
//! the current membership epoch with a fresh snapshot.
//!
//! [`Membership`] is the pure tracker: a live mask over worker slots plus a
//! monotone **epoch** counter bumped on every effective transition. It is
//! embedded per shard inside [`super::policy::Aggregator`] (each shard
//! applies the identical membership event sequence, so per-shard state
//! stays in lockstep — DESIGN.md §2.7) and once globally in the simulator
//! for the run-level membership trajectory. Transitions are idempotent:
//! re-joining a live slot or re-leaving a dead one is a no-op and does not
//! bump the epoch, which is what lets the TCP frontend report every attach
//! as a join without double-counting the founding members.

/// Live-set tracker over a fixed number of worker slots.
#[derive(Clone, Debug)]
pub struct Membership {
    live: Vec<bool>,
    live_count: usize,
    epoch: u64,
}

impl Membership {
    /// `slots` total worker slots, of which the first `initial_live` start
    /// live (the founding members; joiner slots start dead). The initial
    /// complement is epoch 0 — only *changes* bump the epoch.
    pub fn new(slots: usize, initial_live: usize) -> Membership {
        let initial_live = initial_live.min(slots);
        let mut live = vec![false; slots];
        for l in live.iter_mut().take(initial_live) {
            *l = true;
        }
        Membership {
            live,
            live_count: initial_live,
            epoch: 0,
        }
    }

    /// Total worker slots (live or not).
    pub fn slots(&self) -> usize {
        self.live.len()
    }

    /// Currently live workers.
    pub fn live(&self) -> usize {
        self.live_count
    }

    /// Monotone transition counter: one tick per effective join or leave.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn is_live(&self, worker: usize) -> bool {
        self.live.get(worker).copied().unwrap_or(false)
    }

    /// Mark `worker` live. Returns true when the live set changed
    /// (idempotent: joining a live slot is a no-op). Out-of-range ids are
    /// ignored — membership events are advisory, never a panic source.
    pub fn join(&mut self, worker: usize) -> bool {
        match self.live.get_mut(worker) {
            Some(l) if !*l => {
                *l = true;
                self.live_count += 1;
                self.epoch += 1;
                true
            }
            _ => false,
        }
    }

    /// Mark `worker` dead. Returns true when the live set changed.
    pub fn leave(&mut self, worker: usize) -> bool {
        match self.live.get_mut(worker) {
            Some(l) if *l => {
                *l = false;
                self.live_count -= 1;
                self.epoch += 1;
                true
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn founding_members_are_live_without_epoch_ticks() {
        let m = Membership::new(5, 3);
        assert_eq!(m.slots(), 5);
        assert_eq!(m.live(), 3);
        assert_eq!(m.epoch(), 0);
        assert!(m.is_live(0) && m.is_live(2));
        assert!(!m.is_live(3) && !m.is_live(4));
    }

    #[test]
    fn transitions_bump_epoch_and_are_idempotent() {
        let mut m = Membership::new(3, 3);
        assert!(!m.join(0), "re-joining a live slot is a no-op");
        assert_eq!(m.epoch(), 0);
        assert!(m.leave(1));
        assert_eq!((m.live(), m.epoch()), (2, 1));
        assert!(!m.leave(1), "re-leaving a dead slot is a no-op");
        assert_eq!(m.epoch(), 1);
        assert!(!m.is_live(1));
        assert!(m.join(1));
        assert_eq!((m.live(), m.epoch()), (3, 2));
    }

    #[test]
    fn out_of_range_ids_are_ignored() {
        let mut m = Membership::new(2, 2);
        assert!(!m.join(7));
        assert!(!m.leave(7));
        assert_eq!(m.epoch(), 0);
        assert_eq!(m.live(), 2);
    }

    #[test]
    fn everyone_can_leave() {
        let mut m = Membership::new(2, 2);
        assert!(m.leave(0));
        assert!(m.leave(1));
        assert_eq!(m.live(), 0);
        assert!(!m.is_live(0) && !m.is_live(1));
    }

    #[test]
    fn initial_live_is_clamped_to_slots() {
        let m = Membership::new(2, 9);
        assert_eq!(m.live(), 2);
    }
}
