//! Deterministic virtual-time simulation of the full coordinator pipeline.
//!
//! Layering:
//! - [`fault`] — fault clauses (crash / restart / straggler burst / drop /
//!   duplicate / shard stall / Byzantine scale, sign-flip and NaN
//!   poisoning) and their compact text encoding.
//! - [`scenario`] — the one-line scenario DSL: `workers=8 shards=2
//!   policy=hybrid:step:50 secs=10 faults=crash:3@5` fully determines a
//!   run.
//! - [`des`] — the discrete-event engine: PS shards + workers + evaluator
//!   single-threaded in virtual time over a `(time, sequence)`-ordered
//!   event queue, reusing the same pure state machines
//!   ([`super::policy::Aggregator`], [`super::params::ParamStore`]) the
//!   threaded stack runs.
//!
//! Gradient submissions travel in the scenario's wire format
//! (`compress=` key; [`super::compress`]): workers encode through the
//! same `GradEncoder` the threaded stack uses, deliveries carry
//! per-shard payloads, and the metrics account bytes-on-wire — so
//! equal-bandwidth comparisons replay deterministically too.
//!
//! Guarantee: a run is a pure function of (scenario, inputs); the same
//! seed + scenario yields a bitwise-identical [`super::RunMetrics`]. The
//! tier-1 suite leans on this to replay the paper's async/sync/hybrid
//! comparison under injected delays in milliseconds instead of wall-clock
//! minutes, and `hybrid-sgd train --sim --fault-spec ...` exposes it on
//! the CLI. Ordering guarantees and fault semantics: DESIGN.md §2.4.

pub mod des;
pub mod fault;
pub mod scenario;

pub use des::{simulate, Simulation};
pub use fault::{FaultPlan, FaultSpec};
pub use scenario::Scenario;
