//! Fault specifications for simulated runs.
//!
//! A [`FaultPlan`] is a list of [`FaultSpec`] clauses parsed from a compact
//! text encoding (one clause per comma-separated segment):
//!
//! | clause                | meaning                                              |
//! |-----------------------|------------------------------------------------------|
//! | `crash:W@T`           | worker `W` dies at `T` seconds                       |
//! | `restart:W@T`         | worker `W` comes back at `T` (fresh θ from the PS)   |
//! | `leave:W@T`           | worker `W` departs cleanly at `T` (elastic runs only)|
//! | `join:+N@T`           | `N` new workers join at `T` (elastic runs only)      |
//! | `slow:W@T1..T2*F`     | straggler burst: `W` runs `F`× slower in `[T1, T2)`  |
//! | `drop:W@T1..T2:P`     | each submission of `W` in the window is lost w.p. `P`|
//! | `dup:W@T1..T2:P`      | each submission is delivered twice w.p. `P`          |
//! | `stall:S@T1..T2`      | shard server `S` stalls; arrivals queue until `T2`   |
//! | `byz-scale:W:F@T`     | Byzantine: `W` submits gradients scaled by `F`       |
//! | `byz-flip:W@T`        | Byzantine: `W` submits sign-flipped gradients        |
//! | `byz-nan:W@T`         | Byzantine: `W` poisons its gradients with NaN        |
//!
//! `W` may be `*` (every worker). Times are seconds with an optional `s`
//! suffix (`5`, `5s`, `1.5`). Example:
//! `crash:3@5s,stall:0@1..1.5,slow:*@2..4*8,leave:1@8,join:+2@5`.
//!
//! The `byz-*` clauses take either an open-ended onset (`@T`: Byzantine
//! from `T` to the end of the run) or a bounded window (`@T1..T2`). They
//! corrupt the *content* of a submission, never its timing or fan-out:
//! the attacker still computes a real gradient on its shard of the data,
//! corrupts it, and sends the corrupted payload to every shard at the
//! normal time. Delivery therefore preserves the lockstep invariant —
//! every shard sees the same arrival sequence — and the defense lives
//! entirely on the server side (`aggregate=` in the scenario; DESIGN.md
//! §2.10). NaN payloads are rejected at the server boundary and counted,
//! never applied.
//!
//! `leave`/`join` are membership churn, not transport faults: they require
//! `elastic=on` in the scenario (validated there), joiners take fresh
//! worker ids appended after the launch complement, and under elastic
//! membership a `crash` additionally *evicts* the worker from every
//! barrier denominator (the simulator analogue of the TCP heartbeat
//! timeout — DESIGN.md §2.7).
//!
//! Semantics notes (mirrored in DESIGN.md §2.4):
//! - *Drop* loses the whole fan-out of one submission — every shard misses
//!   it, never a subset — modelling a lost worker→PS message. The worker
//!   moves on after its normal iteration time (send-and-forget transport).
//! - *Duplicate* delivers the identical fan-out twice to every shard
//!   (at-least-once transport); the ghost copy generates no worker replies.
//! - *Stall* delays shard processing but preserves per-shard FIFO order, so
//!   every shard still observes the same arrival sequence (the lockstep
//!   invariant of DESIGN.md §2.1 survives every fault type).
//! - Probabilistic clauses draw from the *worker's* seeded RNG stream, so a
//!   fault scenario replays bit-identically from its seed.

use std::time::Duration;

/// One fault clause. Windows are half-open `[from, until)`.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultSpec {
    /// Worker dies at `at`: no further submissions (under sync, the barrier
    /// starves — deliberately observable).
    Crash { worker: usize, at: Duration },
    /// A crashed worker rejoins at `at` with parameters refreshed from the
    /// current shard stores.
    Restart { worker: usize, at: Duration },
    /// Worker `worker` departs cleanly at `at` (elastic membership: it is
    /// removed from every barrier denominator; a later `restart` re-admits
    /// it at the current membership epoch).
    Leave { worker: usize, at: Duration },
    /// `count` brand-new workers join the run at `at`, taking fresh ids
    /// after the launch complement (elastic membership only).
    Join { count: usize, at: Duration },
    /// Straggler burst: iteration time multiplied by `factor` inside the
    /// window. `worker == None` affects every worker.
    Slow {
        worker: Option<usize>,
        from: Duration,
        until: Duration,
        factor: f64,
    },
    /// Submissions inside the window are dropped with probability `prob`.
    Drop {
        worker: Option<usize>,
        from: Duration,
        until: Duration,
        prob: f64,
    },
    /// Submissions inside the window are duplicated with probability `prob`.
    Duplicate {
        worker: Option<usize>,
        from: Duration,
        until: Duration,
        prob: f64,
    },
    /// Shard server `shard` is unresponsive inside the window; arrivals are
    /// processed at `until` in arrival order.
    Stall {
        shard: usize,
        from: Duration,
        until: Duration,
    },
    /// Byzantine: submissions of `worker` are scaled by `factor` inside the
    /// window (`until == None` = until the end of the run).
    ByzScale {
        worker: Option<usize>,
        factor: f64,
        from: Duration,
        until: Option<Duration>,
    },
    /// Byzantine: submissions of `worker` are sign-flipped inside the window.
    ByzFlip {
        worker: Option<usize>,
        from: Duration,
        until: Option<Duration>,
    },
    /// Byzantine: submissions of `worker` are poisoned with NaN inside the
    /// window (exercises the server-side non-finite rejection path).
    ByzNan {
        worker: Option<usize>,
        from: Duration,
        until: Option<Duration>,
    },
}

fn parse_secs(s: &str) -> anyhow::Result<Duration> {
    let s = s.strip_suffix('s').unwrap_or(s);
    let v: f64 = s
        .parse()
        .map_err(|_| anyhow::anyhow!("bad time `{s}` (seconds, e.g. `5` or `1.5s`)"))?;
    anyhow::ensure!(v >= 0.0 && v.is_finite(), "time `{s}` must be >= 0");
    Ok(Duration::from_secs_f64(v))
}

fn parse_who(s: &str) -> anyhow::Result<Option<usize>> {
    if s == "*" {
        return Ok(None);
    }
    Ok(Some(s.parse().map_err(|_| {
        anyhow::anyhow!("bad worker id `{s}` (index or `*`)")
    })?))
}

/// Parse `T1..T2` into a non-empty half-open window.
fn parse_window(s: &str) -> anyhow::Result<(Duration, Duration)> {
    let (a, b) = s
        .split_once("..")
        .ok_or_else(|| anyhow::anyhow!("bad window `{s}` (expected `T1..T2`)"))?;
    let (from, until) = (parse_secs(a)?, parse_secs(b)?);
    anyhow::ensure!(from < until, "empty window `{s}`");
    Ok((from, until))
}

/// Parse `T` (open-ended onset) or `T1..T2` (bounded window).
fn parse_open_window(s: &str) -> anyhow::Result<(Duration, Option<Duration>)> {
    match s.split_once("..") {
        Some((a, b)) => {
            let (from, until) = (parse_secs(a)?, parse_secs(b)?);
            anyhow::ensure!(from < until, "empty window `{s}`");
            Ok((from, Some(until)))
        }
        None => Ok((parse_secs(s)?, None)),
    }
}

fn fmt_secs(d: &Duration) -> String {
    format!("{}", d.as_secs_f64())
}

fn fmt_who(w: &Option<usize>) -> String {
    match w {
        Some(i) => i.to_string(),
        None => "*".to_string(),
    }
}

fn fmt_open_window(from: &Duration, until: &Option<Duration>) -> String {
    match until {
        Some(u) => format!("{}..{}", fmt_secs(from), fmt_secs(u)),
        None => fmt_secs(from),
    }
}

impl FaultSpec {
    /// Parse one clause (see the module docs for the grammar).
    pub fn parse(s: &str) -> anyhow::Result<FaultSpec> {
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("bad fault clause `{s}` (expected `kind:...`)"))?;
        let err = || anyhow::anyhow!("bad fault clause `{s}`");
        match kind {
            "crash" | "restart" | "leave" => {
                let (who, at) = rest.split_once('@').ok_or_else(err)?;
                let worker = parse_who(who)?
                    .ok_or_else(|| anyhow::anyhow!("`{kind}` needs a concrete worker id"))?;
                let at = parse_secs(at)?;
                Ok(match kind {
                    "crash" => FaultSpec::Crash { worker, at },
                    "restart" => FaultSpec::Restart { worker, at },
                    _ => FaultSpec::Leave { worker, at },
                })
            }
            "join" => {
                let (count, at) = rest.split_once('@').ok_or_else(err)?;
                let count = count.strip_prefix('+').ok_or_else(|| {
                    anyhow::anyhow!("bad join clause `{s}` (expected `join:+N@T`)")
                })?;
                let count: usize = count.parse().map_err(|_| {
                    anyhow::anyhow!("bad join count in `{s}` (expected `join:+N@T`)")
                })?;
                anyhow::ensure!(count >= 1, "join count must be >= 1 in `{s}`");
                let at = parse_secs(at)?;
                Ok(FaultSpec::Join { count, at })
            }
            "slow" => {
                let (who, rest) = rest.split_once('@').ok_or_else(err)?;
                let (window, factor) = rest.rsplit_once('*').ok_or_else(err)?;
                let worker = parse_who(who)?;
                let (from, until) = parse_window(window)?;
                let factor: f64 = factor.parse().map_err(|_| err())?;
                anyhow::ensure!(
                    factor > 0.0 && factor.is_finite(),
                    "slow factor must be > 0, got `{factor}`"
                );
                Ok(FaultSpec::Slow {
                    worker,
                    from,
                    until,
                    factor,
                })
            }
            "drop" | "dup" => {
                let (who, rest) = rest.split_once('@').ok_or_else(err)?;
                let (window, prob) = rest.rsplit_once(':').ok_or_else(err)?;
                let worker = parse_who(who)?;
                let (from, until) = parse_window(window)?;
                let prob: f64 = prob.parse().map_err(|_| err())?;
                anyhow::ensure!(
                    (0.0..=1.0).contains(&prob),
                    "probability must be in [0, 1], got `{prob}`"
                );
                Ok(if kind == "drop" {
                    FaultSpec::Drop {
                        worker,
                        from,
                        until,
                        prob,
                    }
                } else {
                    FaultSpec::Duplicate {
                        worker,
                        from,
                        until,
                        prob,
                    }
                })
            }
            "stall" => {
                let (who, window) = rest.split_once('@').ok_or_else(err)?;
                let shard: usize = who.parse().map_err(|_| err())?;
                let (from, until) = parse_window(window)?;
                Ok(FaultSpec::Stall { shard, from, until })
            }
            "byz-scale" => {
                let (who, rest) = rest.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!("bad byz-scale clause `{s}` (expected `byz-scale:W:F@T`)")
                })?;
                let (factor, window) = rest.split_once('@').ok_or_else(err)?;
                let worker = parse_who(who)?;
                let factor: f64 = factor.parse().map_err(|_| err())?;
                anyhow::ensure!(
                    factor.is_finite(),
                    "byz-scale factor must be finite, got `{factor}`"
                );
                let (from, until) = parse_open_window(window)?;
                Ok(FaultSpec::ByzScale {
                    worker,
                    factor,
                    from,
                    until,
                })
            }
            "byz-flip" | "byz-nan" => {
                let (who, window) = rest.split_once('@').ok_or_else(err)?;
                let worker = parse_who(who)?;
                let (from, until) = parse_open_window(window)?;
                Ok(if kind == "byz-flip" {
                    FaultSpec::ByzFlip {
                        worker,
                        from,
                        until,
                    }
                } else {
                    FaultSpec::ByzNan {
                        worker,
                        from,
                        until,
                    }
                })
            }
            _ => anyhow::bail!(
                "unknown fault kind `{kind}` \
                 (crash | restart | leave | join | slow | drop | dup | stall \
                  | byz-scale | byz-flip | byz-nan)"
            ),
        }
    }
}

impl std::fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FaultSpec::Crash { worker, at } => write!(f, "crash:{worker}@{}", fmt_secs(at)),
            FaultSpec::Restart { worker, at } => write!(f, "restart:{worker}@{}", fmt_secs(at)),
            FaultSpec::Leave { worker, at } => write!(f, "leave:{worker}@{}", fmt_secs(at)),
            FaultSpec::Join { count, at } => write!(f, "join:+{count}@{}", fmt_secs(at)),
            FaultSpec::Slow {
                worker,
                from,
                until,
                factor,
            } => write!(
                f,
                "slow:{}@{}..{}*{factor}",
                fmt_who(worker),
                fmt_secs(from),
                fmt_secs(until)
            ),
            FaultSpec::Drop {
                worker,
                from,
                until,
                prob,
            } => write!(
                f,
                "drop:{}@{}..{}:{prob}",
                fmt_who(worker),
                fmt_secs(from),
                fmt_secs(until)
            ),
            FaultSpec::Duplicate {
                worker,
                from,
                until,
                prob,
            } => write!(
                f,
                "dup:{}@{}..{}:{prob}",
                fmt_who(worker),
                fmt_secs(from),
                fmt_secs(until)
            ),
            FaultSpec::Stall { shard, from, until } => {
                write!(f, "stall:{shard}@{}..{}", fmt_secs(from), fmt_secs(until))
            }
            FaultSpec::ByzScale {
                worker,
                factor,
                from,
                until,
            } => write!(
                f,
                "byz-scale:{}:{factor}@{}",
                fmt_who(worker),
                fmt_open_window(from, until)
            ),
            FaultSpec::ByzFlip {
                worker,
                from,
                until,
            } => write!(
                f,
                "byz-flip:{}@{}",
                fmt_who(worker),
                fmt_open_window(from, until)
            ),
            FaultSpec::ByzNan {
                worker,
                from,
                until,
            } => write!(
                f,
                "byz-nan:{}@{}",
                fmt_who(worker),
                fmt_open_window(from, until)
            ),
        }
    }
}

/// An ordered set of fault clauses plus the query helpers the event loop
/// uses. Clause order is irrelevant to semantics (queries combine all
/// matching clauses) but preserved for display.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse a comma-separated clause list; empty/whitespace input is the
    /// empty plan.
    pub fn parse(s: &str) -> anyhow::Result<FaultPlan> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(FaultPlan::default());
        }
        let specs = s
            .split(',')
            .map(|c| FaultSpec::parse(c.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(FaultPlan { specs })
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    fn hits(who: &Option<usize>, worker: usize) -> bool {
        who.map_or(true, |w| w == worker)
    }

    fn in_window(at: Duration, from: Duration, until: Duration) -> bool {
        at >= from && at < until
    }

    fn in_open_window(at: Duration, from: Duration, until: &Option<Duration>) -> bool {
        at >= from && until.map_or(true, |u| at < u)
    }

    /// Combined slowdown factor for `worker` at time `at` (product of all
    /// active `slow` clauses; 1.0 = no burst).
    pub fn slow_factor(&self, worker: usize, at: Duration) -> f64 {
        let mut f = 1.0;
        for s in &self.specs {
            if let FaultSpec::Slow {
                worker: who,
                from,
                until,
                factor,
            } = s
            {
                if Self::hits(who, worker) && Self::in_window(at, *from, *until) {
                    f *= factor;
                }
            }
        }
        f
    }

    /// Probability that a submission of `worker` at `at` is dropped (max of
    /// the active clauses).
    pub fn drop_prob(&self, worker: usize, at: Duration) -> f64 {
        let mut p: f64 = 0.0;
        for s in &self.specs {
            if let FaultSpec::Drop {
                worker: who,
                from,
                until,
                prob,
            } = s
            {
                if Self::hits(who, worker) && Self::in_window(at, *from, *until) {
                    p = p.max(*prob);
                }
            }
        }
        p
    }

    /// Probability that a submission of `worker` at `at` is duplicated.
    pub fn dup_prob(&self, worker: usize, at: Duration) -> f64 {
        let mut p: f64 = 0.0;
        for s in &self.specs {
            if let FaultSpec::Duplicate {
                worker: who,
                from,
                until,
                prob,
            } = s
            {
                if Self::hits(who, worker) && Self::in_window(at, *from, *until) {
                    p = p.max(*prob);
                }
            }
        }
        p
    }

    /// Combined Byzantine scale factor for a submission of `worker` at `at`
    /// (product of all active `byz-scale` clauses; 1.0 = honest).
    pub fn byz_scale_factor(&self, worker: usize, at: Duration) -> f64 {
        let mut f = 1.0;
        for s in &self.specs {
            if let FaultSpec::ByzScale {
                worker: who,
                factor,
                from,
                until,
            } = s
            {
                if Self::hits(who, worker) && Self::in_open_window(at, *from, until) {
                    f *= factor;
                }
            }
        }
        f
    }

    /// Whether a submission of `worker` at `at` is sign-flipped.
    pub fn byz_flip(&self, worker: usize, at: Duration) -> bool {
        self.specs.iter().any(|s| {
            matches!(s, FaultSpec::ByzFlip { worker: who, from, until }
                if Self::hits(who, worker) && Self::in_open_window(at, *from, until))
        })
    }

    /// Whether a submission of `worker` at `at` is poisoned with NaN.
    pub fn byz_nan(&self, worker: usize, at: Duration) -> bool {
        self.specs.iter().any(|s| {
            matches!(s, FaultSpec::ByzNan { worker: who, from, until }
                if Self::hits(who, worker) && Self::in_open_window(at, *from, until))
        })
    }

    /// Whether any clause is a Byzantine content corruption.
    pub fn has_byzantine(&self) -> bool {
        self.specs.iter().any(|s| {
            matches!(
                s,
                FaultSpec::ByzScale { .. } | FaultSpec::ByzFlip { .. } | FaultSpec::ByzNan { .. }
            )
        })
    }

    /// When a gradient arriving at `shard` at time `at` is actually
    /// processed: rolled forward past every stall window it lands in (fixed
    /// point, so overlapping/chained windows compose).
    pub fn deliver_time(&self, shard: usize, at: Duration) -> Duration {
        let mut t = at;
        loop {
            let mut moved = false;
            for s in &self.specs {
                if let FaultSpec::Stall {
                    shard: sh,
                    from,
                    until,
                } = s
                {
                    if *sh == shard && Self::in_window(t, *from, *until) {
                        t = *until;
                        moved = true;
                    }
                }
            }
            if !moved {
                return t;
            }
        }
    }

    /// Largest worker index any clause names (for validation against the
    /// scenario's worker count plus its joiners).
    pub fn max_worker(&self) -> Option<usize> {
        self.specs
            .iter()
            .filter_map(|s| match s {
                FaultSpec::Crash { worker, .. }
                | FaultSpec::Restart { worker, .. }
                | FaultSpec::Leave { worker, .. } => Some(*worker),
                FaultSpec::Slow { worker, .. }
                | FaultSpec::Drop { worker, .. }
                | FaultSpec::Duplicate { worker, .. }
                | FaultSpec::ByzScale { worker, .. }
                | FaultSpec::ByzFlip { worker, .. }
                | FaultSpec::ByzNan { worker, .. } => *worker,
                FaultSpec::Stall { .. } | FaultSpec::Join { .. } => None,
            })
            .max()
    }

    /// Total workers `join` clauses add over the run (the extra slots the
    /// simulator pre-allocates).
    pub fn total_joiners(&self) -> usize {
        self.specs
            .iter()
            .map(|s| match s {
                FaultSpec::Join { count, .. } => *count,
                _ => 0,
            })
            .sum()
    }

    /// Whether any clause is membership churn (`join`/`leave`), which
    /// requires `elastic=on`.
    pub fn has_membership(&self) -> bool {
        self.specs
            .iter()
            .any(|s| matches!(s, FaultSpec::Join { .. } | FaultSpec::Leave { .. }))
    }

    /// Largest shard index any clause names.
    pub fn max_shard(&self) -> Option<usize> {
        self.specs
            .iter()
            .filter_map(|s| match s {
                FaultSpec::Stall { shard, .. } => Some(*shard),
                _ => None,
            })
            .max()
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, s) in self.specs.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn secs(v: f64) -> Duration {
        Duration::from_secs_f64(v)
    }

    #[test]
    fn parse_every_kind_and_roundtrip() {
        let spec = "crash:3@5s,restart:3@7,slow:*@2..4*8,drop:1@0..10:0.25,dup:*@1..2:0.5,stall:0@1..1.5";
        let plan = FaultPlan::parse(spec).unwrap();
        assert_eq!(plan.specs.len(), 6);
        assert_eq!(
            plan.specs[0],
            FaultSpec::Crash {
                worker: 3,
                at: secs(5.0)
            }
        );
        assert_eq!(
            plan.specs[2],
            FaultSpec::Slow {
                worker: None,
                from: secs(2.0),
                until: secs(4.0),
                factor: 8.0
            }
        );
        // Display → parse is the identity.
        let again = FaultPlan::parse(&plan.to_string()).unwrap();
        assert_eq!(plan, again);
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "explode:1@2",
            "crash:*@2",
            "crash:1",
            "slow:1@2..1*4",
            "slow:1@1..2*0",
            "drop:1@1..2:1.5",
            "stall:x@1..2",
            "crash:1@-3",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("   ").unwrap().is_empty());
    }

    #[test]
    fn membership_clauses_parse_and_roundtrip() {
        let plan = FaultPlan::parse("leave:1@8,join:+2@5,join:+1@6.5s").unwrap();
        assert_eq!(
            plan.specs[0],
            FaultSpec::Leave {
                worker: 1,
                at: secs(8.0)
            }
        );
        assert_eq!(
            plan.specs[1],
            FaultSpec::Join {
                count: 2,
                at: secs(5.0)
            }
        );
        assert_eq!(plan.total_joiners(), 3);
        assert!(plan.has_membership());
        assert_eq!(plan.max_worker(), Some(1), "join names no worker id");
        // Display → parse is bitwise the identity.
        assert_eq!(plan.to_string(), "leave:1@8,join:+2@5,join:+1@6.5");
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        // Non-membership plans report no churn.
        let plain = FaultPlan::parse("crash:0@1").unwrap();
        assert!(!plain.has_membership());
        assert_eq!(plain.total_joiners(), 0);
    }

    #[test]
    fn membership_clauses_reject_malformed_input_with_typed_errors() {
        for bad in [
            "join:2@5",    // missing the `+`
            "join:+@5",    // no count
            "join:+0@5",   // zero joiners
            "join:+x@5",   // non-numeric count
            "join:+2",     // no time
            "join:+2@-1",  // negative time
            "join:+2@a",   // bad time
            "leave:*@2",   // leave needs a concrete id
            "leave:1",     // no time
            "leave:@2",    // no id
            "leave:1@",    // empty time
        ] {
            let err = FaultPlan::parse(bad);
            assert!(err.is_err(), "`{bad}` should not parse");
            // typed anyhow error, never a panic — and the message names the
            // offending clause
            let msg = format!("{:#}", err.unwrap_err());
            assert!(!msg.is_empty());
        }
    }

    #[test]
    fn windows_are_half_open() {
        let plan = FaultPlan::parse("slow:0@1..2*4").unwrap();
        assert_eq!(plan.slow_factor(0, secs(0.99)), 1.0);
        assert_eq!(plan.slow_factor(0, secs(1.0)), 4.0);
        assert_eq!(plan.slow_factor(0, secs(1.99)), 4.0);
        assert_eq!(plan.slow_factor(0, secs(2.0)), 1.0);
        assert_eq!(plan.slow_factor(1, secs(1.5)), 1.0, "other worker unaffected");
    }

    #[test]
    fn slow_factors_compose_and_star_matches_all() {
        let plan = FaultPlan::parse("slow:*@0..10*2,slow:1@0..10*3").unwrap();
        assert_eq!(plan.slow_factor(0, secs(5.0)), 2.0);
        assert_eq!(plan.slow_factor(1, secs(5.0)), 6.0);
    }

    #[test]
    fn drop_and_dup_probs_take_max() {
        let plan = FaultPlan::parse("drop:*@0..10:0.2,drop:2@0..10:0.9,dup:2@5..6:1").unwrap();
        assert_eq!(plan.drop_prob(0, secs(1.0)), 0.2);
        assert_eq!(plan.drop_prob(2, secs(1.0)), 0.9);
        assert_eq!(plan.dup_prob(2, secs(5.5)), 1.0);
        assert_eq!(plan.dup_prob(2, secs(6.0)), 0.0);
    }

    #[test]
    fn stall_rolls_delivery_forward_through_chained_windows() {
        let plan = FaultPlan::parse("stall:0@1..2,stall:0@2..3,stall:1@5..6").unwrap();
        assert_eq!(plan.deliver_time(0, secs(0.5)), secs(0.5));
        // lands in the first window, which chains into the second
        assert_eq!(plan.deliver_time(0, secs(1.5)), secs(3.0));
        assert_eq!(plan.deliver_time(0, secs(3.0)), secs(3.0));
        assert_eq!(plan.deliver_time(1, secs(1.5)), secs(1.5));
        assert_eq!(plan.deliver_time(1, secs(5.2)), secs(6.0));
    }

    #[test]
    fn byzantine_clauses_parse_roundtrip_and_query() {
        let plan =
            FaultPlan::parse("byz-scale:2:10@1,byz-flip:*@2..4,byz-nan:1@3,byz-scale:2:-1@0..5")
                .unwrap();
        assert_eq!(
            plan.specs[0],
            FaultSpec::ByzScale {
                worker: Some(2),
                factor: 10.0,
                from: secs(1.0),
                until: None
            }
        );
        assert_eq!(
            plan.specs[1],
            FaultSpec::ByzFlip {
                worker: None,
                from: secs(2.0),
                until: Some(secs(4.0))
            }
        );
        assert!(plan.has_byzantine());
        assert_eq!(plan.max_worker(), Some(2));
        // Display → parse is bitwise the identity.
        assert_eq!(
            plan.to_string(),
            "byz-scale:2:10@1,byz-flip:*@2..4,byz-nan:1@3,byz-scale:2:-1@0..5"
        );
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);

        // Scale factors compose multiplicatively across active clauses.
        assert_eq!(plan.byz_scale_factor(2, secs(0.5)), -1.0);
        assert_eq!(plan.byz_scale_factor(2, secs(1.5)), -10.0);
        assert_eq!(plan.byz_scale_factor(2, secs(6.0)), 10.0, "open-ended onset");
        assert_eq!(plan.byz_scale_factor(0, secs(6.0)), 1.0, "other worker honest");
        // Flip window is half-open; `*` hits everyone.
        assert!(!plan.byz_flip(0, secs(1.99)));
        assert!(plan.byz_flip(0, secs(2.0)));
        assert!(plan.byz_flip(3, secs(3.9)));
        assert!(!plan.byz_flip(3, secs(4.0)));
        // NaN poisoning is per-worker and open-ended.
        assert!(plan.byz_nan(1, secs(100.0)));
        assert!(!plan.byz_nan(1, secs(2.9)));
        assert!(!plan.byz_nan(2, secs(100.0)));

        let honest = FaultPlan::parse("crash:0@1").unwrap();
        assert!(!honest.has_byzantine());
        assert_eq!(honest.byz_scale_factor(0, secs(2.0)), 1.0);
    }

    #[test]
    fn byzantine_clauses_reject_malformed_input() {
        for bad in [
            "byz-scale:1@2",        // missing the factor
            "byz-scale:1:inf@2",    // non-finite factor
            "byz-scale:1:nan@2",    // non-finite factor
            "byz-scale:1:2",        // no onset time
            "byz-flip:1",           // no onset time
            "byz-flip:1@4..2",      // empty window
            "byz-nan:x@2",          // bad worker id
            "byz-nan:1@-2",         // negative time
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn index_bounds_reported() {
        let plan = FaultPlan::parse("crash:7@1,slow:*@0..1*2,stall:3@0..1").unwrap();
        assert_eq!(plan.max_worker(), Some(7));
        assert_eq!(plan.max_shard(), Some(3));
        assert_eq!(FaultPlan::default().max_worker(), None);
    }
}
