//! The discrete-event simulator: the full PS + workers + evaluator
//! pipeline, single-threaded in virtual time.
//!
//! The simulator reuses the *pure* building blocks the threaded stack is
//! made of — one [`Aggregator`] + [`ParamStore`] pair per shard, the same
//! engines and batch sources, the same seed derivations — and replaces
//! threads and channels with an event queue. Because every state mutation
//! happens at a totally ordered (time, sequence) point, a run is a pure
//! function of (scenario, inputs): two runs of the same seed produce
//! bitwise-identical [`RunMetrics`], which is what converts the paper's
//! headline comparison (async vs sync vs hybrid under injected delays, §6)
//! from a flaky minutes-long wall-clock test into a sub-second
//! deterministic one.
//!
//! ## Event-queue ordering guarantees
//!
//! Events pop in ascending `(timestamp, sequence)` order, where `sequence`
//! is a global insertion counter. Consequences:
//!
//! 1. Ties in virtual time resolve by insertion order — deterministic and
//!    FIFO, so a shard processes same-instant arrivals in submission order.
//! 2. A submission fans out to shards `0..S` with consecutive sequence
//!    numbers, so every shard observes the *same arrival sequence* (the
//!    lockstep invariant of DESIGN.md §2.1) even under stalls, which delay
//!    processing but never reorder it.
//! 3. Virtual time never goes backwards; the [`VirtualClock`] is advanced
//!    only by the event loop.
//!
//! ## Protocol fidelity
//!
//! Per arrival the simulator mirrors `server::run_shard` exactly: the same
//! `Aggregator::on_gradient` call, the same reply classification
//! (`AppliedNow`/`Buffered`/`BufferedBlocked`/`Flushed`, including the
//! stale-submitter refresh rule while buffering), the same blocked-worker
//! release at flush, the same non-finite payload rejection at the server
//! boundary (DESIGN.md §2.10), and the same end-of-run drain. Workers hold a local θ
//! copy, refresh only shard slices whose version changed, and start their
//! next gradient once all `S` shard replies are in — the zero-latency
//! analogue of the channel protocol.

use super::super::checkpoint::Checkpoint;
use super::super::clock::{Clock, VirtualClock};
use super::super::compress::{submission_bytes, GradEncoder, ShardGrad};
use super::super::metrics::{RunMetrics, SeriesId};
use super::super::params::ParamStore;
use super::super::policy::{Aggregator, Outcome};
use super::super::shard::ShardLayout;
use super::super::trainer::{eval_on, EvalSet, RunInputs, TrainConfig};
use super::super::worker::BatchSource;
use super::fault::{FaultPlan, FaultSpec};
use super::scenario::Scenario;
use crate::engine::GradEngine;
use crate::util::rng::Pcg64;
use crate::util::stats::Series;
use crate::util::trace::Stage;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

/// Trace-sampling throttle, matching the threaded `ServerConfig` default.
const TRACE_INTERVAL: Duration = Duration::from_millis(200);

/// What can happen at a point in virtual time.
enum Event {
    /// Worker finishes a gradient (compute + injected delay) and submits.
    Submit { worker: usize, epoch: u64 },
    /// One shard's copy of a submission reaches its server, in whatever
    /// wire format the worker's encoder produced.
    Deliver {
        shard: usize,
        worker: usize,
        /// Worker lifetime the submission belongs to (stale after restart).
        epoch: u64,
        /// Duplicated deliveries are ghosts: aggregated by the server (it
        /// cannot tell), but they produce no worker replies.
        ghost: bool,
        base_version: u64,
        loss: f32,
        grad: ShardGrad,
    },
    /// Fault: the worker dies.
    Crash { worker: usize },
    /// Fault: a crashed worker rejoins.
    Restart { worker: usize },
    /// Membership churn (elastic runs): the worker departs cleanly.
    Leave { worker: usize },
    /// Membership churn (elastic runs): `count` new workers join, taking
    /// the lowest never-joined slots.
    Join { count: usize },
    /// One shard's copy of a membership transition. Membership rides the
    /// same per-shard FIFO as gradient deliveries (same stall roll-forward,
    /// consecutive sequence numbers), so every shard observes one totally
    /// ordered (gradient | membership) stream and barrier renormalization
    /// stays in lockstep across shards.
    MemberDeliver {
        shard: usize,
        worker: usize,
        join: bool,
    },
    /// The evaluator samples metrics.
    Eval,
}

struct Scheduled {
    at: Duration,
    seq: u64,
    ev: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.at.cmp(&other.at).then(self.seq.cmp(&other.seq))
    }
}

/// Min-heap of events ordered by `(time, insertion sequence)`.
#[derive(Default)]
struct EventQueue {
    heap: BinaryHeap<Reverse<Scheduled>>,
    seq: u64,
}

impl EventQueue {
    fn push(&mut self, at: Duration, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse(Scheduled {
            at,
            seq: self.seq,
            ev,
        }));
    }

    fn next_time(&self) -> Option<Duration> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }

    fn pop(&mut self) -> Option<(Duration, Event)> {
        self.heap.pop().map(|Reverse(s)| (s.at, s.ev))
    }
}

/// One simulated shard server: the identical state pair the threaded
/// `run_shard` owns, plus the blocked-worker list and trace series.
struct ShardSim {
    agg: Aggregator,
    store: ParamStore,
    /// Workers parked at a barrier, with the epoch of their submission and
    /// the virtual instant they parked (FlushWait span start).
    blocked: Vec<(usize, u64, Duration)>,
    per_worker: Vec<u64>,
    /// Non-finite payloads rejected at this shard's boundary (the same
    /// guard as the threaded `run_shard`; shard 0 is canonical).
    rejected: u64,
    k_traj: Series,
    v_traj: Series,
    last_trace: Option<Duration>,
}

/// One simulated worker: local θ, per-shard versions, engine + data.
struct WorkerSim {
    params: Vec<f32>,
    versions: Vec<u64>,
    needs_refresh: Vec<bool>,
    grad_buf: Vec<f32>,
    /// Wire encoder (error-feedback state + recycled payload buffers),
    /// exactly as the threaded worker owns one.
    encoder: GradEncoder,
    /// Per-shard payloads of the current submission (recycled round-trip).
    payloads: Vec<ShardGrad>,
    engine: Box<dyn GradEngine>,
    source: Box<dyn BatchSource>,
    /// Delay + fault draws; same derivation as the threaded worker:
    /// `Pcg64::new(seed + 1000 + id, id + 1)`.
    rng: Pcg64,
    delayed: bool,
    crashed: bool,
    /// Whether this slot has entered the run. Launch workers start joined;
    /// `join:+N` slots start parked and activate at their join time.
    joined: bool,
    /// Bumped on restart so in-flight events from the previous life are
    /// ignored.
    epoch: u64,
    /// Outstanding shard replies for the current submission.
    pending: usize,
    /// Gradients submitted (the threaded worker's `grads_sent`); bounds
    /// the worker when the scenario sets a `steps` budget.
    sent: u64,
}

/// A resumable simulated run. Construct with [`Simulation::new`], advance
/// with [`Simulation::run_until`] (e.g. to checkpoint mid-run or sample
/// [`Simulation::current_k`]), and finish with [`Simulation::finish`] —
/// or use the one-call [`simulate`].
pub struct Simulation<'a> {
    train: TrainConfig,
    grad_time: Duration,
    faults: FaultPlan,
    layout: ShardLayout,
    shards: Vec<ShardSim>,
    workers: Vec<WorkerSim>,
    queue: EventQueue,
    clock: VirtualClock,
    metrics: RunMetrics,
    eval_engine: Box<dyn GradEngine>,
    test: &'a EvalSet,
    probe: &'a EvalSet,
    params_buf: Vec<f32>,
    faults_dropped: u64,
    faults_duplicated: u64,
    /// Run-level live worker count (elastic runs; == workers otherwise).
    live: usize,
}

impl<'a> Simulation<'a> {
    /// Build the simulated pipeline: engines and batch sources come from
    /// the same factories the threaded trainer uses, with the same seed
    /// derivations (delay assignment from `Pcg64::new(seed, 7)`).
    pub fn new(scn: &Scenario, inputs: &RunInputs<'a>) -> anyhow::Result<Simulation<'a>> {
        scn.validate()?;
        let train = scn.train.clone();
        let dim = inputs.init_params.len();
        anyhow::ensure!(dim > 0, "empty initial parameters");
        let layout = ShardLayout::new(dim, train.shards);

        // Elastic runs pre-allocate slots for every `join:+N` clause:
        // joiners take fresh ids after the launch complement. Without
        // membership clauses this equals `train.workers`, so the static
        // path (worker arrays, RNG draws, aggregator geometry) is
        // unchanged bitwise.
        let total_slots = train.workers + scn.faults.total_joiners();

        let mut shards = Vec::with_capacity(layout.shards());
        for range in layout.ranges() {
            let mut agg = Aggregator::new(train.policy.clone(), range.len(), total_slots);
            if let Some(k) = train.k_max {
                agg = agg.with_k_max(k);
            }
            if train.elastic {
                agg = agg.with_elastic(train.workers, train.min_quorum);
            }
            if !train.aggregate.is_mean() {
                agg = agg.with_aggregate(train.aggregate.clone());
            }
            shards.push(ShardSim {
                agg,
                store: ParamStore::new(inputs.init_params[range].to_vec(), train.lr),
                blocked: Vec::new(),
                per_worker: vec![0; total_slots],
                rejected: 0,
                k_traj: Series::new(),
                v_traj: Series::new(),
                last_trace: None,
            });
        }

        let mut assign_rng = Pcg64::new(train.seed, 7);
        let delayed = train.delay.assign(total_slots, &mut assign_rng);
        let mut workers = Vec::with_capacity(total_slots);
        for id in 0..total_slots {
            let wseed = train.seed.wrapping_add(1000 + id as u64);
            workers.push(WorkerSim {
                params: inputs.init_params.to_vec(),
                versions: vec![0; layout.shards()],
                needs_refresh: vec![false; layout.shards()],
                grad_buf: vec![0.0; dim],
                encoder: GradEncoder::new(train.wire.clone(), dim, layout.shards()),
                payloads: Vec::with_capacity(layout.shards()),
                engine: (inputs.worker_engine)()?,
                source: (inputs.batch_source)(id),
                rng: Pcg64::new(wseed, id as u64 + 1),
                delayed: delayed[id],
                crashed: false,
                joined: id < train.workers,
                epoch: 0,
                pending: 0,
                sent: 0,
            });
        }

        let mut sim = Simulation {
            grad_time: scn.grad_time,
            faults: scn.faults.clone(),
            layout,
            shards,
            workers,
            queue: EventQueue::default(),
            clock: VirtualClock::new(),
            metrics: RunMetrics {
                stream: train.stream.clone(),
                ..Default::default()
            },
            eval_engine: (inputs.eval_engine)()?,
            test: inputs.test,
            probe: inputs.train_probe,
            params_buf: inputs.init_params.to_vec(),
            faults_dropped: 0,
            faults_duplicated: 0,
            live: train.workers,
            train,
        };
        // (The membership trajectory records *transitions* only — same
        // contract as the threaded shard servers — so a churn-free elastic
        // run is bitwise identical to the static one.)

        // Prime the queue: t=0 metric sample, scheduled faults, and every
        // launch worker's first gradient (ready after one iteration time).
        sim.queue.push(Duration::ZERO, Event::Eval);
        for spec in sim.faults.specs.clone() {
            match spec {
                FaultSpec::Crash { worker, at } => sim.queue.push(at, Event::Crash { worker }),
                FaultSpec::Restart { worker, at } => {
                    sim.queue.push(at, Event::Restart { worker })
                }
                FaultSpec::Leave { worker, at } => {
                    sim.queue.push(at, Event::Leave { worker })
                }
                FaultSpec::Join { count, at } => sim.queue.push(at, Event::Join { count }),
                _ => {}
            }
        }
        for w in 0..sim.train.workers {
            if !sim.budget_left(w) {
                continue; // steps=0 edge: the worker never submits
            }
            let d = sim.iter_time(w, Duration::ZERO);
            sim.trace_compute(w, Duration::ZERO, d);
            sim.queue.push(d, Event::Submit { worker: w, epoch: 0 });
        }
        Ok(sim)
    }

    /// Current virtual time.
    pub fn now(&self) -> Duration {
        self.clock.now()
    }

    /// A [`Clock`] view of the simulated time (read-only for callers).
    pub fn clock(&self) -> &dyn Clock {
        &self.clock
    }

    /// Current threshold of one shard's aggregator.
    pub fn current_k(&self, shard: usize) -> usize {
        self.shards[shard].agg.current_k()
    }

    /// Gradient arrivals one shard has aggregated so far.
    pub fn arrivals(&self, shard: usize) -> u64 {
        self.shards[shard].agg.stats.arrivals
    }

    /// Effective shard count.
    pub fn shard_count(&self) -> usize {
        self.layout.shards()
    }

    /// Submissions lost to injected `drop` faults so far.
    pub fn faults_dropped(&self) -> u64 {
        self.faults_dropped
    }

    /// Submissions duplicated by injected `dup` faults so far.
    pub fn faults_duplicated(&self) -> u64 {
        self.faults_duplicated
    }

    /// Run-level live worker count (== the launch worker count on static
    /// runs).
    pub fn live_workers(&self) -> usize {
        self.live
    }

    /// Run-level membership transitions so far (0 on static runs).
    pub fn membership_epochs(&self) -> u64 {
        self.metrics.membership_epochs
    }

    /// One shard's view of the live worker count (lags the run-level count
    /// by membership deliveries still in flight, e.g. behind a stall).
    pub fn shard_live(&self, shard: usize) -> usize {
        self.shards[shard].agg.live()
    }

    /// One shard's applied membership-transition count.
    pub fn shard_membership_epoch(&self, shard: usize) -> u64 {
        self.shards[shard].agg.membership_epoch()
    }

    /// Gradients one shard has *applied* so far (immediately or inside a
    /// flush). `applied + buffered == arrivals` at every quiescent point —
    /// the exactly-once conservation the chaos property test pins.
    pub fn applied(&self, shard: usize) -> u64 {
        let stats = &self.shards[shard].agg.stats;
        stats.applied_async + stats.flushed_gradients
    }

    /// Gradients one shard is currently buffering toward a flush.
    pub fn buffered(&self, shard: usize) -> usize {
        self.shards[shard].agg.buffered()
    }

    /// Parameter-server version (shard 0; shards agree up to in-flight
    /// deliveries).
    pub fn ps_version(&self) -> u64 {
        self.shards[0].store.version()
    }

    /// The assembled full-dimension parameter vector at the current virtual
    /// time (exact: the event loop is quiescent between events, so unlike
    /// the threaded evaluator this view never mixes versions mid-update).
    pub fn assembled_params(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.layout.dim());
        for sh in &self.shards {
            out.extend_from_slice(sh.store.theta());
        }
        out
    }

    /// Snapshot the current training state as a [`Checkpoint`] (save it
    /// with `Checkpoint::save`; reading state does not perturb the run).
    pub fn checkpoint(&self, model: &str) -> Checkpoint {
        Checkpoint {
            model: model.to_string(),
            policy: self.train.policy.to_string(),
            ps_version: self.ps_version(),
            shards: self.layout.shards(),
            params: self.assembled_params(),
        }
    }

    /// Advance virtual time to `min(limit, duration)`, processing every
    /// event scheduled up to and including that instant.
    pub fn run_until(&mut self, limit: Duration) -> anyhow::Result<()> {
        let limit = limit.min(self.train.duration);
        while let Some(at) = self.queue.next_time() {
            if at > limit {
                break;
            }
            let (at, ev) = self.queue.pop().expect("peeked event vanished");
            self.clock.set(at);
            self.handle(ev, at)?;
        }
        if self.clock.now() < limit {
            self.clock.set(limit);
        }
        Ok(())
    }

    /// Run to the end of the virtual budget, drain buffered gradients
    /// (mirroring the threaded shutdown path) and return the metrics.
    pub fn finish(mut self) -> anyhow::Result<RunMetrics> {
        let end = self.train.duration;
        self.run_until(end)?;
        let t = end.as_secs_f64();
        for sh in &mut self.shards {
            sh.agg.drain(&mut sh.store);
            sh.v_traj.push(t, sh.store.version() as f64);
        }
        // Shard 0 is canonical for the logical counters, exactly as in
        // `server::merge_reports`.
        {
            let sh0 = &mut self.shards[0];
            self.metrics.updates_total = sh0.store.version();
            self.metrics.gradients_total = sh0.agg.stats.arrivals;
            self.metrics.flushes = sh0.agg.stats.flushes;
            self.metrics.mean_staleness = if sh0.agg.stats.arrivals > 0 {
                sh0.agg.stats.staleness_sum / sh0.agg.stats.arrivals as f64
            } else {
                0.0
            };
            self.metrics.per_worker_grads = sh0.per_worker.clone();
            self.metrics.rejected_grads = sh0.rejected;
            self.metrics.clipped_grads = sh0.agg.stats.clipped;
            self.metrics.k_trajectory = std::mem::take(&mut sh0.k_traj);
            self.metrics.version_trajectory = std::mem::take(&mut sh0.v_traj);
        }
        self.metrics.shards = self.layout.shards();
        self.metrics.per_shard_updates =
            self.shards.iter().map(|s| s.store.version()).collect();
        self.metrics.final_params = self.assembled_params();
        self.sample_metrics(end)?;
        self.metrics.wall_time = t;
        if let Some(st) = &self.metrics.stream {
            st.flush();
        }
        Ok(self.metrics)
    }

    fn handle(&mut self, ev: Event, at: Duration) -> anyhow::Result<()> {
        match ev {
            Event::Submit { worker, epoch } => self.handle_submit(worker, epoch, at),
            Event::Deliver {
                shard,
                worker,
                epoch,
                ghost,
                base_version,
                loss,
                grad,
            } => self.handle_deliver(shard, worker, epoch, ghost, base_version, loss, &grad, at),
            Event::Crash { worker } => self.handle_departure(worker, at),
            Event::Restart { worker } => self.handle_restart(worker, at),
            Event::Leave { worker } => self.handle_departure(worker, at),
            Event::Join { count } => self.handle_join(count, at),
            Event::MemberDeliver {
                shard,
                worker,
                join,
            } => self.handle_member_deliver(shard, worker, join, at),
            Event::Eval => self.handle_eval(at),
        }
    }

    /// A worker stops for good (crash fault or clean `leave`). On the
    /// static path this only silences the worker — a crashed worker still
    /// counts in every barrier denominator, deliberately observable as a
    /// stall. Under elastic membership the departure is also an eviction:
    /// the worker leaves every barrier denominator (the simulator analogue
    /// of the TCP heartbeat timeout).
    fn handle_departure(&mut self, w: usize, at: Duration) -> anyhow::Result<()> {
        {
            let wk = &mut self.workers[w];
            if wk.crashed || !wk.joined {
                return Ok(()); // already down (or never joined): no-op
            }
            wk.crashed = true;
        }
        if self.train.elastic {
            self.membership_change(w, false, at);
        }
        Ok(())
    }

    /// `count` new workers enter the run: the lowest never-joined slots
    /// activate, pull the complete current θ, and start computing.
    fn handle_join(&mut self, count: usize, at: Duration) -> anyhow::Result<()> {
        let mut admitted = Vec::with_capacity(count);
        for w in 0..self.workers.len() {
            if admitted.len() == count {
                break;
            }
            if !self.workers[w].joined {
                admitted.push(w);
            }
        }
        for w in admitted {
            {
                let wk = &mut self.workers[w];
                wk.joined = true;
                wk.crashed = false;
                wk.pending = 0;
                // A joiner is a fresh process: full refresh of θ.
                for f in wk.needs_refresh.iter_mut() {
                    *f = true;
                }
            }
            self.refresh_worker(w);
            self.membership_change(w, true, at);
            if self.budget_left(w) {
                let d = self.iter_time(w, at);
                let epoch = self.workers[w].epoch;
                self.trace_compute(w, at, at + d);
                self.queue.push(at + d, Event::Submit { worker: w, epoch });
            }
        }
        Ok(())
    }

    /// Record one membership transition and fan it out to every shard
    /// through the same stall-respecting delivery path gradients take.
    fn membership_change(&mut self, worker: usize, join: bool, at: Duration) {
        self.live = if join { self.live + 1 } else { self.live - 1 };
        self.metrics.membership_epochs += 1;
        self.metrics
            .record(SeriesId::Membership, at.as_secs_f64(), self.live as f64);
        for s in 0..self.layout.shards() {
            let deliver_at = self.faults.deliver_time(s, at);
            self.queue.push(
                deliver_at,
                Event::MemberDeliver {
                    shard: s,
                    worker,
                    join,
                },
            );
        }
    }

    /// One shard applies a membership transition: exactly what the
    /// threaded `run_shard` does for a `ShardEvent::Join`/`Leave` — the
    /// departed worker drops out of the blocked list, and a departure that
    /// satisfies the shrunken barrier flushes and releases everyone
    /// blocked.
    fn handle_member_deliver(
        &mut self,
        shard: usize,
        worker: usize,
        join: bool,
        at: Duration,
    ) -> anyhow::Result<()> {
        let t = at.as_secs_f64();
        let t_ns = at.as_nanos() as u64;
        let mut replies: Vec<(usize, u64, bool)> = Vec::new();
        {
            let trace = self.train.trace.as_deref();
            let sh = &mut self.shards[shard];
            if join {
                if sh.agg.member_join(worker) {
                    if let Some(tr) = trace {
                        tr.instant(
                            Stage::Join,
                            worker as u32,
                            shard as u32,
                            t_ns,
                            sh.agg.membership_epoch(),
                            sh.agg.live() as u64,
                        );
                    }
                }
            } else {
                let (changed, flushed) = sh.agg.member_leave(&mut sh.store, worker);
                if changed {
                    sh.blocked.retain(|&(bw, _, _)| bw != worker);
                    if let Some(tr) = trace {
                        tr.instant(
                            Stage::Leave,
                            worker as u32,
                            shard as u32,
                            t_ns,
                            sh.agg.membership_epoch(),
                            sh.agg.live() as u64,
                        );
                    }
                }
                if let Some(Outcome::Flushed { .. }) = flushed {
                    if let Some(tr) = trace {
                        tr.instant(
                            Stage::Flush,
                            worker as u32,
                            shard as u32,
                            t_ns,
                            sh.agg.stats.flushes,
                            sh.store.version(),
                        );
                    }
                    for (bw, be, bat) in sh.blocked.drain(..) {
                        if let Some(tr) = trace {
                            tr.span(
                                Stage::FlushWait,
                                bw as u32,
                                shard as u32,
                                bat.as_nanos() as u64,
                                t_ns,
                                be,
                                0,
                            );
                        }
                        replies.push((bw, be, true));
                    }
                    sh.k_traj.push(t, sh.agg.current_k() as f64);
                }
            }
        }
        let version = self.shards[shard].store.version();
        for (rw, re, changed) in replies {
            self.reply(rw, re, shard, changed, version, at)?;
        }
        Ok(())
    }

    /// Iteration time for worker `w` starting at `at`: virtual compute cost
    /// plus (for affected workers) a seeded delay draw, padded to the
    /// compute-cost floor, times any active straggler-burst factor.
    fn iter_time(&mut self, w: usize, at: Duration) -> Duration {
        let factor = self.faults.slow_factor(w, at);
        let wk = &mut self.workers[w];
        let mut secs = self.grad_time.as_secs_f64();
        if wk.delayed {
            secs += self.train.delay.sample_secs_for(w, &mut wk.rng);
        }
        // `compute_floor` pads the whole iteration (compute + delay),
        // exactly as the threaded worker enforces `min_iter`.
        secs = secs.max(self.train.compute_floor.as_secs_f64());
        Duration::from_secs_f64((secs * factor).max(1e-9))
    }

    fn handle_submit(&mut self, w: usize, epoch: u64, at: Duration) -> anyhow::Result<()> {
        if self.workers[w].crashed || !self.workers[w].joined || self.workers[w].epoch != epoch {
            return Ok(());
        }
        // Compute the gradient against the worker's current local θ.
        let loss = {
            let wk = &mut self.workers[w];
            let (x, y) = wk.source.next();
            match wk.engine.grad(&wk.params, x, y, &mut wk.grad_buf) {
                Ok(l) => l,
                Err(e) => {
                    crate::log_warn!("sim", "worker {w} grad failed: {e:#}");
                    wk.crashed = true;
                    // An engine failure is a permanent loss: under elastic
                    // membership it must also evict, or the dead worker
                    // would stall every future barrier.
                    if self.train.elastic {
                        self.membership_change(w, false, at);
                    }
                    return Ok(());
                }
            }
        };
        // Byzantine corruption acts on the *content* of the gradient,
        // after the honest computation and before encoding — the attacker
        // controls its own process (including its encoder state), but not
        // timing or fan-out, so delivery stays in lockstep and the defense
        // lives on the server side (DESIGN.md §2.10).
        if self.faults.has_byzantine() {
            let nan = self.faults.byz_nan(w, at);
            let mut factor = self.faults.byz_scale_factor(w, at);
            if self.faults.byz_flip(w, at) {
                factor = -factor;
            }
            let wk = &mut self.workers[w];
            if nan {
                for g in wk.grad_buf.iter_mut() {
                    *g = f32::NAN;
                }
            } else if factor != 1.0 {
                let f = factor as f32;
                for g in wk.grad_buf.iter_mut() {
                    *g *= f;
                }
            }
        }
        // Encode into per-shard wire payloads through the worker's encoder.
        // Local compression state (error feedback) advances here, *before*
        // any transport fault: the worker compressed and sent; whether the
        // network then loses the message is not its concern.
        let wire_bytes = {
            let Simulation {
                workers, layout, ..
            } = &mut *self;
            let wk = &mut workers[w];
            wk.encoder.encode(&wk.grad_buf, layout, &mut wk.payloads);
            submission_bytes(&wk.payloads, layout)
        };
        self.metrics.bytes_sent += wire_bytes;
        self.metrics.bytes_dense_equiv += self.layout.dim() as u64 * 4;
        // Encoding is instantaneous in virtual time: a zero-duration span
        // marks the submission point and carries the wire bytes.
        if let Some(tr) = &self.train.trace {
            let t_ns = at.as_nanos() as u64;
            tr.span(
                Stage::Encode,
                w as u32,
                0,
                t_ns,
                t_ns,
                self.workers[w].sent,
                wire_bytes,
            );
        }
        // The submission is out (whatever the transport then does to it):
        // this is the threaded worker's `grads_sent`, and what a `steps`
        // budget counts.
        self.workers[w].sent += 1;

        // Transport faults, drawn from the worker's seeded stream.
        // (Server-side per_worker counters are the authoritative per-worker
        // tally, as in the threaded stack.)
        let drop_p = self.faults.drop_prob(w, at);
        if drop_p > 0.0 && self.workers[w].rng.chance(drop_p) {
            self.faults_dropped += 1;
            if self.budget_left(w) {
                let d = self.iter_time(w, at);
                self.trace_compute(w, at, at + d);
                self.queue.push(at + d, Event::Submit { worker: w, epoch });
            } else if self.train.elastic {
                // The dropped submission spent the budget: clean departure.
                self.workers[w].crashed = true;
                self.membership_change(w, false, at);
            }
            return Ok(());
        }
        let dup_p = self.faults.dup_prob(w, at);
        let dup = dup_p > 0.0 && self.workers[w].rng.chance(dup_p);
        if dup {
            self.faults_duplicated += 1;
        }

        // Fan out to every shard (payload handles are cheap `Arc` clones,
        // like the threaded worker's). Stalled shards receive late but in
        // order.
        self.workers[w].pending = self.layout.shards();
        for s in 0..self.layout.shards() {
            let deliver_at = self.faults.deliver_time(s, at);
            let base_version = self.workers[w].versions[s];
            let grad = self.workers[w].payloads[s].clone();
            if let Some(tr) = &self.train.trace {
                tr.span(
                    Stage::Wire,
                    w as u32,
                    s as u32,
                    at.as_nanos() as u64,
                    deliver_at.as_nanos() as u64,
                    self.workers[w].sent,
                    0,
                );
            }
            self.queue.push(
                deliver_at,
                Event::Deliver {
                    shard: s,
                    worker: w,
                    epoch,
                    ghost: false,
                    base_version,
                    loss,
                    grad: grad.clone(),
                },
            );
            if dup {
                self.queue.push(
                    deliver_at,
                    Event::Deliver {
                        shard: s,
                        worker: w,
                        epoch,
                        ghost: true,
                        base_version,
                        loss,
                        grad,
                    },
                );
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_deliver(
        &mut self,
        shard: usize,
        worker: usize,
        epoch: u64,
        ghost: bool,
        base_version: u64,
        loss: f32,
        grad: &ShardGrad,
        at: Duration,
    ) -> anyhow::Result<()> {
        let range = self.layout.range(shard);
        self.metrics.bytes_received += grad.wire_bytes(range.len()) as u64;
        let t = at.as_secs_f64();
        let t_ns = at.as_nanos() as u64;
        // (worker, epoch, parameters-changed) replies this arrival produces.
        let mut replies: Vec<(usize, u64, bool)> = Vec::new();
        {
            let trace = self.train.trace.as_deref();
            let sh = &mut self.shards[shard];
            sh.per_worker[worker] += 1;
            if !grad.is_finite() {
                // Non-finite payload: rejected at the server boundary, never
                // aggregated (same guard as the threaded `run_shard`). The
                // whole-payload check gives every shard the same verdict, so
                // the lockstep invariant survives, and the submitter still
                // gets a reply (refreshed only if θ moved since it read).
                sh.rejected += 1;
                if !ghost {
                    replies.push((worker, epoch, base_version != sh.store.version()));
                }
            } else {
                let outcome = sh.agg.on_gradient_view(
                    &mut sh.store,
                    grad.view(range),
                    worker,
                    base_version,
                    loss,
                );
                let version = sh.store.version();
                match outcome {
                    Outcome::AppliedNow => {
                        if let Some(tr) = trace {
                            tr.span(
                                Stage::Apply,
                                worker as u32,
                                shard as u32,
                                t_ns,
                                t_ns,
                                base_version,
                                version,
                            );
                        }
                        if !ghost {
                            replies.push((worker, epoch, true));
                        }
                    }
                    Outcome::Buffered => {
                        if let Some(tr) = trace {
                            tr.span(
                                Stage::Accumulate,
                                worker as u32,
                                shard as u32,
                                t_ns,
                                t_ns,
                                base_version,
                                sh.agg.buffered() as u64,
                            );
                        }
                        // θ frozen since the last flush: refresh only a stale
                        // submitter (same rule as the threaded server).
                        if !ghost {
                            replies.push((worker, epoch, base_version != version));
                        }
                    }
                    Outcome::BufferedBlocked => {
                        if let Some(tr) = trace {
                            tr.span(
                                Stage::Accumulate,
                                worker as u32,
                                shard as u32,
                                t_ns,
                                t_ns,
                                base_version,
                                sh.agg.buffered() as u64,
                            );
                        }
                        if !ghost {
                            sh.blocked.push((worker, epoch, at));
                        }
                    }
                    Outcome::Flushed { .. } => {
                        if let Some(tr) = trace {
                            tr.span(
                                Stage::Apply,
                                worker as u32,
                                shard as u32,
                                t_ns,
                                t_ns,
                                base_version,
                                sh.store.version(),
                            );
                            tr.instant(
                                Stage::Flush,
                                worker as u32,
                                shard as u32,
                                t_ns,
                                sh.agg.stats.flushes,
                                sh.store.version(),
                            );
                        }
                        if !ghost {
                            replies.push((worker, epoch, true));
                        }
                        for (bw, be, bat) in sh.blocked.drain(..) {
                            if let Some(tr) = trace {
                                tr.span(
                                    Stage::FlushWait,
                                    bw as u32,
                                    shard as u32,
                                    bat.as_nanos() as u64,
                                    t_ns,
                                    be,
                                    0,
                                );
                            }
                            replies.push((bw, be, true));
                        }
                        sh.k_traj.push(t, sh.agg.current_k() as f64);
                    }
                }
            }
            if sh
                .last_trace
                .map_or(true, |lt| at.saturating_sub(lt) >= TRACE_INTERVAL)
            {
                sh.last_trace = Some(at);
                sh.v_traj.push(t, sh.store.version() as f64);
            }
        }
        let version = self.shards[shard].store.version();
        for (rw, re, changed) in replies {
            self.reply(rw, re, shard, changed, version, at)?;
        }
        Ok(())
    }

    /// Deliver one shard reply to a worker; when it is the last outstanding
    /// reply, refresh changed slices and schedule the next gradient.
    fn reply(
        &mut self,
        w: usize,
        epoch: u64,
        shard: usize,
        changed: bool,
        version: u64,
        at: Duration,
    ) -> anyhow::Result<()> {
        {
            let wk = &mut self.workers[w];
            // Stale replies (crashed or restarted worker) are dropped, like
            // sends to a disconnected channel in the threaded stack.
            if wk.crashed || wk.epoch != epoch || wk.pending == 0 {
                return Ok(());
            }
            if changed && version != wk.versions[shard] {
                wk.needs_refresh[shard] = true;
            }
            wk.pending -= 1;
            if wk.pending > 0 {
                return Ok(());
            }
        }
        self.refresh_worker(w);
        if self.budget_left(w) {
            let d = self.iter_time(w, at);
            let epoch = self.workers[w].epoch;
            self.trace_compute(w, at, at + d);
            self.queue.push(at + d, Event::Submit { worker: w, epoch });
        } else if self.train.elastic && !self.workers[w].crashed {
            // Budget spent: the worker will never submit again, so under
            // elastic membership it departs cleanly instead of being
            // waited on at the next barrier (exactly what a TCP worker
            // does when `join --steps` completes and disconnects).
            self.workers[w].crashed = true;
            self.membership_change(w, false, at);
        }
        Ok(())
    }

    /// Copy every flagged shard slice from its store into the worker's
    /// local θ (the snapshot-cell refresh, without the cells).
    fn refresh_worker(&mut self, w: usize) {
        let Simulation {
            workers,
            shards,
            layout,
            metrics,
            ..
        } = self;
        let wk = &mut workers[w];
        for (s, r) in layout.ranges().enumerate() {
            if wk.needs_refresh[s] {
                let store = &shards[s].store;
                // Logical pull volume (4 B × slice), matching the threaded
                // in-process accounting — deterministic, so it participates
                // in the bitwise RunMetrics reproducibility guarantee.
                metrics.refresh_bytes += (r.len() * 4) as u64;
                wk.params[r].copy_from_slice(store.theta());
                wk.versions[s] = store.version();
                wk.needs_refresh[s] = false;
            }
        }
    }

    fn handle_restart(&mut self, w: usize, at: Duration) -> anyhow::Result<()> {
        {
            let Simulation {
                workers,
                layout,
                train,
                ..
            } = &mut *self;
            let wk = &mut workers[w];
            if !wk.crashed || !wk.joined {
                return Ok(()); // restart of a live (or never-joined) worker is a no-op
            }
            if train.steps.map_or(false, |n| wk.sent >= n) {
                return Ok(()); // budget already spent: nothing to resume
            }
            wk.crashed = false;
            wk.epoch += 1;
            wk.pending = 0;
            // A restarted worker is a fresh process: encoder state (the
            // error-feedback residual, recycled payload buffers) does not
            // survive the crash.
            wk.encoder = GradEncoder::new(train.wire.clone(), layout.dim(), layout.shards());
            // A rejoining worker pulls the complete current θ.
            for f in wk.needs_refresh.iter_mut() {
                *f = true;
            }
        }
        self.refresh_worker(w);
        if self.train.elastic {
            // Readmission: the worker re-enters the live set at the
            // current membership epoch with the fresh snapshot it just
            // pulled.
            self.membership_change(w, true, at);
        }
        if self.budget_left(w) {
            let d = self.iter_time(w, at);
            let epoch = self.workers[w].epoch;
            self.trace_compute(w, at, at + d);
            self.queue.push(at + d, Event::Submit { worker: w, epoch });
        }
        Ok(())
    }

    fn handle_eval(&mut self, at: Duration) -> anyhow::Result<()> {
        self.sample_metrics(at)?;
        let next = at + self.train.eval_interval;
        if next < self.train.duration {
            self.queue.push(next, Event::Eval);
        }
        Ok(())
    }

    fn sample_metrics(&mut self, at: Duration) -> anyhow::Result<()> {
        let Simulation {
            shards,
            layout,
            eval_engine,
            params_buf,
            test,
            probe,
            metrics,
            ..
        } = self;
        for (s, r) in layout.ranges().enumerate() {
            params_buf[r].copy_from_slice(shards[s].store.theta());
        }
        let t = at.as_secs_f64();
        let (test_loss, test_acc) = eval_on(eval_engine.as_mut(), params_buf, *test)?;
        let (train_loss, _) = eval_on(eval_engine.as_mut(), params_buf, *probe)?;
        metrics.record(SeriesId::TestLoss, t, test_loss);
        metrics.record(SeriesId::TestAcc, t, test_acc * 100.0);
        metrics.record(SeriesId::TrainLoss, t, train_loss);
        // Cumulative bytes-on-wire ratio so far; pure integer-counter
        // arithmetic, so the series replays bitwise with the rest.
        let ratio = metrics.wire_compression();
        metrics.record(SeriesId::CompressionRatio, t, ratio);
        Ok(())
    }

    /// Error-feedback residual L1 of one worker's encoder (None for wire
    /// formats without feedback). Diagnostics for the boundedness property
    /// tests; reading it does not perturb the run.
    pub fn worker_residual_l1(&self, w: usize) -> Option<f64> {
        self.workers[w].encoder.residual_l1()
    }

    /// Whether worker `w` may still submit under the scenario's `steps`
    /// budget (always true without one).
    fn budget_left(&self, w: usize) -> bool {
        self.train.steps.map_or(true, |n| self.workers[w].sent < n)
    }

    /// Record the Compute span of worker `w`'s next gradient (scheduled to
    /// land at `end`). Pure observation: it never touches simulation
    /// state, so traced and untraced runs stay bitwise identical.
    fn trace_compute(&self, w: usize, start: Duration, end: Duration) {
        if let Some(tr) = &self.train.trace {
            tr.span(
                Stage::Compute,
                w as u32,
                0,
                start.as_nanos() as u64,
                end.as_nanos() as u64,
                self.workers[w].sent,
                0,
            );
        }
    }
}

/// Run one scenario to completion and return its metrics. Bitwise
/// deterministic: identical (scenario, inputs) ⇒ identical result.
pub fn simulate(scn: &Scenario, inputs: &RunInputs) -> anyhow::Result<RunMetrics> {
    Simulation::new(scn, inputs)?.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::factory;
    use crate::native::QuadraticEngine;
    use std::sync::Arc;

    /// Batch source for engines that ignore their data.
    struct NullSource;
    impl BatchSource for NullSource {
        fn next(&mut self) -> (&[f32], &[i32]) {
            (&[], &[])
        }
    }

    fn quad_inputs<'a>(
        init: &'a [f32],
        eval: &'a EvalSet,
        target: Vec<f32>,
    ) -> RunInputs<'a> {
        let t2 = target.clone();
        RunInputs {
            worker_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(target.clone(), 1, 0.0, 0))
                    as Box<dyn GradEngine>)
            }),
            eval_engine: factory(move || {
                Ok(Box::new(QuadraticEngine::new(t2.clone(), 1, 0.0, 0)) as Box<dyn GradEngine>)
            }),
            batch_source: Arc::new(|_| Box::new(NullSource) as Box<dyn BatchSource>),
            init_params: init,
            test: eval,
            train_probe: eval,
        }
    }

    fn quad_eval_set() -> EvalSet {
        EvalSet {
            x: vec![0.0],
            y: vec![0],
            n: 1,
            x_dim: 1,
            y_dim: 1,
        }
    }

    #[test]
    fn event_queue_orders_by_time_then_insertion() {
        let mut q = EventQueue::default();
        q.push(Duration::from_secs(2), Event::Eval);
        q.push(Duration::from_secs(1), Event::Crash { worker: 0 });
        q.push(Duration::from_secs(1), Event::Crash { worker: 1 });
        q.push(Duration::from_secs(1), Event::Crash { worker: 2 });
        let mut order = Vec::new();
        while let Some((at, ev)) = q.pop() {
            match ev {
                Event::Crash { worker } => order.push((at.as_secs(), worker)),
                Event::Eval => order.push((at.as_secs(), 99)),
                _ => unreachable!(),
            }
        }
        // same-time events pop in insertion order; later times last
        assert_eq!(order, vec![(1, 0), (1, 1), (1, 2), (2, 99)]);
    }

    #[test]
    fn async_sim_counts_and_converges() {
        let init = vec![0.0f32; 6];
        let eval = quad_eval_set();
        let target = vec![2.0f32; 6];
        let inputs = quad_inputs(&init, &eval, target.clone());
        let scn = Scenario::parse("workers=3 policy=async secs=2 grad-ms=10 lr=0.2").unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        // 3 workers × (2 s / 10 ms) iterations, minus in-flight tails
        assert!(m.gradients_total > 500, "{} grads", m.gradients_total);
        assert_eq!(m.updates_total, m.gradients_total);
        assert_eq!(m.shards, 1);
        assert_eq!(m.per_worker_grads.len(), 3);
        // converged to the bowl target
        let final_loss = *m.test_loss.v.last().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        assert_eq!(m.wall_time, 2.0);
    }

    #[test]
    fn sync_sim_barriers_like_the_threaded_server() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        let scn = Scenario::parse("workers=4 policy=sync secs=1 grad-ms=10").unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        // every flush needs all 4 workers, and each flush is one update
        assert!(m.flushes > 10, "{} flushes", m.flushes);
        assert_eq!(m.updates_total, m.flushes);
        assert!(
            m.gradients_total >= 4 * (m.flushes - 1),
            "{} grads for {} flushes",
            m.gradients_total,
            m.flushes
        );
        assert!(m.updates_total <= m.gradients_total / 4 + 1);
    }

    #[test]
    fn hybrid_sim_flushes_and_k_monotone() {
        let init = vec![0.0f32; 8];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 8]);
        let scn =
            Scenario::parse("workers=4 policy=hybrid:step:30 secs=2 grad-ms=10").unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        assert!(m.flushes > 0);
        for w in m.k_trajectory.v.windows(2) {
            assert!(w[1] >= w[0], "K reverted: {:?}", m.k_trajectory.v);
        }
    }

    #[test]
    fn sharded_sim_stays_in_lockstep() {
        let init: Vec<f32> = (0..10).map(|i| i as f32 * 0.1).collect();
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 10]);
        for spec in [
            "workers=3 shards=3 policy=async secs=1 grad-ms=10",
            "workers=3 shards=3 policy=sync secs=1 grad-ms=10",
            "workers=3 shards=3 policy=hybrid:step:25 secs=1 grad-ms=10",
        ] {
            let scn = Scenario::parse(spec).unwrap();
            let m = simulate(&scn, &inputs).unwrap();
            assert_eq!(m.shards, 3);
            assert_eq!(m.per_shard_updates.len(), 3);
            let (min, max) = (
                *m.per_shard_updates.iter().min().unwrap(),
                *m.per_shard_updates.iter().max().unwrap(),
            );
            assert_eq!(min, max, "{spec}: shards diverged {:?}", m.per_shard_updates);
        }
    }

    #[test]
    fn compressed_sim_counts_bytes_and_replays_bitwise() {
        let init = vec![0.0f32; 100];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0f32; 100]);
        let scn = Scenario::parse(
            "workers=2 shards=2 policy=async secs=1 grad-ms=10 compress=topk:0.05",
        )
        .unwrap();
        let a = simulate(&scn, &inputs).unwrap();
        let b = simulate(&scn, &inputs).unwrap();
        assert_eq!(a, b, "compressed runs must replay bitwise");
        assert!(a.gradients_total > 0);
        assert!(a.bytes_sent > 0);
        assert!(a.bytes_received > 0);
        // 5% density at 8 B/coordinate = 10× fewer bytes than dense f32.
        assert!(
            a.wire_compression() > 9.0,
            "compression {}",
            a.wire_compression()
        );
        assert!(!a.compression_ratio.is_empty());
        // The dense format reports ratio 1 and sent == dense-equivalent.
        let dense = Scenario::parse("workers=2 shards=2 policy=async secs=1 grad-ms=10").unwrap();
        let d = simulate(&dense, &inputs).unwrap();
        assert_eq!(d.bytes_sent, d.bytes_dense_equiv);
        assert_eq!(d.wire_compression(), 1.0);
    }

    #[test]
    fn steps_budget_bounds_every_worker() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        let scn = Scenario::parse("workers=3 policy=async secs=5 grad-ms=10 steps=7").unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        // every worker submits exactly its budget, well before the virtual
        // deadline, and the final parameters are reported
        assert_eq!(m.gradients_total, 21);
        assert!(m.per_worker_grads.iter().all(|&g| g == 7), "{:?}", m.per_worker_grads);
        assert_eq!(m.updates_total, 21);
        assert_eq!(m.final_params.len(), 4);
        // replays bitwise like every other scenario
        let n = simulate(&scn, &inputs).unwrap();
        assert_eq!(m, n);
    }

    #[test]
    fn crash_stops_and_restart_resumes_contribution() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        let crash_only =
            Scenario::parse("workers=2 policy=async secs=2 grad-ms=10 faults=crash:0@1").unwrap();
        let m = simulate(&crash_only, &inputs).unwrap();
        // worker 0 contributed for ~half the run, worker 1 throughout
        assert!(m.per_worker_grads[0] < m.per_worker_grads[1]);
        assert!(m.per_worker_grads[0] > 0);

        let with_restart = Scenario::parse(
            "workers=2 policy=async secs=2 grad-ms=10 faults=crash:0@1,restart:0@1.5",
        )
        .unwrap();
        let r = simulate(&with_restart, &inputs).unwrap();
        assert!(
            r.per_worker_grads[0] > m.per_worker_grads[0],
            "restart did not resume: {} vs {}",
            r.per_worker_grads[0],
            m.per_worker_grads[0]
        );
    }

    #[test]
    fn elastic_join_and_leave_track_membership_and_contributions() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        let scn = Scenario::parse(
            "workers=2 policy=async secs=2 grad-ms=10 elastic=on faults=leave:1@1,join:+1@1.2",
        )
        .unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        // Three slots: 2 at launch + 1 joiner.
        assert_eq!(m.per_worker_grads.len(), 3);
        // Worker 1 contributed for only half the run; the joiner for the
        // last 0.8 s.
        assert!(m.per_worker_grads[1] > 0);
        assert!(m.per_worker_grads[1] < m.per_worker_grads[0]);
        assert!(m.per_worker_grads[2] > 0);
        assert!(m.per_worker_grads[2] < m.per_worker_grads[0]);
        // Membership trajectory records the two transitions: down to 1,
        // back to 2.
        assert_eq!(m.membership_epochs, 2);
        assert_eq!(m.membership.v, vec![1.0, 2.0]);
        // Elastic churn replays bitwise like everything else.
        let n = simulate(&scn, &inputs).unwrap();
        assert_eq!(m, n);
    }

    #[test]
    fn byzantine_attacker_diverges_mean_but_not_trimmed() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        // Worker 3 flips and amplifies its gradients 20×. Under the plain
        // mean flush the poisoned contribution dominates every round and θ
        // runs away from the bowl exponentially.
        let attack = "workers=4 policy=sync secs=2 grad-ms=10 lr=0.1 faults=byz-scale:3:-20@0";
        let mean = simulate(&Scenario::parse(attack).unwrap(), &inputs).unwrap();
        let mean_loss = *mean.test_loss.v.last().unwrap();
        assert!(
            !(mean_loss < 10.0),
            "plain mean should diverge under the attack, got loss {mean_loss}"
        );

        // The identical attack with a trimmed-mean flush: the outlier is
        // cut per coordinate and the run converges as if it were clean.
        let defended = format!("{attack} aggregate=trimmed:0.25");
        let scn = Scenario::parse(&defended).unwrap();
        let trimmed = simulate(&scn, &inputs).unwrap();
        let trimmed_loss = *trimmed.test_loss.v.last().unwrap();
        assert!(
            trimmed_loss < 1e-2,
            "trimmed mean should converge under the attack, got loss {trimmed_loss}"
        );
        assert!(trimmed.final_params.iter().all(|p| p.is_finite()));
        // The defended run replays bitwise from its logged scenario line.
        let again = simulate(&Scenario::parse(&scn.to_string()).unwrap(), &inputs).unwrap();
        assert_eq!(trimmed, again);
    }

    #[test]
    fn nan_poisoning_is_rejected_and_the_run_stays_healthy() {
        let init = vec![0.0f32; 4];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 4]);
        let scn = Scenario::parse(
            "workers=2 policy=async secs=2 grad-ms=10 lr=0.2 faults=byz-nan:1@1",
        )
        .unwrap();
        let m = simulate(&scn, &inputs).unwrap();
        // Every payload worker 1 sends after t=1 is rejected at the server
        // boundary: counted, never aggregated, and the run stays healthy.
        assert!(m.rejected_grads > 0, "rejected {}", m.rejected_grads);
        assert_eq!(
            m.gradients_total + m.rejected_grads,
            m.per_worker_grads.iter().sum::<u64>(),
            "accepted + rejected must account for every arrival"
        );
        assert!(m.final_params.iter().all(|p| p.is_finite()));
        let final_loss = *m.test_loss.v.last().unwrap();
        assert!(final_loss < 1e-3, "loss {final_loss}");
        // Rejection still replies, so the poisoned worker keeps iterating
        // instead of hanging on a reply that never comes.
        assert!(
            m.per_worker_grads[1] > m.per_worker_grads[0] / 2,
            "{:?}",
            m.per_worker_grads
        );
        let n = simulate(&scn, &inputs).unwrap();
        assert_eq!(m, n, "byzantine runs replay bitwise");
    }

    #[test]
    fn quiescent_params_match_metrics_view() {
        let init = vec![0.5f32; 5];
        let eval = quad_eval_set();
        let inputs = quad_inputs(&init, &eval, vec![1.0; 5]);
        let scn = Scenario::parse("workers=2 shards=2 policy=async secs=1 grad-ms=20").unwrap();
        let mut sim = Simulation::new(&scn, &inputs).unwrap();
        sim.run_until(Duration::from_millis(500)).unwrap();
        assert_eq!(sim.now(), Duration::from_millis(500));
        let p = sim.assembled_params();
        assert_eq!(p.len(), 5);
        let ck = sim.checkpoint("quad");
        assert_eq!(ck.params, p);
        assert_eq!(ck.shards, 2);
        assert_eq!(ck.ps_version, sim.ps_version());
        // reading state must not perturb the run
        let m = sim.finish().unwrap();
        assert!(m.gradients_total > 0);
    }
}
